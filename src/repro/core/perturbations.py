"""Perturbation generators for multiplexed gradient descent (paper §2.1, §3.4).

The paper trains hardware by adding a small, zero-mean perturbation θ̃ᵢ(t) to
every parameter and homodyne-detecting each parameter's contribution to the
global cost modulation C̃(t).  Four perturbation families are implemented,
matching the paper's Fig. 1c / §3.4:

* ``rademacher``  — simultaneous random ±Δθ codes ("statistically orthogonal",
  the SPSA setting).  This is the at-scale default: each sign is regenerated on
  demand from a counter-based hash of (step, leaf, intra-leaf index), so the
  perturbation is never stored — the JAX analogue of the paper's "generated
  locally and randomly at the parameter" (LFSR-per-synapse) hardware picture.
* ``walsh``       — deterministic pairwise-orthogonal ±Δθ square waves
  (code-multiplexing; Walsh functions indexed by parameter).
* ``sequential``  — one parameter at a time perturbed by +Δθ (finite
  difference / coordinate descent, depending on τ_θ).
* ``sinusoidal``  — unique frequency per parameter (frequency multiplexing,
  the analog Algorithm 2 setting).

All generators are pure functions of (shapes, step, seed) — no state, no HBM
traffic for the perturbation itself, deterministic across hosts and restarts.
The Rademacher hash is bit-for-bit reproduced by the Pallas kernels in
``repro.kernels`` (see ``kernels/ref.py``).
"""
from __future__ import annotations

import dataclasses
import math
import typing
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .utils import leaf_meta

PERTURBATION_TYPES = ("rademacher", "walsh", "sequential", "sinusoidal")

# ---------------------------------------------------------------------------
# Counter-based hashing (murmur3 finalizer).  uint32 arithmetic wraps in XLA,
# which is exactly what we want.  Kept tiny so the same sequence of ops can be
# emitted inside a Pallas kernel body (see kernels/perturbed_matmul.py).
# ---------------------------------------------------------------------------

_GOLDEN = np.uint32(0x9E3779B9)
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)


def _fmix32(x):
    """murmur3 32-bit finalizer — good avalanche, 5 ops, Pallas-friendly."""
    x = x ^ (x >> np.uint32(16))
    x = x * _M1
    x = x ^ (x >> np.uint32(13))
    x = x * _M2
    x = x ^ (x >> np.uint32(16))
    return x


def leaf_seed(seed, pert_step, leaf_id):
    """32-bit per-(step, leaf) seed.  Scalars; works on host ints or tracers."""
    s = jnp.uint32(seed) * _GOLDEN + jnp.uint32(leaf_id)
    s = _fmix32(s)
    s = s + jnp.asarray(pert_step, jnp.uint32) * _M1
    return _fmix32(s)


def rademacher_signs(lseed, idx):
    """±1 (float32) signs from a leaf seed and intra-leaf indices (uint32)."""
    h = _fmix32(idx.astype(jnp.uint32) * _GOLDEN + lseed)
    # top bit → sign
    return 1.0 - 2.0 * (h >> np.uint32(31)).astype(jnp.float32)


def _walsh_signs(pert_step, idx):
    """Walsh function W_{i+1}(t): (-1)^popcount((i+1) & t).

    Deterministically pairwise-orthogonal over any 2^k period covering the
    parameter count.  Index 0 (the all-ones, non-zero-mean code) is skipped.
    """
    v = (idx.astype(jnp.uint32) + np.uint32(1)) & jnp.asarray(pert_step, jnp.uint32)
    v = v ^ (v >> np.uint32(16))
    v = v ^ (v >> np.uint32(8))
    v = v ^ (v >> np.uint32(4))
    v = v ^ (v >> np.uint32(2))
    v = v ^ (v >> np.uint32(1))
    parity = (v & np.uint32(1)).astype(jnp.float32)
    return 1.0 - 2.0 * parity


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def generate(params_like, *, ptype, step, seed, dtheta, tau_p=1, total=None):
    """Generate the perturbation pytree θ̃ for global timestep ``step``.

    params_like may hold concrete arrays or ShapeDtypeStructs — only shapes and
    dtypes are consulted.  Returns a pytree of the same structure/dtype whose
    leaves are the perturbations (amplitude Δθ folded in).

    ``tau_p`` is the perturbation time constant: the perturbation pattern only
    advances every tau_p steps (paper Table 1).
    """
    if ptype not in PERTURBATION_TYPES:
        raise ValueError(f"unknown perturbation type {ptype!r}")
    metas = leaf_meta(params_like)
    total = total or sum(m[2] for m in metas)
    pert_step = jnp.asarray(step, jnp.int32) // jnp.int32(tau_p)
    leaves = jax.tree_util.tree_leaves(params_like)
    out = []
    for (lid, offset, n), leaf in zip(metas, leaves):
        shape = leaf.shape
        if ptype == "rademacher":
            idx = jax.lax.iota(jnp.uint32, n)
            sgn = rademacher_signs(leaf_seed(seed, pert_step, lid), idx)
            pert = sgn * dtheta
        elif ptype == "walsh":
            idx = jax.lax.iota(jnp.uint32, n) + np.uint32(offset)
            pert = _walsh_signs(pert_step, idx) * dtheta
        elif ptype == "sequential":
            idx = jax.lax.iota(jnp.int32, n) + jnp.int32(offset)
            active = (pert_step % jnp.int32(total)).astype(jnp.int32)
            pert = jnp.where(idx == active, dtheta, 0.0).astype(jnp.float32)
        elif ptype == "sinusoidal":
            idx = jax.lax.iota(jnp.float32, n) + float(offset)
            # unique frequency per parameter within (0, f_max], f_max = 1/(2 tau_p)
            f = (idx + 1.0) / float(total + 1) * (0.5 / float(tau_p))
            t = jnp.asarray(step, jnp.float32)
            pert = dtheta * jnp.sin(2.0 * np.pi * f * t)
        out.append(pert.reshape(shape).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params_like), out
    )


def generate_signs_only(params_like, *, step, seed, tau_p=1):
    """Rademacher ±1 signs (no Δθ), f32 — used by the homodyne accumulation
    and the scalar-replay update so the Δθ² normalization cancels exactly."""
    metas = leaf_meta(params_like)
    pert_step = jnp.asarray(step, jnp.int32) // jnp.int32(tau_p)
    leaves = jax.tree_util.tree_leaves(params_like)
    out = []
    for (lid, _, n), leaf in zip(metas, leaves):
        idx = jax.lax.iota(jnp.uint32, n)
        sgn = rademacher_signs(leaf_seed(seed, pert_step, lid), idx)
        out.append(sgn.reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params_like), out
    )


def rademacher_leaf(shape, dtype, lid, *, step, seed, dtheta, tau_p=1,
                    offset=0):
    """θ̃ for ONE leaf (or a contiguous row-major slice of a stacked leaf)
    addressed by its *global* leaf id — bit-for-bit what ``generate`` emits
    for that leaf under ptype="rademacher".

    ``offset`` is the element offset of the slice within the leaf (e.g.
    layer l of a stacked [L, d_in, d_out] bank → offset = l·d_in·d_out);
    it may be a traced uint32 (scan carry).
    """
    n = 1
    for s in shape:
        n *= s
    pert_step = jnp.asarray(step, jnp.int32) // jnp.int32(tau_p)
    idx = jax.lax.iota(jnp.uint32, n) + jnp.asarray(offset, jnp.uint32)
    sgn = rademacher_signs(leaf_seed(seed, pert_step, lid), idx)
    return (sgn * dtheta).reshape(shape).astype(dtype)


def shifted_leaf_seed(lseed, offset_elems):
    """Leaf seed for a kernel that sign-indexes a row-major *slice* of a
    leaf: fmix32((i+Δ)·G + s) == fmix32(i·G + (s + Δ·G)), so shifting the
    seed by Δ·G makes the kernel's local indices reproduce the slice's
    global sign pattern.  ``offset_elems`` is the slice's element offset
    within the flattened leaf (traced ok; uint32 wraparound matches the
    host generator's uint32 iota)."""
    return (jnp.asarray(lseed, jnp.uint32)
            + jnp.asarray(offset_elems, jnp.uint32) * _GOLDEN)


def apply_signed(leaf, theta, sign):
    """leaf + sign·θ̃ with the exact float order of the optimizer's
    materializing path: tree_add for sign=+1, tree_axpy otherwise."""
    if sign == 1.0:
        return leaf + theta
    return (leaf.astype(jnp.float32)
            + sign * theta.astype(jnp.float32)).astype(leaf.dtype)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class ProbeCtx:
    """Static descriptor of a fused probe evaluation (hashable; the traced
    step/seed scalars travel in the ``Probe`` pytree, NOT in this object).

    ``signs`` is the static tuple of probe signs — (1.0,) for a forward
    probe, (1.0, −1.0) for an antithetic central pair (which routes weight
    matmuls through the single-pass pair kernel).
    """

    signs: tuple = (1.0,)
    dtheta: float = 1e-3
    tau_p: int = 1
    impl: Optional[str] = None      # pallas | interpret | ref | None=auto

    @property
    def n_streams(self) -> int:
        return len(self.signs)

    @property
    def is_pair(self) -> bool:
        return self.signs == (1.0, -1.0)


class Probe(typing.NamedTuple):
    """One probe evaluation request: traced scalars + static ProbeCtx.

    A NamedTuple pytree whose ``ctx`` field is register_static, so the whole
    object threads through jit/scan closures with only (step, seed) traced.
    """

    step: jnp.ndarray               # int32 global iteration n
    seed: jnp.ndarray               # uint32 probe seed
    ctx: ProbeCtx

    def lseed(self, leaf_id):
        """Per-leaf kernel seed — identical hash chain to ``generate``."""
        pert_step = jnp.asarray(self.step, jnp.int32) // jnp.int32(
            self.ctx.tau_p)
        return leaf_seed(self.seed, pert_step, leaf_id)

    def leaf_theta(self, shape, dtype, leaf_id, offset=0):
        """Materialized θ̃ for a (slice of a) leaf — the fallback for leaves
        the kernels don't cover (biases, norm scales, embeddings)."""
        return rademacher_leaf(
            shape, dtype, leaf_id, step=self.step, seed=self.seed,
            dtheta=self.ctx.dtheta, tau_p=self.ctx.tau_p, offset=offset)


def orthogonality_check(ptype, n_params, n_steps, *, seed=0, dtheta=1.0, tau_p=1):
    """Empirical Gram matrix of the perturbation sequences (test helper).

    Returns the (n_params, n_params) normalized time-average of θ̃ᵢθ̃ⱼ — the
    paper's pairwise-orthogonality condition is Gram ≈ Δθ²·I (sinusoids: Δθ²/2·I).
    """
    dummy = {"w": jax.ShapeDtypeStruct((n_params,), jnp.float32)}

    def one(t):
        return generate(
            dummy, ptype=ptype, step=t, seed=seed, dtheta=dtheta, tau_p=tau_p
        )["w"]

    seq = jax.vmap(one)(jnp.arange(n_steps))  # [T, P]
    return (seq.T @ seq) / n_steps
