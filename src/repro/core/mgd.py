"""Multiplexed gradient descent — discrete algorithm (paper Algorithm 1).

Construct this algorithm through the driver registry::

    mgd = repro.driver("discrete", repro.DriverConfig(...), loss_fn,
                       plant=..., probe_fn=...)
    state = mgd.init(params)
    params, state, aux = mgd.step(params, state, batch)

``repro.driver`` (see ``repro.api.driver``) builds the discrete,
continuous, and probe-parallel algorithms behind one optax-style
``(init, step)`` contract (the retired ``make_mgd_step`` shim now
raises).  This module keeps the discrete algorithm's implementation:
``MGDConfig``, ``MGDState``, ``mgd_init``, and the step factory
``build_mgd_step``.

The MGD step is *model-free*: it consumes only a scalar cost oracle — a
``repro.hardware.Plant`` (ideal, noisy, quantized, or an external chip),
or equivalently a plain ``loss_fn(params, batch) -> cost`` wrapped into
the implicit in-process plant — plus the three time constants
(τ_p, τ_θ, τ_x) and a perturbation family.  One MGD iteration is:

    1. (re)generate the perturbation θ̃ for this step            [τ_p]
    2. refresh the baseline cost C₀ if the sample or params
       changed (forward mode), or probe ±θ̃ (central mode)       [τ_x]
    3. C̃ ← C(θ+θ̃) − C₀        (the only feedback — ONE SCALAR)
    4. e ← C̃·θ̃/Δθ²;  G ← G + e   (local homodyne accumulation)
    5. every τ_θ: θ ← θ − ηG;  G ← 0                            [τ_θ]

Everything is implemented with ``lax`` control flow so the whole step jits,
lowers, and GSPMD-partitions; under pjit the only gradient-path collective
is the psum XLA inserts for the scalar cost reduction.

Paper-faithful mode is ``mode="forward"`` with ``replay=False`` and
``probes=1``.  Beyond-paper extensions (recorded separately in
EXPERIMENTS.md §Perf):

* ``mode="central"``  — antithetic probe C(θ+θ̃)−C(θ−θ̃): O(Δθ²) bias and no
  C₀ refresh pass (same 2-forward budget as forward mode at τ_x=1).
* ``replay=True``     — scalar-replay memory: instead of the O(P) gradient
  accumulator G the paper requires when τ_θ > τ_p, store only the τ_θ-window
  of C̃ scalars and regenerate θ̃ at update time.  O(1) optimizer memory.
* ``probes=k``        — k independent perturbation vectors per step,
  averaged.  Variance ∝ 1/k; at pod scale the probe axis maps onto the mesh
  (see ``probe_parallel``) with only k scalars crossing the interconnect.
* ``momentum``        — classical heavy-ball on G (the paper notes MGD "is
  capable of implementing" momentum; we provide it).
"""
from __future__ import annotations

import copy
import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import perturbations as pert
from .utils import (
    leaf_meta,
    tree_add,
    tree_axpy,
    tree_scale,
    tree_select,
    tree_size,
    tree_zeros_like,
)

Pytree = Any


# ---------------------------------------------------------------------------
# Config / state
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MGDConfig:
    """Static configuration of the MGD optimizer (hashable → jit-static).

    Attributes mirror the paper's Table 1 plus framework extensions.
    """

    # perturbation family: rademacher | walsh | sequential | sinusoidal
    ptype: str = "rademacher"
    dtheta: float = 1e-3          # Δθ, perturbation amplitude
    eta: float = 1e-2             # η, learning rate
    tau_p: int = 1                # perturbation time constant
    tau_theta: int = 1            # parameter-update (gradient-integration) time
    tau_x: int = 1                # input-sample change time (driver-enforced)
    mode: str = "forward"         # forward (paper) | central (beyond-paper)
    replay: bool = False          # scalar-replay O(1)-memory updates
    probes: int = 1               # probe-averaging count
    probe_impl: str = "map"       # map (sequential) | vmap (parallel/shardable)
    momentum: float = 0.0         # heavy-ball coefficient on G
    seed: int = 0
    # hardware noise emulation (paper §3.5).  These fields describe the
    # IMPLICIT device (they build a hardware.NoisyPlant internally); when
    # an explicit plant is passed to build_mgd_step they must stay 0 — the
    # plant owns all imperfections.
    cost_noise: float = 0.0       # σ_C  — gaussian noise added to every cost read
    update_noise: float = 0.0     # σ_θ  — update noise, std σ_θ·Δθ (see hardware.plants)
    # bounded-staleness feedback: the update at step n may consume C̃ from
    # step n-d (straggler tolerance; 0 = synchronous paper behaviour)
    staleness: int = 0
    # fused probe execution: probes evaluate through a model-provided
    # probe_fn that routes weight matmuls through the Pallas
    # perturbed-matmul kernels (θ̃ generated in VMEM, never in HBM), and
    # the update regenerates θ̃ inside kernels.mgd_update_window for every
    # ndim≥2 leaf.  Bit-identical (f32) cost/parameter trajectories to the
    # materializing path; ~¼ the weight HBM reads per central probe pair
    # (EXPERIMENTS.md §Perf).
    fused: bool = False
    kernel_impl: Optional[str] = None   # pallas | interpret | ref | None=auto

    def __post_init__(self):
        if self.ptype not in pert.PERTURBATION_TYPES:
            raise ValueError(f"unknown perturbation type {self.ptype!r}")
        if self.mode not in ("forward", "central"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.replay and self.ptype == "sinusoidal" and self.tau_theta > 256:
            # replay regenerates θ̃ for each window step — fine for codes,
            # wasteful for long analog windows.
            raise ValueError("replay mode with sinusoidal ptype and large "
                             "tau_theta: use the analog algorithm instead")
        if self.staleness and not self.replay:
            raise ValueError("bounded-staleness feedback requires replay mode "
                             "(the C̃ window is what absorbs the delay)")
        if self.fused:
            if self.ptype != "rademacher":
                raise ValueError("fused path regenerates signs in-kernel — "
                                 "rademacher only")
            if self.probes != 1:
                raise ValueError("fused path supports probes=1 (probe "
                                 "parallelism composes at the mesh level)")
            if self.momentum or self.update_noise:
                raise ValueError("fused path has no materialized update "
                                 "direction — momentum/update_noise need "
                                 "the unfused optimizer")
            if self.tau_theta > 1 and not self.replay:
                raise ValueError("fused path with tau_theta > 1 requires "
                                 "replay=True (the O(P) gradient accumulator "
                                 "is exactly what fusion eliminates)")


class MGDState(NamedTuple):
    """Carried optimizer state.  Structure is fixed per MGDConfig."""

    step: jnp.ndarray                 # int32 global iteration counter n
    c0: jnp.ndarray                   # f32 baseline cost C₀ (forward mode)
    g: Optional[Pytree]               # gradient accumulator (None in replay)
    replay_c: Optional[jnp.ndarray]   # f32[tau_theta + staleness] C̃ window
    m: Optional[Pytree]               # momentum buffer (None if momentum==0)
    metric_cost: jnp.ndarray          # f32 last unperturbed-ish cost (telemetry)


def mgd_init(params: Pytree, cfg: MGDConfig) -> MGDState:
    """Fresh optimizer state for ``params`` under ``cfg``.

    Works with concrete arrays *or* ShapeDtypeStructs (dry-run safe) —
    buffers are created with ``jnp.zeros`` from shape/dtype only.
    τ_θ = 1 needs no gradient accumulator at all (the update consumes the
    error signal immediately — paper §4.2's "only a single additional
    memory element is required"); at deepseek scale the f32 G buffer would
    be 10.5 GiB/device, so this is a fits-in-HBM matter, not a nicety.
    """
    g = (None if (cfg.replay or cfg.tau_theta == 1)
         else tree_zeros_like(params, jnp.float32))
    window = cfg.tau_theta + cfg.staleness
    replay_c = jnp.zeros((window,), jnp.float32) if cfg.replay else None
    m = tree_zeros_like(params, jnp.float32) if cfg.momentum else None
    return MGDState(
        step=jnp.zeros((), jnp.int32),
        c0=jnp.zeros((), jnp.float32),
        g=g,
        replay_c=replay_c,
        m=m,
        metric_cost=jnp.zeros((), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Plant resolution (the device the optimizer drives)
# ---------------------------------------------------------------------------


def _resolve_plant(loss_fn, cfg, *, probe_fn=None, plant=None):
    """The device behind this optimizer run.

    With ``plant=None`` the historical in-process behaviour is rebuilt
    from the config: ``cost_noise``/``update_noise`` become a
    ``NoisyPlant`` with the exact historical key derivation, σ = 0 an
    ``IdealPlant`` — bit-identical (f32) either way.  An explicit plant
    owns ALL hardware imperfections, so the config noise fields must be
    zero (anything else would double-count the noise).
    """
    # runtime import: repro.hardware.base imports core.utils, so a
    # module-level import here would be circular.
    from repro.hardware.base import Plant
    from repro.hardware.plants import plant_from_config

    if plant is None:
        if loss_fn is None:
            raise ValueError("need a loss_fn (or an explicit plant)")
        return plant_from_config(loss_fn, cfg, probe_fn=probe_fn)
    if not isinstance(plant, Plant):
        raise TypeError(f"plant must be a repro.hardware.Plant, "
                        f"got {type(plant).__name__}")
    if getattr(cfg, "cost_noise", 0.0) or getattr(cfg, "update_noise", 0.0):
        raise ValueError(
            "cfg.cost_noise/update_noise describe the implicit device; "
            "with an explicit plant the plant owns all imperfections — "
            "set the config fields to 0")
    if probe_fn is not None and plant.probe_fn is not probe_fn:
        if plant.probe_fn is not None:
            raise ValueError("both the plant and build_mgd_step were given "
                             "a probe_fn — they disagree; set it in one "
                             "place")
        # shallow copy so a plant shared across optimizers never inherits
        # another model's perturbed-apply
        plant = copy.copy(plant)
        plant.probe_fn = probe_fn
    return plant


# ---------------------------------------------------------------------------
# The step factory
# ---------------------------------------------------------------------------


def _probe_seed(cfg: MGDConfig, probe) -> jnp.ndarray:
    # distinct, deterministic seed per probe; probe 0 == cfg.seed so
    # probes=1 is bit-identical to the unprobed path.  uint32 arithmetic —
    # ``probe`` may be a traced int under lax.map/vmap.
    return (jnp.uint32(cfg.seed)
            + jnp.asarray(probe, jnp.uint32) * jnp.uint32(0x9E3779B9))


def build_mgd_step(
    loss_fn: Optional[Callable[[Pytree, Any], jnp.ndarray]],
    cfg: MGDConfig,
    total_params: Optional[int] = None,
    *,
    probe_fn: Optional[Callable] = None,
    plant=None,
):
    """Build the jittable MGD iteration (the registry's discrete builder).

    ``loss_fn(params, batch) -> scalar cost`` is the ONLY model interface —
    MGD never sees the network topology (model-free, paper §1).  All cost
    reads and parameter writes go through a ``repro.hardware.Plant``; pass
    one explicitly to train against a noisy/quantized/external device, or
    pass none to get the implicit in-process device (``IdealPlant``, or
    ``NoisyPlant`` when the config's σ_C/σ_θ fields are set) — bit-identical
    (f32) to the historical inlined path.  With an explicit plant,
    ``loss_fn`` may be ``None``: the plant is the only cost oracle.

    With ``cfg.fused=True`` the model additionally provides
    ``probe_fn(params, batch, probe: perturbations.Probe) -> [n_signs]``
    costs under θ ± θ̃ — the perturbed-apply interface (e.g.
    ``models.simple.make_mlp_probe_fn`` or
    ``models.make_transformer_probe_fn``) that routes weight matmuls
    through the Pallas kernels so θ̃ never exists in HBM.  The fused path
    produces bit-identical (f32) C̃/parameter trajectories to the
    materializing path, and reaches the kernels through
    ``plant.apply_perturbed`` so hardware models compose with it.

    Returns ``step_fn(params, state, batch) -> (params, state, metrics)``.
    The caller controls τ_x by switching ``batch`` every τ_x calls (the data
    pipeline does this); everything else is internal.
    """
    plant = _resolve_plant(loss_fn, cfg, probe_fn=probe_fn, plant=plant)
    if cfg.fused and not plant.supports_fused:
        raise ValueError("cfg.fused=True needs a probe_fn (the model's "
                         "perturbed-apply interface) on the plant")
    if plant.meta.external:
        # Ordered host callbacks cannot live inside lax.cond: forward
        # mode's C₀ refresh and every windowed update (replay or
        # accumulator select) are conds, and the τ_θ>1 accumulator path
        # additionally computes a write per step that tree_select then
        # discards — on a physical device that write already happened.
        # The cond-free step is central τ_θ=1 (the chip-in-the-loop
        # configuration); temporal windows belong on the host loop.
        if cfg.mode != "central" or cfg.tau_theta != 1 or cfg.replay:
            raise ValueError("external plants need mode='central', "
                             "tau_theta=1, replay=False — the only "
                             "cond-free step an ordered host callback "
                             "can ride (see hardware/external.py)")

    def perturbation(params, step, probe=0):
        return pert.generate(
            params,
            ptype=cfg.ptype,
            step=step,
            seed=_probe_seed(cfg, probe),
            dtheta=cfg.dtheta,
            tau_p=cfg.tau_p,
            total=total_params,
        )

    inv_d2 = 1.0 / (cfg.dtheta * cfg.dtheta)

    # Rounding pin for the scalar homodyne coefficients (C̃/Δθ² and the
    # replay a_j).  XLA's simplifier is free to re-merge constant factors
    # (Δθ, 1/Δθ², η) across these products — legal per-program, but it
    # rounds differently in different programs, which would break the
    # fused-vs-materialized bit-equality contract.  Pinning the coefficient
    # value at its definition keeps every program on the written
    # association.
    _pin = jax.lax.optimization_barrier

    def probe_once(params, state, batch, probe):
        """One perturbation probe → (C̃, θ̃, c0, cost_metric)."""
        n = state.step
        theta_t = perturbation(params, n, probe)
        if cfg.mode == "central":
            c_plus, c_minus = plant.read_cost_pair(
                params, theta_t, batch, step=n, tag=2 * probe)
            # barrier: pin C̃'s own rounding before the ·1/Δθ² scaling —
            # XLA otherwise folds 0.5·inv_d2 into one constant in SOME
            # programs, breaking fused-vs-materialized bit-equality.
            c_tilde = jax.lax.optimization_barrier(0.5 * (c_plus - c_minus))
            return c_tilde, theta_t, state.c0, 0.5 * (c_plus + c_minus)
        # forward mode (paper Algorithm 1): refresh C₀ when the sample
        # changed (n % τ_x == 0) or params were updated (n % τ_θ == 0).
        need_c0 = jnp.logical_or(n % cfg.tau_x == 0, n % cfg.tau_theta == 0)
        c0 = jax.lax.cond(
            need_c0,
            lambda: plant.read_cost(params, batch, step=n,
                                    tag=2 * probe).astype(jnp.float32),
            lambda: state.c0,
        )
        c_pert = plant.read_cost(tree_add(params, theta_t), batch,
                                 step=n, tag=2 * probe + 1)
        return c_pert - c0, theta_t, c0, c0

    def accumulate(params, state, batch):
        """All probes → averaged error signal contribution + scalars."""
        if cfg.probes == 1:
            c_tilde, theta_t, c0, cm = probe_once(params, state, batch, 0)
            e = tree_scale(theta_t, _pin(c_tilde * inv_d2))
            return e, c_tilde, c0, cm

        def one(probe):
            c_tilde, theta_t, c0, cm = probe_once(params, state, batch, probe)
            e = tree_scale(theta_t, _pin(c_tilde * inv_d2))
            return e, c_tilde, c0, cm

        ids = jnp.arange(cfg.probes)
        if cfg.probe_impl == "vmap":
            es, cts, c0s, cms = jax.vmap(one)(ids)
        else:
            es, cts, c0s, cms = jax.lax.map(one, ids)
        e = tree_scale(jax.tree_util.tree_map(lambda x: jnp.sum(x, 0), es),
                       1.0 / cfg.probes)
        return e, jnp.mean(cts), c0s.reshape(-1)[0], jnp.mean(cms)

    def apply_update(params, state, g_step):
        """θ ← θ − η·G (Eq. 4) with optional momentum; the write lands
        through the plant (write noise / DAC quantization / slow-write
        lag happen there — identity for the ideal device)."""
        n = state.step
        m = state.m
        if cfg.momentum:
            m = tree_axpy(1.0, g_step, tree_scale(state.m, cfg.momentum))
            direction = m
        else:
            direction = g_step
        new_params = plant.write_params(
            tree_axpy(-cfg.eta, direction, params), step=n, prev=params)
        return new_params, m

    # ----- fused probe + update paths (cfg.fused) --------------------------
    #
    # The probe evaluates through probe_fn (kernels regenerate θ̃ in VMEM);
    # the update regenerates θ̃ inside kernels.mgd_update_window for every
    # ndim≥2 leaf (read-W + write-W HBM traffic, window-length independent)
    # and materializes only the O(d) leaves.  Every float op mirrors the
    # materializing path's association exactly — see mgd_update_window.

    def _probe(n, signs):
        ctx = pert.ProbeCtx(signs=signs, dtheta=cfg.dtheta, tau_p=cfg.tau_p,
                            impl=cfg.kernel_impl)
        return pert.Probe(n, _probe_seed(cfg, 0), ctx)

    def probe_once_fused(params, state, batch):
        """Fused probe → (C̃, c0, cost_metric); no θ̃ pytree exists."""
        n = state.step
        if cfg.mode == "central":
            costs = plant.apply_perturbed(
                params, batch, _probe(n, (1.0, -1.0)), step=n, tags=(0, 1))
            c_plus, c_minus = costs[0], costs[1]
            # same rounding barrier as the materialized probe_once
            c_tilde = jax.lax.optimization_barrier(0.5 * (c_plus - c_minus))
            return c_tilde, state.c0, 0.5 * (c_plus + c_minus)
        need_c0 = jnp.logical_or(n % cfg.tau_x == 0, n % cfg.tau_theta == 0)
        c0 = jax.lax.cond(
            need_c0,
            lambda: plant.read_cost(params, batch, step=n,
                                    tag=0).astype(jnp.float32),
            lambda: state.c0,
        )
        c_pert = plant.apply_perturbed(
            params, batch, _probe(n, (1.0,)), step=n, tags=(1,))[0]
        return c_pert - c0, c0, c0

    def _fused_leaf_updates(params, lseeds_of, coefs, alpha, small_update):
        """Shared leaf walk: ndim≥2 leaves through mgd_update_window,
        small leaves through ``small_update(leaf, lid)``."""
        from repro.kernels import ops as kops
        leaves, treedef = jax.tree_util.tree_flatten(params)
        out = []
        for (lid, _, _), leaf in zip(leaf_meta(params), leaves):
            if leaf.ndim >= 2:
                out.append(kops.mgd_update_window(
                    leaf, lseeds_of(lid), coefs, alpha=alpha,
                    dtheta=cfg.dtheta, impl=cfg.kernel_impl))
            else:
                out.append(small_update(leaf, lid))
        return jax.tree_util.tree_unflatten(treedef, out)

    def fused_update_tau1(params, n, c_tilde):
        """θ ← θ − η·C̃·θ̃/Δθ² with θ̃ regenerated in-kernel (τ_θ = 1)."""
        seed = _probe_seed(cfg, 0)
        s = _pin(c_tilde * inv_d2)     # mirrors tree_scale's f32 scalar

        def small(leaf, lid):
            # sign-LAST form of leaf + (−η)·(θ̃·s): bit-identical (the ±1
            # sign commutes exactly through both roundings) and immune to
            # mul+add FMA contraction — see the sign_exact_update note in
            # the materializing step below and kernels/mgd_update.py.
            signs = pert.rademacher_leaf(
                leaf.shape, jnp.float32, lid, step=n, seed=seed,
                dtheta=1.0, tau_p=cfg.tau_p)
            t = _pin(jnp.float32(-cfg.eta)
                     * _pin(jnp.float32(cfg.dtheta) * s))
            return (leaf.astype(jnp.float32) + signs * t).astype(leaf.dtype)

        def lseeds_of(lid):
            return pert.leaf_seed(seed, n // jnp.int32(cfg.tau_p), lid)[None]

        return _fused_leaf_updates(params, lseeds_of, s[None], -cfg.eta,
                                   small)

    def fused_replay_update(params, state, replay_c):
        """Scalar-replay window update through the fused kernel: the J sign
        regenerations happen against the already-resident W tile, so HBM
        traffic is read-W + write-W regardless of τ_θ."""
        n = state.step
        seed = _probe_seed(cfg, 0)
        window = replay_c.shape[0]
        j = jnp.arange(cfg.tau_theta, dtype=jnp.int32)
        steps = n - (cfg.tau_theta - 1) - cfg.staleness + j       # [J]
        coefs = _pin(jnp.float32(-cfg.eta * inv_d2)
                     * replay_c[steps % window])

        def small(leaf, lid):
            def body(jj, lf):
                theta = pert.rademacher_leaf(
                    lf.shape, lf.dtype, lid, step=steps[jj], seed=seed,
                    dtheta=cfg.dtheta, tau_p=cfg.tau_p)
                return (lf.astype(jnp.float32)
                        + coefs[jj] * theta.astype(jnp.float32)
                        ).astype(lf.dtype)
            return jax.lax.fori_loop(0, cfg.tau_theta, body, leaf)

        def lseeds_of(lid):
            return pert.leaf_seed(seed, steps // jnp.int32(cfg.tau_p), lid)

        return _fused_leaf_updates(params, lseeds_of, coefs, 1.0, small)

    def step_fn_fused(params, state: MGDState, batch):
        n = state.step
        c_tilde, c0, cost_metric = probe_once_fused(params, state, batch)
        do_update = (n + 1) % cfg.tau_theta == 0
        metrics = {"cost": cost_metric, "c_tilde": c_tilde,
                   "updated": do_update.astype(jnp.float32)}
        if cfg.replay:
            window = state.replay_c.shape[0]
            replay_c = state.replay_c.at[n % window].set(c_tilde)
            new_params = jax.lax.cond(
                do_update,
                lambda: plant.write_params(
                    fused_replay_update(params, state, replay_c),
                    step=n, prev=params),
                lambda: params,
            )
            new_state = state._replace(
                step=n + 1, c0=c0, replay_c=replay_c, metric_cost=cost_metric
            )
            return new_params, new_state, metrics
        # tau_theta == 1 (enforced in __post_init__): update every step
        new_params = plant.write_params(
            fused_update_tau1(params, n, c_tilde), step=n, prev=params)
        new_state = MGDState(
            step=n + 1, c0=c0, g=None, replay_c=None, m=None,
            metric_cost=cost_metric,
        )
        return new_params, new_state, metrics

    # ----- replay-mode update: regenerate θ̃ for the τ_θ window ------------
    def replay_update(params, state, replay_c):
        """θ −= η Σ_j C̃_j · θ̃_j / Δθ²  over the last τ_θ steps, with the
        perturbations regenerated (never stored).  With staleness d>0 the
        newest d scalars are excluded — they arrive next window."""
        n = state.step

        window = replay_c.shape[0]

        def body(j, p):
            # j-th step of the window, oldest first; the buffer slot for
            # step s is s % window (ring buffer).
            s = n - (cfg.tau_theta - 1) - cfg.staleness + j
            theta_j = perturbation(params, s)
            coef = replay_c[s % window]
            return tree_axpy(_pin(-cfg.eta * inv_d2 * coef), theta_j, p)

        return jax.lax.fori_loop(0, cfg.tau_theta, body, params)

    if cfg.fused:
        return step_fn_fused

    # τ_θ = 1 rademacher updates take a contraction-immune form: the CPU
    # backend may contract θ̃·s into the following add (one rounding instead
    # of two) once the η = 1 multiply folds to a negation — and HLO
    # optimization barriers are stripped before fusion, so no pin survives
    # to block it.  θ − η·(C̃·θ̃/Δθ²) is rewritten as θ + sgn·t with the
    # scalar t = (−η)·(Δθ·s) pinned at each rounding: sgn·t is an EXACT
    # multiply (sgn = ±1), so FMA contraction cannot change the result,
    # and the value is bit-identical (f32) to the written two-step
    # association — and to the fused kernel's w + α·((Δθ·sgn)·s).
    sign_exact_update = (cfg.tau_theta == 1 and cfg.probes == 1
                         and not cfg.momentum and not cfg.replay
                         and cfg.ptype == "rademacher")

    def step_fn(params, state: MGDState, batch):
        n = state.step
        if sign_exact_update and all(
                leaf.dtype == jnp.float32
                for leaf in jax.tree_util.tree_leaves(params)):
            c_tilde, _, c0, cost_metric = probe_once(params, state, batch, 0)
            s = _pin(c_tilde * inv_d2)
            t = _pin(jnp.float32(-cfg.eta)
                     * _pin(jnp.float32(cfg.dtheta) * s))
            signs = pert.generate_signs_only(
                params, step=n, seed=_probe_seed(cfg, 0), tau_p=cfg.tau_p)
            new_params = plant.write_params(
                jax.tree_util.tree_map(lambda p, g_: p + g_ * t,
                                       params, signs),
                step=n, prev=params)
            new_state = MGDState(
                step=n + 1, c0=c0, g=None, replay_c=None, m=None,
                metric_cost=cost_metric,
            )
            metrics = {"cost": cost_metric, "c_tilde": c_tilde,
                       "updated": jnp.float32(1.0)}
            return new_params, new_state, metrics
        e, c_tilde, c0, cost_metric = accumulate(params, state, batch)
        do_update = (n + 1) % cfg.tau_theta == 0

        if cfg.replay:
            window = state.replay_c.shape[0]
            replay_c = state.replay_c.at[n % window].set(c_tilde)
            new_params = jax.lax.cond(
                do_update,
                lambda: plant.write_params(
                    replay_update(params, state, replay_c),
                    step=n, prev=params),
                lambda: params,
            )
            new_state = state._replace(
                step=n + 1, c0=c0, replay_c=replay_c, metric_cost=cost_metric
            )
            metrics = {"cost": cost_metric, "c_tilde": c_tilde,
                       "updated": do_update.astype(jnp.float32)}
            return new_params, new_state, metrics

        if cfg.tau_theta == 1:
            # no accumulator: θ ← θ − η·e directly (update every step);
            # at deepseek scale an f32 G buffer is 10.5 GiB/device.
            new_params, new_m = apply_update(params, state, e)
            new_g = None
        else:
            g = tree_add(state.g, e)
            updated_params, new_m = apply_update(params, state, g)
            new_params = tree_select(do_update, updated_params, params)
            new_g = tree_select(do_update, tree_zeros_like(g), g)
        if cfg.momentum:
            new_m = tree_select(do_update, new_m, state.m)
        else:
            new_m = None
        new_state = MGDState(
            step=n + 1, c0=c0, g=new_g, replay_c=None, m=new_m,
            metric_cost=cost_metric,
        )
        metrics = {"cost": cost_metric, "c_tilde": c_tilde,
                   "updated": do_update.astype(jnp.float32)}
        return new_params, new_state, metrics

    return step_fn


# ---------------------------------------------------------------------------
# Legacy entry point (deprecated shim over the registry)
# ---------------------------------------------------------------------------


def make_mgd_step(*args, **kwargs):
    """RETIRED (PR 3 deprecation shim, removed PR 10)."""
    raise RuntimeError(
        "make_mgd_step was retired; build the algorithm through the "
        "registry: repro.driver('discrete', cfg, loss_fn, ...).step "
        "(bit-identical f32 trajectory, plus the standardized "
        "grad_norm_proxy aux key)")


# ---------------------------------------------------------------------------
# Multi-step driver (τ_x semantics + lax.scan over iterations)
# ---------------------------------------------------------------------------


def make_mgd_epoch(
    loss_fn, cfg: MGDConfig, steps_per_call: int,
    sample_fn: Callable[[jnp.ndarray], Any],
    *,
    probe_fn: Optional[Callable] = None,
    plant=None,
):
    """Scan ``steps_per_call`` MGD iterations inside one jitted call.

    ``sample_fn(sample_index) -> batch`` implements τ_x: iteration n uses
    sample index n // τ_x.  Used by the training loop and benchmarks to
    amortize dispatch overhead (one device program per chunk of steps).
    Note external plants (ordered host callbacks) cannot live under
    ``lax.scan``'s cond-free requirement on all jax versions — drive them
    step-by-step via the driver's ``step`` instead.  The generic
    equivalent for any driver is ``repro.api.make_epoch``.
    """
    step_fn = build_mgd_step(loss_fn, cfg, probe_fn=probe_fn, plant=plant)

    def body(carry, _):
        params, state = carry
        batch = sample_fn(state.step // cfg.tau_x)
        params, state, metrics = step_fn(params, state, batch)
        return (params, state), metrics

    @jax.jit
    def run(params, state):
        (params, state), metrics = jax.lax.scan(
            body, (params, state), None, length=steps_per_call
        )
        return params, state, metrics

    return run
