"""Hardware non-ideality models (paper §3.5, Figs 8–10).

Three imperfection families the paper studies:

1. cost noise σ_C       — gaussian noise on every cost read (MGDConfig.cost_noise)
2. update noise σ_θ     — gaussian noise on every parameter write
                          (MGDConfig.update_noise)
3. activation defects σ_a — per-neuron static offsets/scalings of the
   sigmoid: f_k(a) = α_k·(1 − e^{−β_k(a−a_k)})^{-1} + b_k with
   α_k, β_k ~ N(1, σ_a) and a_k, b_k ~ N(0, σ_a).  This module provides the
   defect sampling + defective activation used by the paper-scale models.

All noise is generated from counter-based keys so a checkpoint restart
replays the identical hardware — the defect pattern is part of the "device",
not of the training state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ActivationDefects(NamedTuple):
    """Static per-neuron logistic-function defects (one entry per neuron)."""

    alpha: jnp.ndarray  # output scaling,  N(1, σ_a)
    beta: jnp.ndarray   # input slope,     N(1, σ_a)
    a0: jnp.ndarray     # input offset,    N(0, σ_a)
    b0: jnp.ndarray     # output offset,   N(0, σ_a)


def sample_defects(seed: int, n_neurons: int, sigma_a: float) -> ActivationDefects:
    key = jax.random.PRNGKey(seed)
    ka, kb, kc, kd = jax.random.split(key, 4)
    shape = (n_neurons,)
    return ActivationDefects(
        alpha=1.0 + sigma_a * jax.random.normal(ka, shape),
        beta=1.0 + sigma_a * jax.random.normal(kb, shape),
        a0=sigma_a * jax.random.normal(kc, shape),
        b0=sigma_a * jax.random.normal(kd, shape),
    )


def ideal_defects(n_neurons: int) -> ActivationDefects:
    one = jnp.ones((n_neurons,))
    zero = jnp.zeros((n_neurons,))
    return ActivationDefects(one, one, zero, zero)


def defective_sigmoid(a: jnp.ndarray, d: ActivationDefects) -> jnp.ndarray:
    """General logistic f_k(a) = α_k·σ(β_k·(a − a_k)) + b_k (paper §3.5).

    ``a`` has neurons on the last axis; defects broadcast over leading axes.
    σ_a = 0 (ideal_defects) reduces exactly to jax.nn.sigmoid.
    """
    return d.alpha * jax.nn.sigmoid(d.beta * (a - d.a0)) + d.b0
