"""Cost functions.

The paper uses MSE throughout (no softmax, §3.6).  LM-scale configs use the
standard softmax cross-entropy.  All costs reduce to a single scalar — in MGD
that scalar *is* the entire feedback channel, so under pjit the only
gradient-path collective is the psum XLA inserts for this reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mse(y, y_hat):
    """Mean squared error over all elements (paper's cost)."""
    d = y.astype(jnp.float32) - y_hat.astype(jnp.float32)
    return jnp.mean(d * d)


def mae(y, y_hat):
    """Mean absolute error.  With dyadic inputs every intermediate is
    exact in f32 (|·| and power-of-two means don't round), which makes
    this the cost of choice for bit-equality calibration against
    ``hardware.devices.LinearLaneChip``."""
    return jnp.mean(jnp.abs(y.astype(jnp.float32) - y_hat.astype(jnp.float32)))


def softmax_xent(logits, labels, ignore_id=-1):
    """Token-mean softmax cross entropy; labels == ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


COSTS = {"mse": mse, "xent": softmax_xent}
