"""Forward-gradient oracle — the Δθ→0, T→∞ limit of MGD.

On a differentiable substrate, the MGD homodyne estimate for one Rademacher
probe s is  G = C̃·s/Δθ ≈ (∇C·s)·s + O(Δθ) — exactly the *forward gradient*
of Baydin et al. (paper ref [26]).  ``jax.jvp`` computes ∇C·s without any
finite-difference bias, so this module provides:

* ``forward_gradient``    — (∇C·s)·s via one jvp (2× forward cost, like MGD)
* ``true_gradient``       — jax.grad reference (the backprop the paper
  compares against)
* ``gradient_angle``      — the paper's Fig. 5 metric between pytrees

Used (a) as a validation oracle in tests — MGD's G must converge to jvp's
estimate as Δθ→0 and to jax.grad as T→∞ — and (b) as a beyond-paper
fast mode for differentiable models.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import perturbations as pert
from .utils import tree_dot, tree_norm, tree_scale

Pytree = Any


def forward_gradient(loss_fn, params, batch, *, step, seed, total=None):
    """Single-probe forward gradient (∇C·s)·s with a Rademacher tangent."""
    signs = pert.generate_signs_only(params, step=step, seed=seed)
    tangent = jax.tree_util.tree_map(
        lambda s, p: s.astype(p.dtype), signs, params
    )
    _, jvp_val = jax.jvp(lambda p: loss_fn(p, batch), (params,), (tangent,))
    return tree_scale(signs, jvp_val)


def true_gradient(loss_fn, params, batch):
    return jax.grad(lambda p: loss_fn(p, batch))(params)


def gradient_angle(g_approx: Pytree, g_true: Pytree) -> jnp.ndarray:
    """Angle (radians) between two gradient pytrees — paper Fig. 5 metric."""
    num = tree_dot(g_approx, g_true)
    den = tree_norm(g_approx) * tree_norm(g_true) + 1e-30
    return jnp.arccos(jnp.clip(num / den, -1.0, 1.0))
