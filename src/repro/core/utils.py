"""Small pytree utilities shared across the MGD core.

All helpers are shape-only or elementwise so they trace cleanly under jit with
``ShapeDtypeStruct`` leaves (required by the multi-pod dry-run).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree (python int, static)."""
    return sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(tree))


def leaf_meta(tree):
    """Per-leaf (leaf_id, global_offset, size) in flattened order.

    The ordering is the canonical ``tree_flatten`` order, which is stable for a
    fixed pytree structure — this is what makes perturbations reproducible
    across restarts and across hosts (every host sees the same structure).
    Returns a list aligned with ``tree_leaves(tree)``.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    metas = []
    offset = 0
    for i, leaf in enumerate(leaves):
        n = math.prod(leaf.shape)
        metas.append((i, offset, n))
        offset += n
    return metas


def leaf_id_tree(tree):
    """Same-structure tree whose leaves are their python-int leaf ids
    (tree_flatten order) — the ids the perturbation generator hashes.
    Structure is static under jit, so the ids are static too."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return jax.tree_util.tree_unflatten(treedef, list(range(len(leaves))))


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), tree)


def tree_axpy(a, x, y):
    """y + a * x, computed in f32 then cast back to y.dtype (bf16-safe)."""
    return jax.tree_util.tree_map(
        lambda xi, yi: (yi.astype(jnp.float32) + a * xi.astype(jnp.float32)).astype(yi.dtype),
        x,
        y,
    )


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def tree_select(pred, a, b):
    """Elementwise ``where(pred, a, b)`` over two pytrees (pred is scalar bool)."""
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_dot(a, b):
    """Sum of elementwise products across the whole pytree, in f32."""
    parts = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, parts, jnp.float32(0.0))


def tree_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)
