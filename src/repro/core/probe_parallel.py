"""Pod-local probe parallelism — MGD's native way to use a multi-pod fleet.

Plain data parallelism under MGD would psum the per-pod costs into one
global C̃ and pair it with one global perturbation.  Instead, each pod k
draws its OWN perturbation θ̃_k and evaluates its OWN data shard, giving k
independent (C̃_k, θ̃_k) probe pairs per step:

    update = −η · (1/k) Σ_k C̃_k · θ̃_k / Δθ²

* Unbiased: E[C̃_k·θ̃_k/Δθ²] = ∇L_k, so the average estimates ∇(mean_k L_k)
  — the same target as synchronous DP.
* k× probe-variance reduction at ZERO extra forward FLOPs versus DP (each
  pod was computing its shard anyway).  This axis exists only because MGD
  is forward-only; backprop has no analogue.
* Cross-pod traffic: ONE all-gather of k f32 scalars per step.  Every pod
  then regenerates all k sign-trees locally (counter hash, elementwise,
  ≪ matmul FLOPs) and applies the identical update, keeping parameters
  bit-replicated across pods with no parameter collective at all.

Implemented as one shard_map over the whole mesh.  The probe axis is
always manual (each slice IS a distinct probe); the other axes join the
manual set exactly when the caller's specs mention them:

* ``data_axis=`` shards each pod's batch further over a data axis and
  pmean-combines the per-device costs into the pod's C̃ — plain data
  parallelism *inside* each probe.
* ``param_specs=`` places parameters via ``distributed/sharding.py``
  logical rules (or an explicit spec pytree), so each device holds only
  its model/fsdp shard and the Pallas kernels run on per-device shards.
  A sharded ``loss_fn`` must be shard-aware (psum its own collectives) —
  shard_map runs it manual over every axis the specs mention.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import perturbations as pert
from .mgd import MGDConfig
from .utils import leaf_meta, tree_axpy


def pod_seed(seed, k):
    """Probe seed of pod/chip ``k``: distinct, deterministic, uint32.
    ONE definition — the mesh and external drivers' bit-equality (a farm
    of ideal chips walks a k-pod mesh's trajectory) hangs on both using
    the same derivation.  ``k`` may be traced (lax.axis_index /
    fori_loop counter)."""
    return (jnp.uint32(seed)
            + jnp.asarray(k, jnp.uint32) * jnp.uint32(0x9E3779B9))


def _is_spec_rules(specs) -> bool:
    """True when ``specs`` is an ordered (regex, logical-names) rules list
    (the ``distributed.sharding.param_specs`` input) rather than a spec
    pytree."""
    if not isinstance(specs, (list, tuple)) or not specs:
        return False
    return all(
        isinstance(r, (list, tuple)) and len(r) == 2 and isinstance(r[0], str)
        and not isinstance(r, P) for r in specs)


def _spec_axes(spec_tree) -> set:
    """Every mesh axis a spec pytree mentions."""
    axes: set = set()
    leaves = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    for spec in leaves:
        if not isinstance(spec, P):
            continue
        for entry in spec:
            if entry is None:
                continue
            if isinstance(entry, (tuple, list)):
                axes.update(entry)
            else:
                axes.add(entry)
    return axes


def build_probe_parallel_step(
    loss_fn: Callable,
    cfg: MGDConfig,
    mesh,
    *,
    probe_axis: str = "pod",
    data_axis=None,
    param_specs=None,
    batch_specs=None,
    plant=None,
    probe_fn=None,
):
    """Build step_fn(params, step, batch) → (params, metrics) — the
    registry's probe-parallel builder (``repro.driver("probe_parallel",
    cfg, loss_fn, mesh=mesh)`` wraps this behind the uniform contract).

    central-difference, τ_θ = 1 (immediate update) — the at-scale serving
    configuration.  ``mesh`` may be multi-axis: the ``probe_axis`` slices
    are the k probes; ``data_axis=`` additionally shards each pod's batch
    and pmean-combines the per-device costs into the pod's C̃;
    ``param_specs=`` (a PartitionSpec pytree, or an ordered
    (regex, logical-names) rules list resolved through
    ``distributed.sharding.param_specs``) places parameter shards so the
    kernels run per-device — the loss_fn must then be shard-aware.
    ``batch_specs`` overrides the batch placement (default: leading dim
    over ``probe_axis`` [× ``data_axis``]).  On a 1-D pod mesh with
    default specs the trajectory is bit-identical (f32) to the historical
    single-axis builder.

    With ``cfg.fused=True`` the probe evaluates through
    ``probe_fn(params, batch, probe)`` (the Pallas perturbed-matmul path —
    θ̃ never exists in HBM) and the update regenerates all k sign-trees
    inside ``kernels.mgd_update_window`` per ndim≥2 leaf: one read-W +
    write-W regardless of k.  Bit-identical (f32) to the materializing
    pod loop.

    Cost reads and the parameter write go through a ``hardware.Plant``
    (implicit ideal/noisy device when ``plant=None``), so every pod may be
    its own imperfect chip: readout-noise tags are keyed per (step, pod),
    and the post-all-gather write lands through the plant once per step.
    Pure-JAX plants only — the probe loop runs inside ``shard_map``.
    """
    if cfg.mode != "central":
        raise ValueError(
            f"probe-parallel uses central differences (its per-pod probe "
            f"shares no C₀ memory); got mode={cfg.mode!r} — set "
            f'mode="central"')
    if probe_axis not in mesh.axis_names:
        raise ValueError(
            f"mesh axes {tuple(mesh.axis_names)} have no probe axis "
            f"{probe_axis!r} — name one axis of the mesh after the probe "
            f"dimension (or pass probe_axis=)")
    if data_axis is not None:
        if data_axis == probe_axis:
            raise ValueError(
                f"data_axis={data_axis!r} IS the probe axis — each pod "
                f"already gets its own batch shard along it; a data axis "
                f"shards *within* a pod")
        if data_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh axes {tuple(mesh.axis_names)} have no data axis "
                f"{data_axis!r}")
    from repro.core.mgd import _resolve_plant
    plant = _resolve_plant(loss_fn, cfg, probe_fn=probe_fn, plant=plant)
    if plant.meta.external:
        raise ValueError("probe-parallel drives pure-JAX plants; an "
                         "ExternalPlant cannot run inside shard_map — "
                         "use repro.driver('probe_parallel_external', cfg, "
                         "plant=ChipFarm(...)) for k chips behind a host "
                         "boundary")
    if cfg.fused:
        if not plant.supports_fused:
            raise ValueError("cfg.fused=True needs a probe_fn (the model's "
                             "perturbed-apply interface) on the plant")
        if cfg.tau_theta != 1 or cfg.replay:
            raise ValueError("fused probe-parallel updates every step "
                             "(tau_theta=1, no replay)")
    n_pods = mesh.shape[probe_axis]
    inv_d2 = 1.0 / (cfg.dtheta * cfg.dtheta)
    # same rounding pin as core.mgd: keep the written float association in
    # every program so fused and materializing paths agree bitwise
    _pin = jax.lax.optimization_barrier

    param_rules = None
    if param_specs is not None and _is_spec_rules(param_specs):
        param_rules = list(param_specs)
        param_specs = None
    if batch_specs is None:
        batch_specs = (P(probe_axis) if data_axis is None
                       else P((probe_axis, data_axis)))

    def fused_pod_update(params, step, all_c):
        """All k pod windows through the fused kernel: ndim≥2 leaves pay
        read-W + write-W once regardless of k (signs regenerate against
        the resident tile); O(d) leaves materialize in a fori_loop that
        mirrors the pod loop's float association exactly."""
        from repro.kernels import ops as kops
        seeds = pod_seed(cfg.seed, jnp.arange(n_pods))            # [k]
        coefs = _pin(jnp.float32(-cfg.eta * inv_d2) * all_c
                     / jnp.float32(n_pods))

        def small(leaf, lid):
            def body(k, lf):
                theta = pert.rademacher_leaf(
                    lf.shape, lf.dtype, lid, step=step,
                    seed=pod_seed(cfg.seed, k), dtheta=cfg.dtheta,
                    tau_p=cfg.tau_p)
                return (lf.astype(jnp.float32)
                        + coefs[k] * theta.astype(jnp.float32)
                        ).astype(lf.dtype)
            return jax.lax.fori_loop(0, n_pods, body, leaf)

        leaves, treedef = jax.tree_util.tree_flatten(params)
        out = []
        for (lid, _, _), leaf in zip(leaf_meta(params), leaves):
            if leaf.ndim >= 2:
                lseeds = pert.leaf_seed(
                    seeds, jnp.asarray(step, jnp.int32) // jnp.int32(cfg.tau_p),
                    lid)
                out.append(kops.mgd_update_window(
                    leaf, lseeds, coefs, alpha=1.0, dtheta=cfg.dtheta,
                    impl=cfg.kernel_impl))
            else:
                out.append(small(leaf, lid))
        return jax.tree_util.tree_unflatten(treedef, out)

    def run(params, step, batch):
        pod = jax.lax.axis_index(probe_axis)
        if cfg.fused:
            probe = pert.Probe(
                step, pod_seed(cfg.seed, pod),
                pert.ProbeCtx(signs=(1.0, -1.0), dtheta=cfg.dtheta,
                              tau_p=cfg.tau_p, impl=cfg.kernel_impl))
            costs = plant.apply_perturbed(
                params, batch, probe, step=step, tags=(2 * pod, 2 * pod + 1))
            c_plus, c_minus = costs[0], costs[1]
        else:
            theta = pert.generate(
                params, ptype=cfg.ptype, step=step,
                seed=pod_seed(cfg.seed, pod),
                dtheta=cfg.dtheta, tau_p=cfg.tau_p)
            c_plus, c_minus = plant.read_cost_pair(
                params, theta, batch, step=step, tag=2 * pod)
        if data_axis is not None:
            # plain DP inside the pod: the pod's C is the mean over its
            # data-axis devices' shard costs (one scalar psum per read)
            c_plus = jax.lax.pmean(c_plus, data_axis)
            c_minus = jax.lax.pmean(c_minus, data_axis)
        c_local = (0.5 * (c_plus - c_minus)).astype(jnp.float32)
        all_c = jax.lax.all_gather(c_local, probe_axis)        # [k] scalars

        if cfg.fused:
            updated = fused_pod_update(params, step, all_c)
        else:
            def body(k, p):
                signs = pert.generate(
                    p, ptype=cfg.ptype, step=step, seed=pod_seed(cfg.seed, k),
                    dtheta=cfg.dtheta, tau_p=cfg.tau_p)
                # pinned to the written association — the fused kernel path
                # computes the identical coefficient vector, and XLA must
                # not re-fold the constants differently in either program
                coef = _pin(jnp.float32(-cfg.eta * inv_d2) * all_c[k]
                            / jnp.float32(n_pods))
                return tree_axpy(coef, signs, p)

            updated = jax.lax.fori_loop(0, n_pods, body, params)
        new_params = plant.write_params(updated, step=step, prev=params)
        cost = 0.5 * (c_plus + c_minus)
        return new_params, {"cost": cost.astype(jnp.float32),
                            "c_tilde_mean": jnp.mean(jnp.abs(all_c))}

    from repro.distributed.compat import shard_map

    def _wrap(pspec_tree):
        manual = {probe_axis} | _spec_axes(pspec_tree) | _spec_axes(batch_specs)
        if data_axis is not None:
            manual.add(data_axis)
        shard = shard_map(
            run, mesh=mesh,
            in_specs=(pspec_tree, P(), batch_specs),
            out_specs=(pspec_tree, P()),
            manual_axes=manual,
        )

        @jax.jit
        def stepper(params, step, batch):
            return shard(params, jnp.asarray(step, jnp.int32), batch)

        return stepper

    if param_rules is None:
        fixed = _wrap(P() if param_specs is None else param_specs)

        def step_fn(params, step, batch):
            return fixed(params, step, batch)

        return step_fn

    # rules need the params *shapes* — resolve lazily on first call and
    # cache per (structure, shapes); jit inside recompiles on the same key
    built = {}

    def step_fn(params, step, batch):
        from repro.distributed.sharding import param_specs as resolve_specs
        key = (jax.tree_util.tree_structure(params),
               tuple(tuple(leaf.shape)
                     for leaf in jax.tree_util.tree_leaves(params)))
        try:
            stepper = built[key]
        except KeyError:
            stepper = built[key] = _wrap(
                resolve_specs(params, param_rules, mesh))
        return stepper(params, step, batch)

    return step_fn


def _mad_chip_mask(costs, valid, threshold):
    """Robust outlier rejection over the 2k gathered cost scalars:
    median-absolute-deviation gate, computed over VALID chips' readouts
    only (invalid entries are NaN-ed out of the medians).  A chip is
    kept when BOTH of its pair scalars sit within ``threshold`` robust
    standard deviations of the median — a spiked-but-finite C₊ raises no
    exception at the host boundary; only the statistics can reject it.
    The MAD floor guards the degenerate all-equal case (MAD = 0)."""
    flat = costs.reshape(-1)
    vmask = jnp.repeat(valid, 2)
    x = jnp.where(vmask, flat, jnp.nan)
    med = jnp.nanmedian(x)
    mad = jnp.nanmedian(jnp.abs(x - med))
    scale = jnp.maximum(jnp.float32(1.4826) * mad,
                        1e-6 * jnp.maximum(jnp.abs(med), 1.0))
    ok = jnp.abs(flat - med) <= threshold * scale
    return jnp.logical_and(valid, jnp.all(ok.reshape(-1, 2), axis=1))


def _trimmed_chip_mask(c_tilde, valid, trim_frac):
    """Symmetric trimmed mean as a mask: drop the ⌊trim_frac·k_valid⌋
    largest and smallest C̃ values among the valid chips.  Rank-based
    (argsort + inverse permutation), so it stays static-shape under jit;
    invalid chips sort to the top (+inf key) and are excluded by the
    ``ranks < n_valid − t`` cut as well as the final AND."""
    k = c_tilde.shape[0]
    n_valid = jnp.sum(valid.astype(jnp.int32))
    t = jnp.floor(trim_frac * n_valid.astype(jnp.float32)).astype(jnp.int32)
    key = jnp.where(valid, c_tilde, jnp.inf)
    order = jnp.argsort(key)
    ranks = jnp.zeros(k, jnp.int32).at[order].set(
        jnp.arange(k, dtype=jnp.int32))
    keep = jnp.logical_and(ranks >= t, ranks < n_valid - t)
    return jnp.logical_and(valid, keep)


def build_probe_parallel_external_step(
    cfg: MGDConfig,
    farm,
):
    """Build step_fn(params, step, batch) → (params, metrics) — the
    registry's ``probe_parallel_external`` builder: the SAME averaged
    update as ``build_probe_parallel_step``,

        θ ← θ − η · (1/k) Σ_k C̃_k · θ̃_k / Δθ²,

    but the k central-difference probes fan out to k EXTERNAL chips over
    the host boundary (``hardware.farm.ChipFarm``: one ordered
    ``io_callback`` per step gathers all 2k scalars, the chips evaluate
    concurrently on the farm's execution backend — per-chip runner
    threads, worker processes, or a cluster transport; see
    ``hardware/backend/``) instead of k shard_map mesh slices — the
    paper §6 "farm of imperfect chips" picture.  This builder is
    backend-agnostic BY CONSTRUCTION: it sees only
    ``farm.read_cost_pairs`` / ``farm.write_params``, and device noise
    is counter-keyed, so serial, thread and process farms (pipelined or
    not) walk the bit-identical trajectory.  All k sign-trees
    are then regenerated locally (counter hash) and the update applied
    with the identical float association as the mesh driver, so a farm
    of k ideal chips and a k-pod mesh walk the same trajectory.

    Chip k's probe seed is ``pod_seed(k)`` — the mesh driver's formula —
    and its readout tags are (2k, 2k+1), so counter-keyed device noise
    distinguishes every read and restarts replay deterministically.

    **Fault masking / η-rescaling** (armed when the farm carries a
    ``FaultPolicy``; the policy is read ONCE at build time, so the clean
    path compiles to the historical minimal graph): the farm's
    ``valid[k]`` mask — further tightened by a traced finiteness check
    and the policy's robust aggregation mode (``"mad"`` /
    ``"trimmed"``) — zeroes rejected chips' C̃_k while the per-chip
    coefficient ``−η/(k·Δθ²)`` stays UNCHANGED.  Because η is tuned ∝ k,
    dropping a chip's term at fixed η/k IS the "rescale η by the live
    chip count" rule: the surviving chips apply exactly the
    (η·k_live/k)-scaled masked average, degrading the step size
    gracefully instead of corrupting the direction.  With every chip
    valid, ``where(True, C̃, 0) ≡ C̃`` bitwise — the fault-tolerant
    trajectory is bit-identical to the historical one.  Aux gains
    ``n_valid`` (chips that answered with finite costs) and ``n_used``
    (chips surviving robust aggregation).
    """
    from repro.hardware.farm import ChipFarm
    if not isinstance(farm, ChipFarm):
        raise TypeError(
            f"probe_parallel_external needs a hardware.farm.ChipFarm "
            f"(k external chips behind one host boundary); got "
            f"{type(farm).__name__}")
    if cfg.mode != "central":
        raise ValueError(
            f"probe-parallel uses central differences (its per-chip probe "
            f"shares no C₀ memory); got mode={cfg.mode!r} — set "
            f'mode="central"')
    n_chips = farm.n_chips
    inv_d2 = 1.0 / (cfg.dtheta * cfg.dtheta)
    _pin = jax.lax.optimization_barrier
    # static at build time: a frozen FaultPolicy (or None) — the traced
    # masking/aggregation branch is selected here, not per step
    policy = getattr(farm, "policy", None)

    @jax.jit
    def step_fn(params, step, batch):
        step = jnp.asarray(step, jnp.int32)
        thetas = [pert.generate(
            params, ptype=cfg.ptype, step=step, seed=pod_seed(cfg.seed, k),
            dtheta=cfg.dtheta, tau_p=cfg.tau_p) for k in range(n_chips)]
        costs, valid = farm.read_cost_pairs(params, thetas, batch,
                                            step=step)    # [k, 2], [k]
        c_raw = (0.5 * (costs[:, 0] - costs[:, 1])).astype(jnp.float32)
        if policy is None:
            all_c = c_raw
            aux_cost = jnp.mean(0.5 * (costs[:, 0] + costs[:, 1]))
            aux = {"cost": aux_cost.astype(jnp.float32),
                   "c_tilde_mean": jnp.mean(jnp.abs(all_c))}
        else:
            # belt-and-braces: the host masks non-finite readouts already,
            # but a masked chip's placeholder is NaN by construction —
            # never let it through the arithmetic
            valid = jnp.logical_and(valid,
                                    jnp.all(jnp.isfinite(costs), axis=1))
            if policy.aggregate == "mad":
                used = _mad_chip_mask(costs, valid,
                                      jnp.float32(policy.mad_threshold))
            elif policy.aggregate == "trimmed":
                used = _trimmed_chip_mask(c_raw, valid,
                                          jnp.float32(policy.trim_frac))
            else:
                used = valid
            all_c = jnp.where(used, c_raw, jnp.float32(0.0))
            n_valid = jnp.sum(valid.astype(jnp.int32))
            n_used = jnp.sum(used.astype(jnp.int32))
            denom = jnp.maximum(n_used, 1).astype(jnp.float32)
            aux_cost = jnp.sum(jnp.where(
                used, 0.5 * (costs[:, 0] + costs[:, 1]), 0.0)) / denom
            aux = {"cost": aux_cost.astype(jnp.float32),
                   "c_tilde_mean": jnp.sum(jnp.abs(all_c)) / denom,
                   "n_valid": n_valid, "n_used": n_used}

        def body(k, p):
            signs = pert.generate(
                p, ptype=cfg.ptype, step=step, seed=pod_seed(cfg.seed, k),
                dtheta=cfg.dtheta, tau_p=cfg.tau_p)
            # same pinned association as the mesh driver — the k-chip farm
            # ≡ k-pod mesh bit-equality law includes the coefficient
            coef = _pin(jnp.float32(-cfg.eta * inv_d2) * all_c[k]
                        / jnp.float32(n_chips))
            return tree_axpy(coef, signs, p)

        new_params = farm.write_params(
            jax.lax.fori_loop(0, n_chips, body, params),
            step=step, prev=params)
        return new_params, aux

    return step_fn


def make_probe_parallel_step(*args, **kwargs):
    """RETIRED (PR 3 deprecation shim, removed PR 10)."""
    raise RuntimeError(
        "make_probe_parallel_step was retired; build the algorithm through "
        "the registry: repro.driver('probe_parallel', cfg, loss_fn, "
        "mesh=mesh).step — or build_probe_parallel_step for the raw "
        "(params, step, batch) contract")
