"""MGD core — the paper's contribution as a composable JAX module."""
from .mgd import MGDConfig, MGDState, mgd_init, make_mgd_step, make_mgd_epoch
from .analog import AnalogMGDConfig, AnalogMGDState, analog_init, make_analog_step
from .cost import mse, softmax_xent, COSTS
from . import perturbations, noise, forward_grad, utils

__all__ = [
    "MGDConfig", "MGDState", "mgd_init", "make_mgd_step", "make_mgd_epoch",
    "AnalogMGDConfig", "AnalogMGDState", "analog_init", "make_analog_step",
    "mse", "softmax_xent", "COSTS",
    "perturbations", "noise", "forward_grad", "utils",
]
