"""MGD core — the paper's contribution as a composable JAX module.

Algorithms are constructed through the driver registry
(``repro.driver("discrete" | "analog" | "probe_parallel", cfg, loss_fn)``);
the ``make_*_step`` names below are deprecated shims kept for migration.
"""
from .mgd import (MGDConfig, MGDState, build_mgd_step, make_mgd_epoch,
                  make_mgd_step, mgd_init)
from .analog import (AnalogMGDConfig, AnalogMGDState, analog_init,
                     build_analog_step, make_analog_step)
from .cost import mae, mse, softmax_xent, COSTS
from . import perturbations, noise, forward_grad, utils

__all__ = [
    "MGDConfig", "MGDState", "mgd_init", "build_mgd_step", "make_mgd_step",
    "make_mgd_epoch",
    "AnalogMGDConfig", "AnalogMGDState", "analog_init", "build_analog_step",
    "make_analog_step",
    "mae", "mse", "softmax_xent", "COSTS",
    "perturbations", "noise", "forward_grad", "utils",
]
