"""Continuous-time MGD — the paper's Algorithm 2 (analog hardware).

Construct through the registry: ``repro.driver("analog", cfg, loss_fn)``
(the retired ``make_analog_step`` shim now raises).

Discretized with timestep ``dt``:

    C̃(t)  ← α_hp · (C̃(t−dt) + C(t) − C(t−dt))        α_hp = τ_hp/(τ_hp+dt)
    e(t)  ← C̃(t)·θ̃(t)·dt/Δθ²
    G(t)  ← (dt/(τ_θ+dt)) · (e(t) + (τ_θ/dt)·G(t−dt))   (single-pole lowpass)
    θ     ← θ − η·G(t)                                   (continuous update)

Unlike Algorithm 1 there is no discrete parameter-update event and no C₀
memory — the highpass filter at the cost output plays the role of the
baseline subtraction, and the per-parameter lowpass plays the role of the
gradient integrator.  Default perturbations are sinusoidal (frequency
multiplexing); any family works.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import perturbations as pert
from .utils import tree_add, tree_axpy, tree_scale, tree_zeros_like

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AnalogMGDConfig:
    """Continuous MGD constants.

    Stability (paper §4.2): parameter drift per perturbation period must stay
    well below the perturbation amplitude, i.e. η·|G|·dt ≪ Δθ — "if η is too
    large, rapid changes in θ generate unwanted frequency components that mix
    with the perturbation input".  The defaults sit inside that regime for
    O(1)-curvature costs.
    """

    ptype: str = "sinusoidal"
    dtheta: float = 1e-2
    eta: float = 1e-3
    tau_theta: float = 10.0   # lowpass (gradient-integration) time constant
    tau_hp: float = 100.0     # highpass (baseline-removal) time constant
    tau_p: int = 1            # perturbation bandwidth control (1/Δf)
    dt: float = 1.0
    seed: int = 0
    # σ_C of the implicit device (builds a hardware.NoisyPlant); must stay
    # 0 when an explicit plant is passed to build_analog_step.
    cost_noise: float = 0.0


class AnalogMGDState(NamedTuple):
    t: jnp.ndarray          # int32 tick counter (time = t·dt)
    c_prev: jnp.ndarray     # C(t−dt)
    c_tilde: jnp.ndarray    # highpass output C̃(t−dt)
    g: Pytree               # lowpass gradient estimate
    primed: jnp.ndarray     # bool — first tick initializes c_prev only


def analog_init(params: Pytree, cfg: AnalogMGDConfig) -> AnalogMGDState:
    return AnalogMGDState(
        t=jnp.zeros((), jnp.int32),
        c_prev=jnp.zeros((), jnp.float32),
        c_tilde=jnp.zeros((), jnp.float32),
        g=tree_zeros_like(params, jnp.float32),
        primed=jnp.zeros((), jnp.bool_),
    )


def build_analog_step(
    loss_fn: Optional[Callable[[Pytree, Any], jnp.ndarray]],
    cfg: AnalogMGDConfig,
    total_params: Optional[int] = None,
    *,
    plant=None,
):
    """One dt tick of Algorithm 2 (the registry's analog builder).
    Returns step_fn(params, state, batch).

    Cost reads and the continuous parameter write go through a
    ``repro.hardware.Plant`` — the same device models (noisy, quantized,
    external) the discrete driver composes with.  ``plant=None`` builds
    the implicit in-process device from the config (``cost_noise`` → a
    ``NoisyPlant``), bit-identical (f32) to the ideal path at σ = 0.
    """
    from repro.core.mgd import _resolve_plant
    plant = _resolve_plant(loss_fn, cfg, plant=plant)

    inv_d2 = 1.0 / (cfg.dtheta * cfg.dtheta)
    a_hp = cfg.tau_hp / (cfg.tau_hp + cfg.dt)
    # G(t) = (dt·e(t)/dt + τ_θ·G)/(τ_θ+dt) — from Alg. 2 line 10
    a_g_new = cfg.dt / (cfg.tau_theta + cfg.dt)
    a_g_old = cfg.tau_theta / (cfg.tau_theta + cfg.dt)

    def step_fn(params, state: AnalogMGDState, batch):
        t = state.t
        theta_t = pert.generate(
            params, ptype=cfg.ptype, step=t, seed=cfg.seed,
            dtheta=cfg.dtheta, tau_p=cfg.tau_p, total=total_params,
        )
        c = plant.read_cost(tree_add(params, theta_t), batch,
                            step=t, tag=0).astype(jnp.float32)
        # first tick: prime the filter memory, no update
        c_prev = jnp.where(state.primed, state.c_prev, c)
        c_tilde = a_hp * (state.c_tilde + c - c_prev)
        # e(t) = C̃·θ̃·dt/Δθ²;  G ← a_new·(e/dt·… ) per Alg. 2:
        # G(t) = dt/(τθ+dt)·(e(t) + τθ/dt·G(t−dt)), e already carries dt
        e_coef = c_tilde * cfg.dt * inv_d2
        g = jax.tree_util.tree_map(
            lambda gi, pi: a_g_new * (e_coef / cfg.dt)
            * pi.astype(jnp.float32) + a_g_old * gi,
            state.g, theta_t,
        )
        # continuous update: every tick is a physical write event
        new_params = plant.write_params(
            tree_axpy(-cfg.eta, g, params), step=t, prev=params)
        new_state = AnalogMGDState(
            t=t + 1, c_prev=c, c_tilde=c_tilde, g=g,
            primed=jnp.ones((), jnp.bool_),
        )
        metrics = {"cost": c, "c_tilde": c_tilde}
        return new_params, new_state, metrics

    return step_fn


def make_analog_step(*args, **kwargs):
    """RETIRED (PR 3 deprecation shim, removed PR 10)."""
    raise RuntimeError(
        "make_analog_step was retired; build the algorithm through the "
        "registry: repro.driver('analog', cfg, loss_fn, ...).step "
        "(bit-identical f32 trajectory, plus the standardized "
        "grad_norm_proxy aux key)")
