"""Pure-jnp oracles for the Pallas kernels.

Bit-exactness contract: these use the *same* murmur3 counter hash via
``repro.core.perturbations``, with the same row-major linear indexing, so
the Pallas kernels (interpret or TPU) must match them exactly on the sign
pattern and to float tolerance on the accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.perturbations import rademacher_signs


def leaf_signs(lseed, shape):
    """±1 f32 signs for a whole leaf of ``shape`` (row-major indexing)."""
    n = 1
    for s in shape:
        n *= s
    idx = jax.lax.iota(jnp.uint32, n)
    return rademacher_signs(lseed, idx).reshape(shape)


def perturbed_matmul_ref(x, w, lseed, *, dtheta, sign=1.0, out_dtype=None):
    """y = x @ (W + sign·Δθ·signs) — materializes θ̃ (the thing the Pallas
    kernel avoids); used as the correctness oracle."""
    signs = leaf_signs(jnp.asarray(lseed, jnp.uint32), w.shape)
    wp = w.astype(jnp.float32) + (sign * dtheta) * signs
    y = x.astype(jnp.float32) @ wp
    return y.astype(out_dtype or x.dtype)


def perturbed_matmul_pair_ref(xp, xm, w, lseed, *, dtheta, out_dtype=None):
    """(xp @ (W+θ̃), xm @ (W−θ̃)) — two materialized matmuls sharing θ̃."""
    yp = perturbed_matmul_ref(xp, w, lseed, dtheta=dtheta, sign=1.0,
                              out_dtype=out_dtype)
    ym = perturbed_matmul_ref(xm, w, lseed, dtheta=dtheta, sign=-1.0,
                              out_dtype=out_dtype)
    return yp, ym


def mgd_update_ref(w, lseeds, coefs, *, eta, dtheta):
    """W − (η/Δθ)·Σ_j coefs[j]·signs_j — materializes every window sign."""
    acc = jnp.zeros(w.shape, jnp.float32)
    for j in range(lseeds.shape[0]):
        acc = acc + coefs[j] * leaf_signs(lseeds[j], w.shape)
    return (w.astype(jnp.float32) - (eta / dtheta) * acc).astype(w.dtype)


def mgd_update_window_ref(w, lseeds, coefs, *, alpha, dtheta):
    """Sequential-axpy window update, association identical to the kernel:
    W ← W + α·((Δθ·sign_j)·coefs[j]) for j = 0..J−1 in order."""
    w32 = w.astype(jnp.float32)
    for j in range(lseeds.shape[0]):
        sgn = leaf_signs(lseeds[j], w.shape)
        w32 = w32 + alpha * ((dtheta * sgn) * coefs[j])
    return w32.astype(w.dtype)
