"""jit'd dispatch wrappers for the MGD Pallas kernels.

``impl`` selection:
* "pallas"    — compiled Pallas (TPU target)
* "interpret" — Pallas interpret mode (CPU-correctness path; default when no
  TPU backend is present)
* "ref"       — pure-jnp oracle (always available, materializes θ̃)

The wrappers zero-pad non-tile-aligned shapes, so any (M, K, N) works.
Padding is sign-safe on every dim because the kernels index signs with the
*unpadded* row stride (``n_cols``): real elements keep their original
row-major linear indices; padded rows multiply zero x columns and padded
columns feed only outputs that are sliced away.  (The previous strategy —
largest divisor ≤ cap — silently degraded to 1-wide tiles for prime dims,
e.g. K=257 → bk=1, a catastrophic grid.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .mgd_update import mgd_update as _mgd_update_pallas
from .mgd_update import mgd_update_window as _mgd_update_window_pallas
from .perturbed_matmul import perturbed_matmul as _perturbed_matmul_pallas
from .perturbed_matmul import (
    perturbed_matmul_pair as _perturbed_matmul_pair_pallas)


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _tile(dim: int, cap: int) -> int:
    """Tile size for ``dim``: the whole dim when it fits under ``cap``,
    else the cap itself (the operand is zero-padded to a multiple)."""
    return dim if dim <= cap else cap


def _flatten_lead(x):
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    return x.reshape(m, x.shape[-1]), lead, m


def perturbed_matmul(x, w, lseed, *, dtheta, sign=1.0, impl=None,
                     bm=128, bn=128, bk=128, out_dtype=None):
    """y = x @ (W + sign·Δθ·rademacher(lseed)); θ̃ fused in-kernel.

    Leading batch dims of ``x`` are flattened into M.  Arbitrary shapes are
    zero-padded to tile multiples; sign indexing stays anchored to the
    unpadded W (see module docstring).
    """
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.perturbed_matmul_ref(
            x, w, lseed, dtheta=dtheta, sign=sign, out_dtype=out_dtype)

    x2, lead, m = _flatten_lead(x)
    kdim, n = w.shape
    bm_eff = min(bm, max(8, m))
    bk_eff = _tile(kdim, bk)
    bn_eff = _tile(n, bn)
    x2p = _pad_to(_pad_to(x2, bm_eff, 0), bk_eff, 1)
    wp = _pad_to(_pad_to(w, bk_eff, 0), bn_eff, 1)
    y = _perturbed_matmul_pallas(
        x2p, wp, lseed, dtheta=dtheta, sign=sign,
        bm=min(bm_eff, x2p.shape[0]), bn=bn_eff, bk=bk_eff,
        out_dtype=out_dtype or x.dtype, n_cols=n,
        interpret=(impl == "interpret"),
    )
    return y[:m, :n].reshape(*lead, n)


def perturbed_matmul_pair(xp, xm, w, lseed, *, dtheta, impl=None,
                          bm=128, bn=128, bk=128, out_dtype=None):
    """(xp @ (W+θ̃), xm @ (W−θ̃)) with ONE pass over W (antithetic probe pair).

    ``xp``/``xm`` are the +/− probe activation streams (same shape); leading
    batch dims are flattened into M.
    """
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.perturbed_matmul_pair_ref(
            xp, xm, w, lseed, dtheta=dtheta, out_dtype=out_dtype)

    xp2, lead, m = _flatten_lead(xp)
    xm2, _, _ = _flatten_lead(xm)
    kdim, n = w.shape
    bm_eff = min(bm, max(8, m))
    bk_eff = _tile(kdim, bk)
    bn_eff = _tile(n, bn)
    xp2 = _pad_to(_pad_to(xp2, bm_eff, 0), bk_eff, 1)
    xm2 = _pad_to(_pad_to(xm2, bm_eff, 0), bk_eff, 1)
    wp = _pad_to(_pad_to(w, bk_eff, 0), bn_eff, 1)
    yp, ym = _perturbed_matmul_pair_pallas(
        xp2, xm2, wp, lseed, dtheta=dtheta,
        bm=min(bm_eff, xp2.shape[0]), bn=bn_eff, bk=bk_eff,
        out_dtype=out_dtype or xp.dtype, n_cols=n,
        interpret=(impl == "interpret"),
    )
    return (yp[:m, :n].reshape(*lead, n), ym[:m, :n].reshape(*lead, n))


def _as_matrix(w):
    """View an ndim≥2 leaf as [prod(lead), last] — row-major flattening, so
    the linear sign indices are unchanged."""
    assert w.ndim >= 2, w.shape
    return w.reshape(-1, w.shape[-1])


def mgd_update(w, lseeds, coefs, *, eta, dtheta, impl=None, bk=256, bn=256):
    """Fused scalar-replay window update for one weight matrix."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.mgd_update_ref(w, lseeds, coefs, eta=eta, dtheta=dtheta)
    kdim, n = w.shape
    bk_eff = _tile(kdim, bk)
    bn_eff = _tile(n, bn)
    wp = _pad_to(_pad_to(w, bk_eff, 0), bn_eff, 1)
    out = _mgd_update_pallas(
        wp, lseeds, coefs, eta=eta, dtheta=dtheta,
        bk=bk_eff, bn=bn_eff, n_cols=n,
        interpret=(impl == "interpret"),
    )
    return out[:kdim, :n]


def mgd_update_window(w, lseeds, coefs, *, alpha, dtheta, impl=None,
                      bk=256, bn=256):
    """Sequential-axpy window update W ← W + α·((Δθ·sign_j)·coefs[j]) —
    bit-exact (f32) fused form of the optimizer's per-step update chain.

    Accepts any ndim ≥ 2 leaf (stacked [L, d_in, d_out] banks, conv
    kernels); the leaf is viewed row-major as a matrix, which preserves the
    host generator's linear sign indices exactly.
    """
    shape = w.shape
    w2 = _as_matrix(w)
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.mgd_update_window_ref(
            w2, lseeds, coefs, alpha=alpha, dtheta=dtheta).reshape(shape)
    kdim, n = w2.shape
    bk_eff = _tile(kdim, bk)
    bn_eff = _tile(n, bn)
    wp = _pad_to(_pad_to(w2, bk_eff, 0), bn_eff, 1)
    out = _mgd_update_window_pallas(
        wp, lseeds, coefs, alpha=alpha, dtheta=dtheta,
        bk=bk_eff, bn=bn_eff, n_cols=n,
        interpret=(impl == "interpret"),
    )
    return out[:kdim, :n].reshape(shape)
