"""jit'd dispatch wrappers for the MGD Pallas kernels.

``impl`` selection:
* "pallas"    — compiled Pallas (TPU target)
* "interpret" — Pallas interpret mode (CPU-correctness path; default when no
  TPU backend is present)
* "ref"       — pure-jnp oracle (always available, materializes θ̃)

The wrappers pad non-tile-aligned shapes, so any (M, K, N) works.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .mgd_update import mgd_update as _mgd_update_pallas
from .perturbed_matmul import perturbed_matmul as _perturbed_matmul_pallas


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def perturbed_matmul(x, w, lseed, *, dtheta, sign=1.0, impl=None,
                     bm=128, bn=128, bk=128, out_dtype=None):
    """y = x @ (W + sign·Δθ·rademacher(lseed)); θ̃ fused in-kernel.

    Leading batch dims of ``x`` are flattened into M.  Arbitrary shapes are
    zero-padded to tile multiples (padding K would corrupt the sign indexing
    of W, so K/N padding pads W *columns/rows are index-significant* — we
    instead require the caller's W shape and pad only M).
    """
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.perturbed_matmul_ref(
            x, w, lseed, dtheta=dtheta, sign=sign, out_dtype=out_dtype)

    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    x2 = x.reshape(m, x.shape[-1])
    kdim, n = w.shape
    # M padding is sign-safe (signs depend only on W's indices)
    bm_eff = min(bm, max(8, m))
    x2p = _pad_to(x2, bm_eff, 0)
    # K and N must tile exactly — pick divisors instead of padding
    bk_eff = _largest_tile(kdim, bk)
    bn_eff = _largest_tile(n, bn)
    y = _perturbed_matmul_pallas(
        x2p, w, lseed, dtheta=dtheta, sign=sign,
        bm=min(bm_eff, x2p.shape[0]), bn=bn_eff, bk=bk_eff,
        out_dtype=out_dtype or x.dtype,
        interpret=(impl == "interpret"),
    )
    return y[:m].reshape(*lead, n)


def mgd_update(w, lseeds, coefs, *, eta, dtheta, impl=None, bk=256, bn=256):
    """Fused scalar-replay window update for one weight matrix."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.mgd_update_ref(w, lseeds, coefs, eta=eta, dtheta=dtheta)
    kdim, n = w.shape
    return _mgd_update_pallas(
        w, lseeds, coefs, eta=eta, dtheta=dtheta,
        bk=_largest_tile(kdim, bk), bn=_largest_tile(n, bn),
        interpret=(impl == "interpret"),
    )


def _largest_tile(dim: int, cap: int) -> int:
    """Largest divisor of ``dim`` that is ≤ cap (prefers MXU-aligned)."""
    if dim <= cap:
        return dim
    for t in range(cap, 0, -1):
        if dim % t == 0:
            return t
    return dim
