"""Pallas TPU kernel: fused scalar-replay MGD parameter update.

Applies the τ_θ-window update of the scalar-replay mode in one pass over W:

    W ← W − (η/Δθ) · Σ_j  c̃_j · sign(h(idx, lseed_j))

The per-window-step leaf seeds (lseed_j) and cost scalars (c̃_j) live in SMEM
(scalar-prefetch); the J sign regenerations happen in VMEM against the
already-resident W tile.  HBM traffic is therefore read-W + write-W — the
same bytes as a plain SGD update, independent of the window length J — which
is the memory-roofline form of the paper's "no per-parameter gradient memory"
claim for τ_θ > τ_p hardware.

Grid: (K/bk, N/bn); the J-loop is an in-register fori_loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .perturbed_matmul import _index_signs, _tile_index


def _kernel(lseeds_ref, coefs_ref, w_ref, o_ref, *,
            scale, bk, bn, n_cols, window):
    i = pl.program_id(0)
    j = pl.program_id(1)
    idx_g = _tile_index(i * bk, j * bn, bk, bn, n_cols)

    def body(t, acc):
        sgn = _index_signs(idx_g, lseeds_ref[t])
        return acc + coefs_ref[t] * sgn

    acc = jax.lax.fori_loop(
        0, window, body, jnp.zeros((bk, bn), jnp.float32)
    )
    o_ref[...] = (w_ref[...].astype(jnp.float32) - scale * acc).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eta", "dtheta", "bk", "bn", "interpret",
                              "n_cols")
)
def mgd_update(
    w: jnp.ndarray,        # [K, N] parameter matrix
    lseeds: jnp.ndarray,   # [J] uint32 — leaf_seed per window step
    coefs: jnp.ndarray,    # [J] f32   — C̃ scalar per window step
    *,
    eta: float,
    dtheta: float,
    bk: int = 256,
    bn: int = 256,
    interpret: bool = False,
    n_cols: int | None = None,
) -> jnp.ndarray:
    """W − (η/Δθ)·Σ_j coefs[j]·signs_j, fused; returns the updated W.

    ``n_cols`` overrides the sign-indexing row stride (the unpadded N) when
    W arrives zero-padded on its last dim — see perturbed_matmul.
    """
    kdim, n = w.shape
    bk, bn = min(bk, kdim), min(bn, n)
    assert kdim % bk == 0 and n % bn == 0, (w.shape, bk, bn)
    window = lseeds.shape[0]
    assert coefs.shape == (window,)

    kernel = functools.partial(
        _kernel, scale=float(eta) / float(dtheta),
        bk=bk, bn=bn, n_cols=n_cols or n, window=window,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(kdim // bk, n // bn),
            in_specs=[pl.BlockSpec((bk, bn), lambda i, j, *_: (i, j))],
            out_specs=pl.BlockSpec((bk, bn), lambda i, j, *_: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((kdim, n), w.dtype),
        interpret=interpret,
    )(jnp.asarray(lseeds, jnp.uint32), jnp.asarray(coefs, jnp.float32), w)


# ---------------------------------------------------------------------------
# Exact-order window update (the optimizer's fused path)
# ---------------------------------------------------------------------------
#
# The kernel above computes sum-then-subtract, which is the natural fused
# form but NOT the floating-point order of the reference optimizer
# (core/mgd.py applies the window sequentially:
#     W ← W + a_j·θ̃_j,  θ̃_j = Δθ·sign_j,  one axpy per window step).
# ``mgd_update_window`` reproduces that exact association —
#     W ← W + α·((Δθ·sign_j)·coef_j)   for j = 0..J−1, in order —
# so the fused optimizer path is bit-identical (f32) to the materializing
# path while still paying only read-W + write-W in HBM traffic.


def _window_kernel(lseeds_ref, coefs_ref, w_ref, o_ref, *,
                   alpha, dtheta, bk, bn, n_cols, window):
    i = pl.program_id(0)
    j = pl.program_id(1)
    idx_g = _tile_index(i * bk, j * bn, bk, bn, n_cols)

    def body(t, w32):
        sgn = _index_signs(idx_g, lseeds_ref[t])
        # association mirrors tree_scale→tree_axpy: α·((Δθ·sgn)·coef) =
        # sgn·(α·(Δθ·coef)) exactly (sgn = ±1 commutes through both
        # roundings), computed sign-LAST so the multiply feeding the add
        # is exact — FMA contraction of mul+add then cannot move the
        # result off the reference optimizer's two-rounding chain, and
        # needs no barrier to survive fusion.  The scalar-chain barriers
        # keep XLA from merging the α and Δθ constants into one factor.
        term = jax.lax.optimization_barrier(
            alpha * jax.lax.optimization_barrier(dtheta * coefs_ref[t]))
        return w32 + sgn * term

    w32 = jax.lax.fori_loop(
        0, window, body, w_ref[...].astype(jnp.float32)
    )
    o_ref[...] = w32.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("alpha", "dtheta", "bk", "bn", "interpret",
                              "n_cols")
)
def mgd_update_window(
    w: jnp.ndarray,        # [K, N] parameter matrix
    lseeds: jnp.ndarray,   # [J] uint32 — leaf_seed per window step
    coefs: jnp.ndarray,    # [J] f32   — per-step scalar coefficient
    *,
    alpha: float,
    dtheta: float,
    bk: int = 256,
    bn: int = 256,
    interpret: bool = False,
    n_cols: int | None = None,
) -> jnp.ndarray:
    """W + α·Σ_j (Δθ·sign_j)·coefs[j], applied sequentially in j.

    Bit-exact (f32) fused form of the optimizer's per-step axpy chain; the
    coefficients carry whatever scalar the caller's order requires
    (C̃/Δθ² for τ_θ=1 with α=−η; −η·C̃/Δθ² for replay with α=1).
    """
    kdim, n = w.shape
    bk, bn = min(bk, kdim), min(bn, n)
    assert kdim % bk == 0 and n % bn == 0, (w.shape, bk, bn)
    window = lseeds.shape[0]
    assert coefs.shape == (window,)

    kernel = functools.partial(
        _window_kernel, alpha=float(alpha), dtheta=float(dtheta),
        bk=bk, bn=bn, n_cols=n_cols or n, window=window,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(kdim // bk, n // bn),
            in_specs=[pl.BlockSpec((bk, bn), lambda i, j, *_: (i, j))],
            out_specs=pl.BlockSpec((bk, bn), lambda i, j, *_: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((kdim, n), w.dtype),
        interpret=interpret,
    )(jnp.asarray(lseeds, jnp.uint32), jnp.asarray(coefs, jnp.float32), w)
