"""Pallas TPU kernel: fused scalar-replay MGD parameter update.

Applies the τ_θ-window update of the scalar-replay mode in one pass over W:

    W ← W − (η/Δθ) · Σ_j  c̃_j · sign(h(idx, lseed_j))

The per-window-step leaf seeds (lseed_j) and cost scalars (c̃_j) live in SMEM
(scalar-prefetch); the J sign regenerations happen in VMEM against the
already-resident W tile.  HBM traffic is therefore read-W + write-W — the
same bytes as a plain SGD update, independent of the window length J — which
is the memory-roofline form of the paper's "no per-parameter gradient memory"
claim for τ_θ > τ_p hardware.

Grid: (K/bk, N/bn); the J-loop is an in-register fori_loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .perturbed_matmul import _fmix32, _GOLDEN


def _kernel(lseeds_ref, coefs_ref, w_ref, o_ref, *,
            scale, bk, bn, n_cols, window):
    i = pl.program_id(0)
    j = pl.program_id(1)
    # i/j are traced program ids — convert via astype, not np.uint32
    rows = (jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
            + (i * bk).astype(jnp.uint32))
    cols = (jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
            + (j * bn).astype(jnp.uint32))
    idx_g = rows * np.uint32(n_cols) + cols

    def body(t, acc):
        h = _fmix32(idx_g * _GOLDEN + lseeds_ref[t])
        sgn = 1.0 - 2.0 * (h >> np.uint32(31)).astype(jnp.float32)
        return acc + coefs_ref[t] * sgn

    acc = jax.lax.fori_loop(
        0, window, body, jnp.zeros((bk, bn), jnp.float32)
    )
    o_ref[...] = (w_ref[...].astype(jnp.float32) - scale * acc).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eta", "dtheta", "bk", "bn", "interpret")
)
def mgd_update(
    w: jnp.ndarray,        # [K, N] parameter matrix
    lseeds: jnp.ndarray,   # [J] uint32 — leaf_seed per window step
    coefs: jnp.ndarray,    # [J] f32   — C̃ scalar per window step
    *,
    eta: float,
    dtheta: float,
    bk: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """W − (η/Δθ)·Σ_j coefs[j]·signs_j, fused; returns the updated W."""
    kdim, n = w.shape
    bk, bn = min(bk, kdim), min(bn, n)
    assert kdim % bk == 0 and n % bn == 0, (w.shape, bk, bn)
    window = lseeds.shape[0]
    assert coefs.shape == (window,)

    kernel = functools.partial(
        _kernel, scale=float(eta) / float(dtheta),
        bk=bk, bn=bn, n_cols=n, window=window,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(kdim // bk, n // bn),
            in_specs=[pl.BlockSpec((bk, bn), lambda i, j, *_: (i, j))],
            out_specs=pl.BlockSpec((bk, bn), lambda i, j, *_: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((kdim, n), w.dtype),
        interpret=interpret,
    )(jnp.asarray(lseeds, jnp.uint32), jnp.asarray(coefs, jnp.float32), w)
