"""Pallas TPU kernels for MGD compute hot-spots.

* ``perturbed_matmul`` — x @ (W + Δθ·θ̃) with the Rademacher signs generated
  in VMEM during the MXU matmul (θ̃ never exists in HBM).
* ``mgd_update``       — fused scalar-replay window update
  W −= (η/Δθ)·Σ_j C̃_j·θ̃_j, HBM traffic = one read + one write of W.

``ops`` holds the jit'd dispatch wrappers (pallas / interpret / ref);
``ref`` holds the pure-jnp oracles that share the exact counter hash.
"""
from . import ops, ref
from .ops import perturbed_matmul, mgd_update

__all__ = ["ops", "ref", "perturbed_matmul", "mgd_update"]
