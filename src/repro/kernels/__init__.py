"""Pallas TPU kernels for MGD compute hot-spots.

* ``perturbed_matmul``      — x @ (W + Δθ·θ̃) with the Rademacher signs
  generated in VMEM during the MXU matmul (θ̃ never exists in HBM).
* ``perturbed_matmul_pair`` — the antithetic probe pair
  (x₊ @ (W+θ̃), x₋ @ (W−θ̃)) in one grid pass: W is read from HBM ONCE per
  central-difference probe pair.
* ``mgd_update``            — fused scalar-replay window update
  W −= (η/Δθ)·Σ_j C̃_j·θ̃_j, HBM traffic = one read + one write of W.
* ``mgd_update_window``     — the same update in the optimizer's exact
  sequential-axpy float order (bit-identical f32 trajectories; this is the
  variant ``MGDConfig(fused=True)`` consumes).

``ops`` holds the jit'd dispatch wrappers (pallas / interpret / ref);
``ref`` holds the pure-jnp oracles that share the exact counter hash.
"""
from . import ops, ref
from .ops import (mgd_update, mgd_update_window, perturbed_matmul,
                  perturbed_matmul_pair)

__all__ = ["ops", "ref", "perturbed_matmul", "perturbed_matmul_pair",
           "mgd_update", "mgd_update_window"]
