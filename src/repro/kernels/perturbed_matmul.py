"""Pallas TPU kernel: matmul with in-kernel Rademacher perturbation.

Computes  y = x @ (W + σ·Δθ·sign(h(idx, lseed)))  without ever materializing
the perturbation θ̃ in HBM: the ±1 signs are regenerated inside VMEM from the
same murmur3 counter hash the host uses (``repro.core.perturbations``), tile
by tile, while the W tile is already resident for the MXU matmul.

This is the TPU adaptation of the paper's "perturbation generated locally at
the parameter" (an LFSR per synapse in hardware): the synapse-local noise
source becomes a hash of the weight's linear index, evaluated next to the
compute unit.  Memory-roofline effect: an MGD probe step reads W exactly
once per matmul, the same HBM bytes as inference — versus 2× for an
implementation that materializes θ+θ̃ (measured in EXPERIMENTS.md §Perf).

σ ∈ {+1, −1} selects the antithetic probe for central differences.

Grid: (M/bm, N/bn, K/bk), K innermost; f32 accumulation in VMEM scratch.
Tile defaults are MXU-aligned (128×128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# numpy scalars: static constants, never captured as traced values
_GOLDEN = np.uint32(0x9E3779B9)
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)


def _fmix32(x):
    """murmur3 finalizer — must stay bit-identical to perturbations._fmix32."""
    x = x ^ (x >> np.uint32(16))
    x = x * _M1
    x = x ^ (x >> np.uint32(13))
    x = x * _M2
    x = x ^ (x >> np.uint32(16))
    return x


def _tile_index(k0, n0, bk, bn, n_cols):
    """uint32 linear indices of the W tile whose top-left element is
    (k0, n0): W[r, c] flattens row-major to r*N + c — identical to the
    ``lax.iota`` indexing of the host-side generator.  ``n_cols`` is the
    UNPADDED row stride (see perturbed_matmul's docstring)."""
    # k0/n0 are traced (program_id·tile) — convert via astype, not np.uint32
    rows = (jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
            + jnp.asarray(k0, jnp.int32).astype(jnp.uint32))
    cols = (jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
            + jnp.asarray(n0, jnp.int32).astype(jnp.uint32))
    return rows * np.uint32(n_cols) + cols


def _index_signs(idx, lseed):
    """±1 f32 Rademacher signs for linear indices ``idx`` under ``lseed``
    — the ONE in-kernel copy of the host hash (perturbations.rademacher_
    signs); every kernel that regenerates θ̃ must go through here."""
    h = _fmix32(idx * _GOLDEN + lseed)
    return 1.0 - 2.0 * (h >> np.uint32(31)).astype(jnp.float32)


def _tile_signs(lseed, k0, n0, bk, bn, n_cols):
    """±1 f32 signs for the W tile whose top-left element is (k0, n0)."""
    return _index_signs(_tile_index(k0, n0, bk, bn, n_cols), lseed)


def _kernel(lseed_ref, x_ref, w_ref, o_ref, acc_ref, *,
            dtheta, sign, bk, bn, n_cols, k_tiles):
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lseed = lseed_ref[0]
    signs = _tile_signs(lseed, k * bk, j * bn, bk, bn, n_cols)
    w = w_ref[...].astype(jnp.float32) + (sign * dtheta) * signs
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_tiles - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("dtheta", "sign", "bm", "bn", "bk", "out_dtype",
                     "interpret", "n_cols"),
)
def perturbed_matmul(
    x: jnp.ndarray,            # [M, K]
    w: jnp.ndarray,            # [K, N]
    lseed: jnp.ndarray,        # uint32 scalar — leaf_seed(seed, step, leaf_id)
    *,
    dtheta: float,
    sign: float = 1.0,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
    n_cols: int | None = None,
) -> jnp.ndarray:
    """y = x @ (W + sign·Δθ·rademacher(lseed)) with fused sign generation.

    ``n_cols`` overrides the row stride used for sign indexing — pass the
    *unpadded* N when W has been zero-padded on its last dim so the signs of
    the real elements keep their original linear indices (padded rows/cols
    feed only discarded outputs or zero x columns, so their garbage signs
    are harmless).
    """
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, (x.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (
        f"shapes ({m},{kdim})x({kdim},{n}) not divisible by tile "
        f"({bm},{bn},{bk}); pad upstream")
    out_dtype = out_dtype or x.dtype
    k_tiles = kdim // bk

    grid = (m // bm, n // bn, k_tiles)
    kernel = functools.partial(
        _kernel, dtheta=float(dtheta), sign=float(sign),
        bk=bk, bn=bn, n_cols=n_cols or n, k_tiles=k_tiles,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, *_: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k, *_: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, *_: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(jnp.asarray(lseed, jnp.uint32).reshape(1), x, w)


# ---------------------------------------------------------------------------
# Antithetic pair: y± = x± @ (W ± Δθ·signs), one HBM read of W per pair
# ---------------------------------------------------------------------------


def _pair_kernel(lseed_ref, xp_ref, xm_ref, w_ref, op_ref, om_ref,
                 accp_ref, accm_ref, *, dtheta, bk, bn, n_cols, k_tiles):
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        accp_ref[...] = jnp.zeros_like(accp_ref)
        accm_ref[...] = jnp.zeros_like(accm_ref)

    lseed = lseed_ref[0]
    signs = _tile_signs(lseed, k * bk, j * bn, bk, bn, n_cols)
    w = w_ref[...].astype(jnp.float32)
    theta = dtheta * signs
    dn = (((1,), (0,)), ((), ()))
    accp_ref[...] += jax.lax.dot_general(
        xp_ref[...].astype(jnp.float32), w + theta, dn,
        preferred_element_type=jnp.float32)
    accm_ref[...] += jax.lax.dot_general(
        xm_ref[...].astype(jnp.float32), w + (-dtheta) * signs, dn,
        preferred_element_type=jnp.float32)

    @pl.when(k == k_tiles - 1)
    def _():
        op_ref[...] = accp_ref[...].astype(op_ref.dtype)
        om_ref[...] = accm_ref[...].astype(om_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("dtheta", "bm", "bn", "bk", "out_dtype", "interpret",
                     "n_cols"),
)
def perturbed_matmul_pair(
    xp: jnp.ndarray,           # [M, K] activation stream of the +θ̃ probe
    xm: jnp.ndarray,           # [M, K] activation stream of the −θ̃ probe
    w: jnp.ndarray,            # [K, N]
    lseed: jnp.ndarray,        # uint32 scalar
    *,
    dtheta: float,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
    n_cols: int | None = None,
):
    """(xp @ (W+θ̃), xm @ (W−θ̃)) in ONE grid pass over W.

    The central-difference probe pair of MGD shares one HBM read of each W
    tile: the tile is loaded for the MXU once, the ±Δθ sign pattern is
    regenerated in VMEM, and both antithetic products accumulate in separate
    scratch.  Per probe *pair* the weight-read traffic is therefore 1× the
    inference bytes (vs 2× for two independent fused calls and ~4× for the
    materializing baseline — see EXPERIMENTS.md §Perf).

    ``xp`` and ``xm`` are the two activation streams (identical at the input
    layer, diverging after the first perturbed layer).
    """
    m, kdim = xp.shape
    assert xm.shape == xp.shape, (xp.shape, xm.shape)
    k2, n = w.shape
    assert kdim == k2, (xp.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (
        f"shapes ({m},{kdim})x({kdim},{n}) not divisible by tile "
        f"({bm},{bn},{bk}); pad upstream")
    out_dtype = out_dtype or xp.dtype
    k_tiles = kdim // bk

    grid = (m // bm, n // bn, k_tiles)
    kernel = functools.partial(
        _pair_kernel, dtheta=float(dtheta),
        bk=bk, bn=bn, n_cols=n_cols or n, k_tiles=k_tiles,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, *_: (i, k)),
                pl.BlockSpec((bm, bk), lambda i, j, k, *_: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k, *_: (k, j)),
            ],
            out_specs=[
                pl.BlockSpec((bm, bn), lambda i, j, k, *_: (i, j)),
                pl.BlockSpec((bm, bn), lambda i, j, k, *_: (i, j)),
            ],
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                            pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((m, n), out_dtype),
                   jax.ShapeDtypeStruct((m, n), out_dtype)],
        interpret=interpret,
    )(jnp.asarray(lseed, jnp.uint32).reshape(1), xp, xm, w)
