"""Batched serving: prefill a prompt batch, then step the decoder.

Static-batch continuous decoding: one jitted ``decode_step`` is reused for
every token (cache donated, length carried in-cache).  Greedy and
temperature sampling; per-request stop handling via an ``alive`` mask so a
finished request stops contributing compute-visible tokens (its slot keeps
cycling — the production pattern for fixed-shape serving on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import init_cache, model_decode, model_prefill


def _sample(logits, key, temperature):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


def greedy_generate(params, cfg, prompts, max_new: int, *,
                    temperature: float = 0.0, seed: int = 0,
                    eos_id: Optional[int] = None):
    """prompts: [B, S_prompt] int32 → generated [B, max_new] int32."""
    b, s_prompt = prompts.shape
    max_len = s_prompt + max_new
    logits, cache = model_prefill(params, cfg, {"tokens": prompts}, max_len)
    last = logits[:, -1]

    decode = jax.jit(functools.partial(model_decode, cfg=cfg),
                     donate_argnames=("cache",))

    key = jax.random.PRNGKey(seed)
    toks = _sample(last, key, temperature)
    out = [toks]
    alive = jnp.ones((b,), bool)
    for t in range(1, max_new):
        key = jax.random.fold_in(key, t)
        logits, cache = decode(params, tokens=toks, cache=cache)
        toks = _sample(logits, key, temperature)
        if eos_id is not None:
            alive = alive & (out[-1] != eos_id)
            toks = jnp.where(alive, toks, eos_id)
        out.append(toks)
    return jnp.stack(out, axis=1)


def serve_batch(params, cfg, requests, max_new: int, **kw):
    """Pad a ragged request list to a rectangular batch and generate.

    requests: list of 1-D int32 arrays.  Left-pads with 0 (positions still
    causal; synthetic serving path used by examples/serve_lm.py).
    """
    b = len(requests)
    s = max(int(r.shape[0]) for r in requests)
    batch = jnp.zeros((b, s), jnp.int32)
    for i, r in enumerate(requests):
        batch = batch.at[i, s - r.shape[0]:].set(r)
    return greedy_generate(params, cfg, batch, max_new, **kw)
