"""Serving substrate: batched prefill + decode loops with KV/SSM caches,
plus the online-learning service (inference under live traffic with
background MGD re-trim)."""
from .decode import serve_batch, greedy_generate
from .online import (OnlineService, OnlineTrimmer, ParamSnapshot, ParamStore,
                     ReplayBuffer, ServeResult, ServiceConfig, TrimConfig,
                     serve)

__all__ = [
    "serve_batch", "greedy_generate", "OnlineService", "OnlineTrimmer",
    "ParamSnapshot", "ParamStore", "ReplayBuffer", "ServeResult",
    "ServiceConfig", "TrimConfig", "serve",
]
