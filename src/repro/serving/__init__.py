"""Serving substrate: batched prefill + decode loops with KV/SSM caches."""
from .decode import serve_batch, greedy_generate
