"""Online-learning serving tier: inference under live traffic while MGD
re-trims the plant in the background.

This is the deployment regime the drift study (``benchmarks/
drift_aging.py``) said matters: a deployed analog device ages
continuously, and continuous MGD re-trim holds ~0.9 of drift-free
accuracy where the unmitigated device collapses.  ``OnlineService``
turns that result into a product — the repo's first workload where
inference and MGD training share a device:

* **Serving** — requests are queued and batched into FIXED-SHAPE decode
  slots (the ``serving/decode.py`` static-batch pattern: ``slots``
  request lanes plus an alive mask; dead slots keep cycling zeros so the
  jitted predict program never recompiles under ragged traffic).
* **Feedback logging** — every served request that carries feedback is
  appended to a bounded :class:`ReplayBuffer` as an (input, cost-
  feedback) example; the buffer is the bridge between live traffic and
  the optimizer.
* **Background re-trim** — :class:`OnlineTrimmer` drives any MGD
  algorithm through any ``hardware.Plant`` (including a drifting
  ``ChipFarm`` armed with a ``FaultPolicy``) from replay samples, using
  the same registry drivers and per-step jit dispatch as
  ``training.train_mgd``.  Replay sampling is counter-keyed on the
  global step, so the trim trajectory is a pure function of (buffer
  content, step) — checkpoint/resume replays it bit-exactly while the
  buffer is quiescent.
* **Snapshot-consistent swaps** — the trainer publishes parameters into
  a versioned :class:`ParamStore`; the dispatcher takes ONE snapshot per
  decode batch, so a swap can never tear mid-decode (a response is
  computed entirely under old or entirely under new parameters — the
  torn-swap regression test pins this).  Publishes happen only after
  ``fence()`` drains in-flight pipelined plant writes (the PR 7
  discipline), so the published tree is what actually LANDED on the
  device.
* **Checkpointing** — the trimmer checkpoints the generic
  ``{"params", "state"}`` driver-state tree through
  ``training.checkpoint`` (the PR 3 mechanism), with the replay ring in
  a sidecar ``replay_<step>.npz``; restoring resumes serve→trim
  bit-exactly (f32).

Lifecycle contract (shared with ``ExternalPlant`` and ``ChipFarm``):
``__enter__``/``__exit__``, idempotent ``close()``, and ``fence()``.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.driver import state_step
from repro.training import checkpoint as ckpt
from repro.training.train_loop import resolve_driver

Pytree = Any

#: default bound on any blocking service operation — a serving tier must
#: degrade into a visible timeout, never a silent hang (PR 6 discipline)
DEFAULT_TIMEOUT_S = 60.0


# ---------------------------------------------------------------------------
# Versioned parameter store — the snapshot-consistency mechanism
# ---------------------------------------------------------------------------


class ParamSnapshot(NamedTuple):
    """One immutable (version, params) pair.  Readers that hold a
    snapshot keep a complete, internally consistent tree no matter how
    many publishes happen while they decode with it."""

    version: int
    params: Pytree


class ParamStore:
    """Atomic published-parameter slot.

    ``publish`` swaps a single tuple reference under a lock;
    ``snapshot`` reads that one reference.  Because jax arrays are
    immutable and the whole tree rides one tuple, a reader can never
    observe a mix of old and new leaves — the swap is all-or-nothing by
    construction (tests/test_online_serving.py hammers this from a
    concurrent reader).
    """

    def __init__(self, params: Pytree):
        self._lock = threading.Lock()
        self._snap = ParamSnapshot(0, params)

    def publish(self, params: Pytree) -> int:
        """Install ``params`` as the new serving tree; returns the new
        version.  Callers that drive a pipelined plant must ``fence()``
        first so the published tree is the landed one."""
        with self._lock:
            self._snap = ParamSnapshot(self._snap.version + 1, params)
            return self._snap.version

    def snapshot(self) -> ParamSnapshot:
        # one reference read — atomic; the lock only serializes writers
        return self._snap

    @property
    def version(self) -> int:
        return self._snap.version


# ---------------------------------------------------------------------------
# Bounded replay buffer — served traffic becomes training data
# ---------------------------------------------------------------------------


class ReplayBuffer:
    """Bounded ring of (input, feedback) examples logged from traffic.

    Examples are dicts of fixed-shape numpy rows (no leading batch dim);
    storage is allocated lazily from the first example's shapes/dtypes.
    ``sample`` draws a batch with a generator keyed on (seed, step) —
    counter-keyed like every other noise source in the repo (MGD002), so
    a resumed trimmer replays the identical batch sequence from an
    identical buffer.
    """

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._data: Optional[Dict[str, np.ndarray]] = None
        self._size = 0
        self._cursor = 0
        self._total = 0                 # lifetime adds (telemetry)

    def __len__(self) -> int:
        return self._size

    @property
    def total_added(self) -> int:
        return self._total

    def _allocate(self, example: Dict[str, np.ndarray]) -> None:
        self._data = {
            k: np.zeros((self.capacity,) + np.asarray(v).shape,
                        np.asarray(v).dtype)
            for k, v in example.items()}

    def add(self, example: Dict[str, Any]) -> None:
        """Append one example (dict of rows); oldest entry evicted when
        full."""
        rows = {k: np.asarray(v) for k, v in example.items()}
        with self._lock:
            if self._data is None:
                self._allocate(rows)
            if set(rows) != set(self._data):
                raise ValueError(
                    f"example keys {sorted(rows)} != buffer keys "
                    f"{sorted(self._data)}")
            for k, v in rows.items():
                self._data[k][self._cursor] = v
            self._cursor = (self._cursor + 1) % self.capacity
            self._size = min(self._size + 1, self.capacity)
            self._total += 1

    def add_batch(self, batch: Dict[str, Any]) -> None:
        """Append every row of a [B, ...] batch dict."""
        arrs = {k: np.asarray(v) for k, v in batch.items()}
        n = next(iter(arrs.values())).shape[0]
        for i in range(n):
            self.add({k: v[i] for k, v in arrs.items()})

    def sample(self, batch_size: int, step: int, *,
               seed: int = 0) -> Dict[str, np.ndarray]:
        """Draw ``batch_size`` examples (with replacement), keyed on
        (seed, step) — deterministic for a given buffer content."""
        with self._lock:
            if self._size == 0:
                raise ValueError("cannot sample from an empty replay buffer")
            rng = np.random.default_rng((int(seed), int(step)))
            idx = rng.integers(0, self._size, size=int(batch_size))
            return {k: v[idx].copy() for k, v in self._data.items()}

    # -- sidecar persistence (rides next to the driver-state checkpoint) ----

    def state(self) -> Dict[str, np.ndarray]:
        with self._lock:
            out = {"__size": np.int64(self._size),
                   "__cursor": np.int64(self._cursor),
                   "__total": np.int64(self._total)}
            if self._data is not None:
                out.update({f"data_{k}": v.copy()
                            for k, v in self._data.items()})
            return out

    def load_state(self, tree: Dict[str, np.ndarray]) -> None:
        with self._lock:
            data = {k[len("data_"):]: np.array(tree[k])
                    for k in tree if k.startswith("data_")}
            self._data = data or None
            if self._data is not None:
                cap = next(iter(self._data.values())).shape[0]
                if cap != self.capacity:
                    raise ValueError(
                        f"replay checkpoint capacity {cap} != configured "
                        f"{self.capacity}")
            self._size = int(tree["__size"])
            self._cursor = int(tree["__cursor"])
            self._total = int(tree["__total"])

    def save_sidecar(self, path: str) -> None:
        np.savez(path, **self.state())

    def load_sidecar(self, path: str) -> None:
        with np.load(path) as z:
            self.load_state({k: z[k] for k in z.files})


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServiceConfig:
    """Loop-level knobs of :class:`OnlineService` (the serving twin of
    ``training.TrainLoopConfig``)."""

    slots: int = 8                  # fixed decode-slot batch width
    queue_depth: int = 256          # bounded request queue (backpressure)
    batch_window_s: float = 0.002   # linger filling a slot batch
    jit_predict: bool = True        # jit predict_fn (fixed shapes → 1 compile)
    request_timeout_s: float = DEFAULT_TIMEOUT_S
    replay_capacity: int = 2048     # bounded feedback ring
    trim_batch: int = 8             # replay samples per trim step
    min_fill: int = 8               # examples required before trimming
    publish_every: int = 20         # trim steps between param publishes
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0       # trim steps between checkpoints
    resume: bool = True
    seed: int = 0                   # replay-sampling seed (counter-keyed)

    def replace(self, **kw) -> "ServiceConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class TrimConfig:
    """What the background trimmer trains: an algorithm config (or a
    pre-built ``MGDDriver``) plus the model/device plumbing —
    exactly the arguments ``repro.driver`` takes at construction."""

    cfg: Any                        # DriverConfig | legacy config | MGDDriver
    loss_fn: Optional[Callable] = None
    plant: Any = None               # hardware.Plant (None → implicit ideal)
    algorithm: Optional[str] = None
    probe_fn: Optional[Callable] = None


# ---------------------------------------------------------------------------
# The background trimmer
# ---------------------------------------------------------------------------


class OnlineTrimmer:
    """Step-driven MGD re-trim over replay samples, with fenced
    publishes and generic driver-state checkpointing.

    The trimmer is the serving twin of ``train_mgd``'s inner loop: the
    same registry driver, the same per-step ``jax.jit`` dispatch that
    external plants require, the same ``{"params", "state"}`` checkpoint
    tree, and the same fence-before-boundary discipline.  It is driven
    either synchronously (``step(n)`` — deterministic, what the tests
    and gated benchmark rows use) or from the service's trainer thread.
    """

    def __init__(self, trim: TrimConfig, params: Pytree,
                 replay: ReplayBuffer, store: ParamStore,
                 cfg: ServiceConfig):
        self._drv = resolve_driver(
            trim.loss_fn, trim.cfg, probe_fn=trim.probe_fn,
            plant=trim.plant, algorithm=trim.algorithm)
        self._step_fn = jax.jit(self._drv.step)
        self._replay = replay
        self._store = store
        self._cfg = cfg
        self._lock = threading.RLock()
        self._params = params
        self._state = self._drv.init(params)
        self._last_aux: Dict[str, Any] = {}
        self.steps_done = 0             # steps taken by THIS process
        self.publishes = 0

    @property
    def driver(self):
        return self._drv

    @property
    def plant(self):
        return self._drv.plant

    @property
    def params(self) -> Pytree:
        with self._lock:
            return self._params

    @property
    def global_step(self) -> int:
        with self._lock:
            return int(state_step(self._state))

    def fence(self) -> None:
        """Drain in-flight plant writes (pipelined farms) — the
        precondition for publishes, checkpoints and accuracy readouts.
        A no-op for plants without a fence."""
        plant_fence = getattr(self._drv.plant, "fence", None)
        if callable(plant_fence):
            plant_fence()

    # -- trimming -----------------------------------------------------------

    def ready(self) -> bool:
        return len(self._replay) >= max(self._cfg.min_fill, 1)

    def step(self, n: int = 1) -> int:
        """Run up to ``n`` trim steps; returns how many actually ran
        (0 when the replay buffer is below ``min_fill``).  Publish and
        checkpoint boundaries are pure functions of the global step, so
        a resumed trimmer replays the identical schedule."""
        took = 0
        for _ in range(n):
            with self._lock:
                if not self.ready():
                    break
                gstep = int(state_step(self._state))
                batch = self._replay.sample(
                    self._cfg.trim_batch, gstep, seed=self._cfg.seed)
                jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
                self._params, self._state, self._last_aux = self._step_fn(
                    self._params, self._state, jbatch)
                self.steps_done += 1
                took += 1
                done = gstep + 1
                if self._cfg.publish_every and \
                        done % self._cfg.publish_every == 0:
                    self.publish()
                if self._cfg.checkpoint_dir and self._cfg.checkpoint_every \
                        and done % self._cfg.checkpoint_every == 0:
                    self.save()
        return took

    # -- boundaries (fence first — PR 7 discipline, linted by MGD006) -------

    def publish(self) -> int:
        """Swap the trainer's parameters into the serving store,
        snapshot-consistently: fence the plant so every pipelined write
        has landed, then publish the whole tree in one atomic swap."""
        with self._lock:
            self.fence()
            version = self._store.publish(self._params)
            self.publishes += 1
            return version

    def save(self) -> Optional[str]:
        """Checkpoint the generic driver-state tree (+ replay sidecar)."""
        d = self._cfg.checkpoint_dir
        if not d:
            return None
        with self._lock:
            self.fence()
            step = int(state_step(self._state))
            # sidecar first: a crash between the two writes leaves an
            # orphan npz, never a checkpoint that references a missing one
            self._replay.save_sidecar(_sidecar_path(d, step))
            return ckpt.save(d, step,
                             {"params": self._params, "state": self._state},
                             extra={"algo": self._drv.algorithm,
                                    "service": True,
                                    "seed": int(self._cfg.seed)})

    def restore(self) -> Optional[int]:
        """Resume from the newest checkpoint under ``checkpoint_dir``;
        returns the restored global step (None when there is nothing to
        restore).  Parameters, driver state AND the replay ring come
        back, so the continued trajectory is the uninterrupted one."""
        d = self._cfg.checkpoint_dir
        if not d or ckpt.latest_step(d) is None:
            return None
        with self._lock:
            tree, _, step = ckpt.restore(
                d, {"params": self._params, "state": self._state})
            self._params, self._state = tree["params"], tree["state"]
            try:
                self._replay.load_sidecar(_sidecar_path(d, step))
            except FileNotFoundError:
                pass                     # pre-sidecar checkpoint: keep buffer
            return step

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            aux = {k: float(v) for k, v in self._last_aux.items()
                   if np.ndim(v) == 0}
            return {"global_step": int(state_step(self._state)),
                    "steps_done": self.steps_done,
                    "publishes": self.publishes,
                    "replay_fill": len(self._replay),
                    **{f"aux_{k}": v for k, v in aux.items()}}


def _sidecar_path(ckpt_dir: str, step: int) -> str:
    import os
    os.makedirs(ckpt_dir, exist_ok=True)
    return os.path.join(ckpt_dir, f"replay_{step:012d}.npz")


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------


class _Request(NamedTuple):
    inputs: Dict[str, Any]
    feedback: Optional[Dict[str, Any]]
    future: Future
    t0: float


class ServeResult(NamedTuple):
    """One served response: the output row, the parameter version that
    computed it (whole-tree consistent), and the request latency."""

    output: Any
    version: int
    latency_s: float


class OnlineService:
    """Inference under live traffic with background MGD re-trim.

    ``predict_fn(params, batch) -> outputs`` maps a fixed-shape
    ``[slots, ...]`` batch dict to outputs whose leading dim is the slot
    index (jitted once — the static-batch serving pattern).  ``trim=``
    attaches an :class:`OnlineTrimmer`; without it the service is a
    plain batching inference tier.

    Thread layout: callers ``submit``; a dispatcher thread batches
    requests into slots and decodes them under ONE parameter snapshot
    per batch; an optional trainer thread runs the trimmer.  All
    threads are owned by the service and joined by ``close()``.
    """

    def __init__(self, predict_fn: Callable, params: Pytree,
                 cfg: Optional[ServiceConfig] = None, *,
                 trim: Optional[TrimConfig] = None,
                 name: str = "online-service"):
        self.cfg = cfg or ServiceConfig()
        self.name = name
        self._predict = (jax.jit(predict_fn) if self.cfg.jit_predict
                         else predict_fn)
        self.replay = ReplayBuffer(self.cfg.replay_capacity)
        # store constructed after a possible resume so version 0 is the
        # tree the service actually starts serving
        self._store: Optional[ParamStore] = None
        self.trimmer: Optional[OnlineTrimmer] = None
        self.resumed_step: Optional[int] = None
        if trim is not None:
            # the store reference is installed right below; the trimmer
            # never publishes during construction
            self._store = ParamStore(params)
            self.trimmer = OnlineTrimmer(trim, params, self.replay,
                                         self._store, self.cfg)
            if self.cfg.checkpoint_dir and self.cfg.resume:
                self.resumed_step = self.trimmer.restore()
            self._store = ParamStore(self.trimmer.params)
            self.trimmer._store = self._store
        else:
            self._store = ParamStore(params)
        self._queue: queue.Queue = queue.Queue(maxsize=self.cfg.queue_depth)
        self._stop = threading.Event()
        self._threads: list = []
        self._started = False
        self._closed = False
        self._lock = threading.Lock()
        self._served = 0
        self._batches = 0
        self._latencies: list = []      # rolling window (host-side floats)

    # -- lifecycle (uniform with ExternalPlant / ChipFarm) ------------------

    def start(self, *, background_trim: bool = True) -> "OnlineService":
        """Start the dispatcher (and, with a trimmer attached, the
        trainer thread).  Idempotent."""
        if self._closed:
            raise RuntimeError(f"{self.name}: service is closed")
        if self._started:
            return self
        self._started = True
        t = threading.Thread(target=self._dispatch_loop,
                             name=f"{self.name}-dispatch", daemon=True)
        t.start()
        self._threads.append(t)
        if self.trimmer is not None and background_trim:
            t = threading.Thread(target=self._trim_loop,
                                 name=f"{self.name}-trim", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def close(self) -> None:
        """Stop threads, flush the queue (pending requests get a
        RuntimeError, never a hang), fence the plant.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        for t in self._threads:
            t.join(timeout=DEFAULT_TIMEOUT_S)
        self._threads = []
        while True:                     # fail pending futures loudly
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            item.future.set_exception(
                RuntimeError(f"{self.name}: service closed"))
            self._queue.task_done()
        if self.trimmer is not None:
            self.trimmer.fence()

    def __enter__(self) -> "OnlineService":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    @property
    def closed(self) -> bool:
        return self._closed

    def fence(self, timeout: Optional[float] = None) -> None:
        """Drain in-flight serving work (queued + mid-decode requests),
        then fence the trimmer's plant — after this, every submitted
        request has been answered and every parameter write has landed."""
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else DEFAULT_TIMEOUT_S)
        with self._queue.all_tasks_done:
            while self._queue.unfinished_tasks:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._queue.all_tasks_done.wait(
                        remaining):
                    raise TimeoutError(
                        f"{self.name}: fence timed out with "
                        f"{self._queue.unfinished_tasks} requests in flight")
        if self.trimmer is not None:
            self.trimmer.fence()

    # -- serving ------------------------------------------------------------

    @property
    def store(self) -> ParamStore:
        """The versioned serving-parameter store (read-mostly; writers
        must follow the fence-before-publish discipline)."""
        return self._store

    @property
    def version(self) -> int:
        return self._store.version

    def snapshot(self) -> ParamSnapshot:
        return self._store.snapshot()

    def submit(self, inputs: Dict[str, Any],
               feedback: Optional[Dict[str, Any]] = None) -> Future:
        """Enqueue one request (dict of per-example rows).  Returns a
        Future resolving to a :class:`ServeResult`.  ``feedback`` (e.g.
        the eventual label/cost target) is logged with the inputs into
        the replay buffer and becomes training signal for the trimmer."""
        if self._closed:
            raise RuntimeError(f"{self.name}: service is closed")
        if not self._started:
            raise RuntimeError(f"{self.name}: call start() (or use the "
                               f"service as a context manager) first")
        fut: Future = Future()
        item = _Request(inputs, feedback, fut, time.perf_counter())
        self._queue.put(item, timeout=self.cfg.request_timeout_s)
        return fut

    def serve(self, inputs: Dict[str, Any],
              feedback: Optional[Dict[str, Any]] = None,
              timeout: Optional[float] = None) -> ServeResult:
        """Synchronous ``submit`` + wait."""
        return self.submit(inputs, feedback).result(
            timeout if timeout is not None else self.cfg.request_timeout_s)

    # -- trimming (synchronous surface; the trainer thread uses the same) ---

    def trim(self, n: int = 1) -> int:
        """Run up to ``n`` trim steps synchronously; returns how many
        ran.  Deterministic — what tests and gated benchmarks drive."""
        if self.trimmer is None:
            raise RuntimeError(f"{self.name}: no trimmer attached "
                               f"(construct with trim=TrimConfig(...))")
        return self.trimmer.step(n)

    def publish(self) -> int:
        if self.trimmer is None:
            raise RuntimeError(f"{self.name}: no trimmer attached")
        return self.trimmer.publish()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            lat = np.asarray(self._latencies[-4096:], np.float64)
            out = {
                "served": self._served,
                "batches": self._batches,
                "version": self.version,
                "queue_depth": self._queue.qsize(),
                "latency_p50_ms": (float(np.percentile(lat, 50)) * 1e3
                                   if lat.size else 0.0),
                "latency_p99_ms": (float(np.percentile(lat, 99)) * 1e3
                                   if lat.size else 0.0),
            }
        if self.trimmer is not None:
            out.update({f"trim_{k}": v
                        for k, v in self.trimmer.stats().items()})
        return out

    # -- internals ----------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            try:
                first = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            items = [first]
            deadline = time.perf_counter() + self.cfg.batch_window_s
            while len(items) < self.cfg.slots:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    items.append(self._queue.get(timeout=remaining))
                except queue.Empty:
                    break
            self._serve_batch(items)
            for _ in items:
                self._queue.task_done()

    def _pad_slots(self, items):
        """Pack ragged request rows into the fixed [slots, ...] batch
        with an alive mask — dead slots cycle zeros (decode.py's
        static-batch pattern), so the jitted program never re-traces."""
        slots = self.cfg.slots
        keys = list(items[0].inputs)
        batch = {}
        for k in keys:
            rows = [np.asarray(it.inputs[k]) for it in items]
            ref = rows[0]
            arr = np.zeros((slots,) + ref.shape, ref.dtype)
            for i, r in enumerate(rows):
                if r.shape != ref.shape or r.dtype != ref.dtype:
                    raise ValueError(
                        f"request {i}: key {k!r} has shape {r.shape} "
                        f"dtype {r.dtype}, slot expects {ref.shape} "
                        f"{ref.dtype} — fixed-shape serving pads ragged "
                        f"inputs caller-side (see serving.decode)")
                arr[i] = r
            batch[k] = jnp.asarray(arr)
        alive = np.zeros((slots,), bool)
        alive[:len(items)] = True
        return batch, alive

    def _serve_batch(self, items) -> None:
        # ONE snapshot for the whole batch: every response in it was
        # computed under a single complete parameter tree
        snap = self._store.snapshot()
        try:
            batch, _alive = self._pad_slots(items)
            out = jax.device_get(self._predict(snap.params, batch))
        except Exception as e:          # noqa: BLE001 — surfaced per-request
            for it in items:
                it.future.set_exception(e)
            return
        t_done = time.perf_counter()
        lats = []
        for i, it in enumerate(items):
            row = jax.tree_util.tree_map(lambda a: np.asarray(a)[i], out)
            lat = t_done - it.t0
            lats.append(lat)
            if it.feedback is not None:
                self.replay.add({**it.inputs, **it.feedback})
            it.future.set_result(ServeResult(row, snap.version, lat))
        with self._lock:
            self._served += len(items)
            self._batches += 1
            self._latencies.extend(lats)
            if len(self._latencies) > 65536:
                del self._latencies[:-4096]

    def _trim_loop(self) -> None:
        while not self._stop.is_set():
            took = self.trimmer.step(4)
            if not took:
                self._stop.wait(0.005)


# ---------------------------------------------------------------------------
# The front door
# ---------------------------------------------------------------------------


def serve(cfg: Optional[ServiceConfig], predict_fn: Callable,
          params: Pytree, *, trim: Optional[TrimConfig] = None,
          start: bool = True, name: str = "online-service") -> OnlineService:
    """Build (and by default start) an :class:`OnlineService` — the
    canonical serving entry point, re-exported as ``repro.serve``:

        svc = repro.serve(ServiceConfig(slots=8), predict_fn, params,
                          trim=TrimConfig(DriverConfig(...), loss_fn,
                                          plant=farm))
        result = svc.serve({"x": x}, feedback={"y": y})

    Pass ``cfg=None`` for defaults; ``start=False`` to wire threads up
    later (tests that drive the service synchronously do this).
    """
    svc = OnlineService(predict_fn, params, cfg, trim=trim, name=name)
    return svc.start() if start else svc
