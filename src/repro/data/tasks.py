"""Procedural datasets for every paper experiment (offline container).

* XOR / n-bit parity — exact (the paper's Figs 4–7, 9).
* NIST7x7 — procedural reproduction of the paper's 7×7 N/I/S/T letter task
  (base glyphs + pixel noise + shift augmentations; 49-4-4 net target).
* Fashion-MNIST / CIFAR-10 stand-ins — procedural class-template images of
  identical shape/cardinality (28×28×1 and 32×32×3, 10 classes).  The repo
  validates MGD-vs-backprop parity ON THE SAME DATA, not absolute paper
  accuracies (recorded in DESIGN.md §Honest limitations).
* Synthetic LM streams — Zipf-Markov token sequences for the LM-scale archs.

Every sampler is a pure function of (key/index) — restartable, shardable,
and identical across hosts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# --- parity -----------------------------------------------------------------


def parity_dataset(n_bits: int):
    """All 2^n (x, y) pairs; y = XOR of bits.  Returns (x [N,n], y [N,1])."""
    n = 2 ** n_bits
    x = ((np.arange(n)[:, None] >> np.arange(n_bits)[None, :]) & 1
         ).astype(np.float32)
    y = (x.sum(axis=1) % 2).astype(np.float32)[:, None]
    return jnp.asarray(x), jnp.asarray(y)


def xor_dataset():
    return parity_dataset(2)


# --- NIST7x7 ----------------------------------------------------------------

_GLYPHS = {
    "N": ["X.....X", "XX....X", "X.X...X", "X..X..X", "X...X.X", "X....XX",
          "X.....X"],
    "I": ["..XXX..", "...X...", "...X...", "...X...", "...X...", "...X...",
          "..XXX.."],
    "S": [".XXXXX.", "X......", "X......", ".XXXX..", "......X", "......X",
          "XXXXXX."],
    "T": ["XXXXXXX", "...X...", "...X...", "...X...", "...X...", "...X...",
          "...X..."],
}


def _glyph_array(name):
    return np.array([[1.0 if c == "X" else 0.0 for c in row]
                     for row in _GLYPHS[name]], np.float32)


_BASE = np.stack([_glyph_array(c) for c in "NIST"])  # [4,7,7]


def nist7x7_batch(key, batch_size: int, *, noise=0.25, shift=True):
    """Random (x [B,49], y one-hot [B,4]) N/I/S/T samples with pixel noise
    and ±1 px shifts — the paper's small image task, generated on the fly."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    labels = jax.random.randint(k1, (batch_size,), 0, 4)
    imgs = jnp.asarray(_BASE)[labels]                      # [B,7,7]
    if shift:
        sh = jax.random.randint(k2, (batch_size, 2), -1, 2)
        imgs = jax.vmap(lambda im, s: jnp.roll(im, s, axis=(0, 1)))(imgs, sh)
    imgs = imgs + noise * jax.random.normal(k3, imgs.shape)
    x = imgs.reshape(batch_size, 49)
    y = jax.nn.one_hot(labels, 4)
    return x, y


# --- procedural image classes (F-MNIST / CIFAR stand-ins) -------------------


@functools.lru_cache(maxsize=None)
def _templates(hw: int, ch: int, n_classes: int, seed: int):
    # numpy-eager (never traced): lru_cache inside a jit would otherwise
    # cache a tracer.  Smooth class templates = low-frequency random fields.
    rng = np.random.default_rng(seed)
    base = rng.standard_normal((n_classes, hw // 4, hw // 4, ch))
    t = np.repeat(np.repeat(base, 4, axis=1), 4, axis=2)
    # light smoothing to remove the blockiness
    t = (t + np.roll(t, 1, axis=1) + np.roll(t, 1, axis=2)
         + np.roll(t, -1, axis=1) + np.roll(t, -1, axis=2)) / 5.0
    # cache a PURE numpy array: caching a jax constant created inside a
    # trace leaks the tracer into later traces (lru_cache + jit hazard)
    return t.astype(np.float32)


def procedural_image_batch(key, batch_size: int, *, hw, ch, n_classes=10,
                           noise=0.6, seed=17):
    """x [B,hw,hw,ch] f32, y one-hot [B,n_classes]."""
    t = jnp.asarray(_templates(hw, ch, n_classes, seed))
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (batch_size,), 0, n_classes)
    imgs = t[labels]
    sh = jax.random.randint(k2, (batch_size, 2), -2, 3)
    imgs = jax.vmap(lambda im, s: jnp.roll(im, s, axis=(0, 1)))(imgs, sh)
    imgs = imgs + noise * jax.random.normal(k3, imgs.shape)
    return imgs, jax.nn.one_hot(labels, n_classes)


def fashion_batch(key, batch_size: int):
    return procedural_image_batch(key, batch_size, hw=28, ch=1, seed=23)


def cifar_batch(key, batch_size: int):
    return procedural_image_batch(key, batch_size, hw=32, ch=3, seed=29)


# --- synthetic LM token streams ---------------------------------------------


def lm_batch(key, batch_size: int, seq_len: int, vocab: int):
    """Zipf-Markov synthetic text: token t+1 = hash-mix of token t with
    Zipfian resets.  Returns dict(tokens, labels) with next-token labels."""
    k1, k2 = jax.random.split(key)
    # Zipfian marginal via inverse-CDF on uniform
    u = jax.random.uniform(k1, (batch_size, seq_len + 1), minval=1e-6)
    z = jnp.exp(u * np.log(vocab)).astype(jnp.int32) - 1   # ~1/rank
    z = jnp.clip(z, 0, vocab - 1)
    # local structure: 75% of positions continue a deterministic chain
    cont = jax.random.bernoulli(k2, 0.75, (batch_size, seq_len + 1))

    def chain(prev, inputs):
        zt, ct = inputs
        nxt = jnp.where(ct, (prev * 31 + 7) % vocab, zt)
        return nxt, nxt

    _, toks = jax.lax.scan(chain, z[:, 0],
                           (z.T[1:], cont.T[1:]))
    toks = jnp.concatenate([z[:, :1], toks.T], axis=1)     # [B, S+1]
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
