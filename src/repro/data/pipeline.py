"""Host data pipeline: τ_x-aware sample feeds + device placement.

MGD's τ_x (input-sample change time) is a *data-pipeline* responsibility:
the same batch must be presented for τ_x consecutive MGD iterations.  The
builders here return ``sample_fn(sample_index) -> batch`` callables that
``make_mgd_epoch`` drives with index = step // τ_x — pure functions of the
index, so training is deterministic across restarts and hosts.

``shard_batch`` places a global batch onto a mesh with the "batch" logical
axes (used by the launch drivers); ``shard_chip_batch`` is its host-side
twin for chip farms — contiguous per-chip slices matching the mesh's pod
blocks, so batch-sharded farm and mesh runs stay bit-comparable.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding
from repro.distributed.sharding import logical_spec

from . import tasks


def dataset_sampler(x, y, batch_size: int, *, wrap=True):
    """Cycle deterministically through a fixed dataset (XOR/parity-style).

    sample_fn(i) yields the i-th batch (wrapping); batch_size = len(x)
    reproduces the paper's 'all four samples each τ_x' setting.
    """
    n = x.shape[0]

    def sample_fn(i):
        if batch_size >= n:
            return {"x": x, "y": y}
        start = (i * batch_size) % n if wrap else i * batch_size
        idx = (start + jnp.arange(batch_size)) % n
        return {"x": jnp.take(x, idx, axis=0), "y": jnp.take(y, idx, axis=0)}

    return sample_fn


def generator_sampler(batch_fn: Callable, batch_size: int, *, seed=0,
                      as_dict_keys=("x", "y")):
    """Index-seeded procedural sampler: sample_fn(i) = batch_fn(key_i, B).

    Works under jit/scan — the key is derived from the traced index.
    """

    def sample_fn(i):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        out = batch_fn(key, batch_size)
        if isinstance(out, dict):
            return out
        return dict(zip(as_dict_keys, out))

    return sample_fn


def lm_sampler(batch_size: int, seq_len: int, vocab: int, *, seed=0):
    return generator_sampler(
        lambda k, b: tasks.lm_batch(k, b, seq_len, vocab), batch_size,
        seed=seed)


def shard_batch(batch, mesh):
    """Place a host batch onto the mesh, batch dim → ("pod","data")."""

    def put(x):
        spec = logical_spec(x.shape, ["batch"], mesh)
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, batch)


def shard_chip_batch(batch, n_chips: int, chip: int):
    """Chip ``chip``'s contiguous leading-dim shard out of ``n_chips``.

    The host twin of the mesh's ``P("pod")`` block placement: chip i and
    pod i of an equal-k mesh consume the identical rows, which is what
    extends the farm ≡ mesh bit-equality law to sharded batches
    (``ChipFarm(shard_batch=True)`` slices through this).  Pure indexing
    on numpy or jax leaves — host-callback safe.
    """

    def one(x):
        per = x.shape[0] // n_chips
        return x[chip * per:(chip + 1) * per]

    return jax.tree_util.tree_map(one, batch)


def check_chip_shardable(batch, n_chips: int) -> None:
    """Raise unless every batch leaf's leading dim splits evenly into
    ``n_chips`` contiguous shards (the mesh twin enforces the same
    divisibility through its PartitionSpec)."""
    for path, leaf in jax.tree_util.tree_flatten_with_path(batch)[0]:
        shape = getattr(leaf, "shape", ())
        if not shape or shape[0] % n_chips:
            name = "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            raise ValueError(
                f"batch leaf {name!r} with shape {tuple(shape)} cannot be "
                f"sharded over {n_chips} chips — its leading dim must be a "
                f"multiple of the farm size")
