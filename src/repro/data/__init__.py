"""Synthetic data substrate (offline container → procedural datasets)."""
from . import tasks, pipeline
