"""Fault-tolerant checkpointing: atomic, deterministic, elastic.

Design points (MGD makes this unusually cheap):

* State = params + a handful of scalars (step, C₀, C̃ window, seed).  There
  are NO optimizer moments — zeroth-order training holds its entire
  optimizer state in O(τ_θ) scalars, so checkpoint bytes ≈ param bytes.
* Atomicity: write into ``<dir>/.tmp-<step>`` then ``os.rename`` to
  ``step_<n>`` — a crash mid-write never corrupts the latest checkpoint.
* Determinism: perturbations are counter-keyed on the global step, so a
  restore reproduces the *exact* training trajectory (tested in
  tests/test_checkpoint.py).
* Elasticity: ``restore`` accepts a target mesh + shardings and
  ``device_put``s each leaf to the new topology — a 256-chip checkpoint
  restores onto any mesh whose axes divide the leaf dims (elastic scaling /
  failed-node replacement).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, params, extra: Optional[dict] = None,
         keep: int = 3):
    """Atomically save params (+ JSON-serializable ``extra``) at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:012d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(params)
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:012d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, params_like, step: Optional[int] = None,
            mesh=None, shardings=None):
    """Load a checkpoint into the structure of ``params_like``.

    With (mesh, shardings) given, each leaf is device_put to its
    NamedSharding — this is the elastic-resharding path: the checkpoint
    carries no topology, so any compatible mesh works.
    Returns (params, extra_dict, step).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:012d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    ref_leaves, treedef = _flatten(params_like)
    assert len(ref_leaves) == manifest["n_leaves"], (
        f"checkpoint has {manifest['n_leaves']} leaves, "
        f"model expects {len(ref_leaves)}")
    loaded = []
    shard_leaves = (_flatten(shardings)[0] if shardings is not None
                    else [None] * len(ref_leaves))
    for i, (ref, shd) in enumerate(zip(ref_leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        assert tuple(arr.shape) == tuple(ref.shape), (
            i, arr.shape, ref.shape)
        if shd is not None:
            leaf = jax.device_put(arr.astype(ref.dtype), shd)
        else:
            leaf = jnp.asarray(arr, dtype=ref.dtype)
        loaded.append(leaf)
    params = jax.tree_util.tree_unflatten(treedef, loaded)
    return params, manifest["extra"], step
