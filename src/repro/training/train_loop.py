"""Training drivers: MGD (the paper) and backprop+SGD (the baseline).

``train_mgd`` consumes any ``repro.api.MGDDriver`` — discrete Algorithm 1
(incl. the fused Pallas path), continuous Algorithm 2, or probe-parallel
— or any config the registry resolves (``DriverConfig``, ``MGDConfig``,
``AnalogMGDConfig``).  Both loops share the same loss_fn / sampler
interfaces so every comparison in benchmarks/ runs the algorithms on
identical models and data.  The MGD loop scans ``chunk`` iterations per
device program (τ_x handled inside the scan via index-seeded samplers),
checkpoints periodically, and resumes deterministically — the
perturbation sequence is a pure function of the global step and
checkpoints carry the driver's FULL state pytree (whatever the algorithm
keeps: G accumulator, momentum, replay window, filter memories), so a
resumed run is the uninterrupted run.  The loop drives any
``repro.hardware.Plant``: pure-JAX devices scan ``chunk`` steps per
program; external plants (``ExternalPlant``, ``ChipFarm`` — ordered host
callbacks cannot ride lax.scan) fall back to per-step dispatch with the
same sampler/checkpoint semantics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.api.driver import (MGDDriver, driver as build_driver, state_step,
                              warn_deprecated)
from repro.core import MGDState
from repro.optim import sgd_init, sgd_step
from . import checkpoint as ckpt


@dataclasses.dataclass
class TrainResult:
    params: Any
    state: Any
    history: list          # list of (step, metric dict)
    steps_done: int


@dataclasses.dataclass
class TrainLoopConfig:
    """Every loop-level knob of ``train_mgd``, in one place.

    ``train_mgd`` historically grew a dozen keyword arguments (chunking,
    eval cadence, checkpointing, resume, recalibration, device plumbing);
    this dataclass is the consolidated surface —

        repro.train(loss_fn, params, cfg, sample_fn, steps,
                    loop=TrainLoopConfig(chunk=50, checkpoint_dir=d,
                                         checkpoint_every=100))

    The flat keyword spelling is still accepted (it builds this config
    internally, so the two paths are the SAME code — f32-bit-identical
    trajectories, pinned in tests/test_online_serving.py) but emits a
    single-fire ``PendingDeprecationWarning``.
    """

    algorithm: Optional[str] = None    # registry name for a DriverConfig
    chunk: int = 100                   # steps per device program
    eval_fn: Optional[Callable] = None     # eval_fn(params) -> dict
    eval_every: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    resume: bool = True
    log: Optional[Callable] = print
    probe_fn: Optional[Callable] = None    # fused probe path (cfg.fused)
    plant: Any = None                  # hardware.Plant (None → implicit)
    mesh: Any = None                   # probe-parallel probe mesh
    recal_every: int = 0               # scheduled full-rewrite period
    recal_params: Any = None           # shadow params (None → initial)

    def replace(self, **kw) -> "TrainLoopConfig":
        return dataclasses.replace(self, **kw)


_LOOP_FIELDS = tuple(f.name for f in dataclasses.fields(TrainLoopConfig))


def resolve_driver(loss_fn, cfg, *, probe_fn=None, plant=None, mesh=None,
                   algorithm: Optional[str] = None) -> MGDDriver:
    """Resolve ``cfg`` to an ``MGDDriver``: pass one through, or build it
    from a config (legacy configs pick their algorithm; ``DriverConfig``
    defaults to discrete unless ``algorithm`` says otherwise)."""
    if isinstance(cfg, MGDDriver):
        if loss_fn is not None or probe_fn is not None or plant is not None \
                or mesh is not None:
            raise ValueError(
                "got a pre-built MGDDriver AND loss_fn/probe_fn/plant/mesh "
                "— those belong to repro.driver(...) at construction time")
        return cfg
    if algorithm is None:
        from repro.core import AnalogMGDConfig
        algorithm = "analog" if isinstance(cfg, AnalogMGDConfig) \
            else "discrete"
    return build_driver(algorithm, cfg, loss_fn, probe_fn=probe_fn,
                        plant=plant, mesh=mesh)


# the historical private name, kept for callers inside the repo's history
_as_driver = resolve_driver


def _ckpt_tree(params, state):
    """Checkpoint payload: params + the driver's FULL state pytree (None
    entries vanish from the flattened tree, so the structure is a pure
    function of the driver config).  Dropping optimizer buffers on resume
    would silently diverge a resumed run mid-τ_θ-window."""
    return {"params": params, "state": state}


def _recalibrate(drv, params, shadow, step):
    """Scheduled recalibration: commit the trainer's shadow parameters to
    the device, replacing whatever drifted state is stored there.  The
    rewrite lands through the plant's write path — DAC grid, write noise,
    and one drift transition all apply (a recalibration write is still a
    write on an aging device).  With no explicit plant the device is the
    implicit ideal one and the rewrite is the shadow itself."""
    plant = drv.plant
    shadow = jax.tree_util.tree_map(jnp.asarray, shadow)
    if plant is None:
        return shadow
    return plant.write_params(shadow, step=jnp.asarray(step, jnp.int32),
                              prev=params)


def _restore_any(checkpoint_dir, params, state, log):
    """Restore the newest checkpoint into (params, state), falling back
    through the historical layouts: full-state → PR-2 buffers-only
    (discrete) → params-only (buffers reset)."""
    try:
        tree, _, start = ckpt.restore(checkpoint_dir,
                                      _ckpt_tree(params, state))
        return tree["params"], tree["state"], start
    except AssertionError:
        pass
    if isinstance(state, MGDState):
        try:    # PR-2 layout: {"params", "opt": {g, replay_c, m}} + extra
            tree, extra, start = ckpt.restore(
                checkpoint_dir,
                {"params": params, "opt": {"g": state.g,
                                           "replay_c": state.replay_c,
                                           "m": state.m}})
            state = state._replace(
                g=tree["opt"]["g"], replay_c=tree["opt"]["replay_c"],
                m=tree["opt"]["m"], step=jnp.asarray(start, jnp.int32),
                c0=jnp.asarray(extra.get("c0", 0.0), jnp.float32),
                metric_cost=jnp.asarray(extra.get("metric_cost", 0.0),
                                        jnp.float32))
            return tree["params"], state, start
        except AssertionError:
            pass
    # params-only legacy checkpoint
    params, extra, start = ckpt.restore(checkpoint_dir, params)
    if log:
        log("[mgd] legacy checkpoint: optimizer buffers reset")
    from repro.api.driver import replace_step
    state = replace_step(state, start)
    if isinstance(state, MGDState):
        state = state._replace(
            c0=jnp.asarray(extra.get("c0", 0.0), jnp.float32),
            metric_cost=jnp.asarray(extra.get("metric_cost", 0.0),
                                    jnp.float32))
    return params, state, start


def train_mgd(
    loss_fn: Optional[Callable],
    params,
    cfg,                          # MGDDriver | DriverConfig | legacy config
    sample_fn: Callable,          # sample_fn(sample_index) -> batch
    num_steps: int,
    *,
    loop: Optional[TrainLoopConfig] = None,
    **flat,                       # legacy flat spelling of TrainLoopConfig
) -> TrainResult:
    """Run any MGD driver for ``num_steps`` iterations (τ_p ticks).

    Loop-level knobs (chunking, eval cadence, checkpoint/resume,
    scheduled recalibration, device plumbing) live in ``loop=``, a
    ``TrainLoopConfig``.  The historical flat keywords (``chunk=``,
    ``eval_fn=``, ``checkpoint_dir=``, ``plant=``, ...) are still
    accepted — they build the same config, so the flat and ``loop=``
    paths are f32-bit-identical — but the flat spelling emits a
    single-fire ``PendingDeprecationWarning``; new code should pass
    ``loop=TrainLoopConfig(...)`` (or call ``repro.train``).

    ``loop.recal_every`` turns on scheduled recalibration — the
    lab-bench mitigation for drifting/aging devices that MGD's online
    feedback is measured against (``benchmarks/drift_aging.py``): every
    ``recal_every`` completed steps the loop rewrites the device from the
    trainer's shadow parameters (``recal_params``, defaulting to the
    initial ``params`` — the last full calibration) through the plant's
    write path.  Boundaries are a pure function of the global step, so
    checkpoint/resume replays the identical recalibration schedule.
    """
    if flat:
        unknown = sorted(set(flat) - set(_LOOP_FIELDS))
        if unknown:
            raise TypeError(f"train_mgd got unexpected keyword arguments "
                            f"{unknown}; loop-level knobs are the fields "
                            f"of TrainLoopConfig: {sorted(_LOOP_FIELDS)}")
        if loop is not None:
            raise ValueError(
                f"got loop=TrainLoopConfig(...) AND the flat keywords "
                f"{sorted(flat)} — set every loop knob in one place")
        warn_deprecated(
            "train_mgd's flat loop keywords",
            "train_mgd(..., loop=TrainLoopConfig(...))",
            category=PendingDeprecationWarning)
        loop = TrainLoopConfig(**flat)
    elif loop is None:
        loop = TrainLoopConfig()
    if loop.recal_every < 0:
        raise ValueError(
            f"recal_every must be >= 0, got {loop.recal_every}")
    (chunk, eval_fn, eval_every, checkpoint_dir, checkpoint_every, log,
     recal_every, recal_params) = (
        loop.chunk, loop.eval_fn, loop.eval_every, loop.checkpoint_dir,
        loop.checkpoint_every, loop.log, loop.recal_every,
        loop.recal_params)
    # shadow captured from the caller's arguments BEFORE any resume
    # restore — the factory calibration, identical across restarts
    shadow = recal_params if recal_params is not None else params
    drv = resolve_driver(loss_fn, cfg, probe_fn=loop.probe_fn,
                         plant=loop.plant, mesh=loop.mesh,
                         algorithm=loop.algorithm)
    state = drv.init(params)
    start_step = 0
    if checkpoint_dir and loop.resume \
            and ckpt.latest_step(checkpoint_dir) is not None:
        params, state, start_step = _restore_any(
            checkpoint_dir, params, state, log)
        if log:
            log(f"[mgd] resumed from step {start_step}")

    def body(carry, _):
        p, s = carry
        batch = sample_fn(state_step(s) // drv.tau_x)
        p, s, m = drv.step(p, s, batch)
        return (p, s), m

    # External plants (ordered host callbacks — ExternalPlant, ChipFarm)
    # cannot ride lax.scan on all jax versions; drive them step-by-step
    # with the same τ_x sampler semantics.  Checkpoint/resume is identical
    # either way: the state pytree carries the step counter and the
    # device noise is counter-keyed, so a resumed farm run replays the
    # uninterrupted trajectory.
    external = bool(getattr(getattr(drv.plant, "meta", None),
                            "external", False))
    if external:
        step_jit = jax.jit(drv.step)

        def make_runner(n):
            def run(p, s):
                m = {}
                for _ in range(n):
                    batch = sample_fn(int(state_step(s)) // drv.tau_x)
                    p, s, m = step_jit(p, s, batch)
                return p, s, m
            return run
    else:
        def make_runner(n):
            @jax.jit
            def run(p, s):
                (p, s), ms = jax.lax.scan(body, (p, s), None, length=n)
                return p, s, jax.tree_util.tree_map(lambda x: x[-1], ms)
            return run

    # double-buffered farms (ChipFarm(pipeline=True)) leave parameter
    # writes in flight between steps; state-dependent boundaries —
    # checkpoints, evals, recalibration — must not run with writes
    # pending, so the loop fences the plant first.  A no-op for every
    # other plant (and values are unaffected either way: device noise is
    # counter-keyed, so the fence changes WHEN writes land, never what
    # the chips read — resume stays bit-exact through a pipelined
    # boundary).
    plant_fence = getattr(drv.plant, "fence", None)
    fence = plant_fence if callable(plant_fence) else (lambda: None)

    runners = {}
    history = []
    done = start_step
    t0 = time.time()
    while done < num_steps:
        n = min(chunk, num_steps - done)
        if recal_every:
            # stop each device program at the next recalibration boundary
            n = min(n, recal_every - done % recal_every)
        if n not in runners:
            runners[n] = make_runner(n)
        params, state, metrics = runners[n](params, state)
        done += n
        rec = {k: float(v) for k, v in metrics.items()}
        if eval_fn and eval_every and (done % eval_every < chunk):
            fence()
            rec.update({k: float(v) for k, v in eval_fn(params).items()})
        history.append((done, rec))
        if log:
            msg = " ".join(f"{k}={v:.4g}" for k, v in rec.items())
            log(f"[mgd] step {done}/{num_steps} {msg} "
                f"({(time.time()-t0):.1f}s)")
        if recal_every and done % recal_every == 0 and done < num_steps:
            fence()
            params = _recalibrate(drv, params, shadow, done)
            if log:
                log(f"[mgd] step {done}: scheduled recalibration "
                    f"(full rewrite from shadow params)")
        if checkpoint_dir and checkpoint_every and done % checkpoint_every == 0:
            fence()
            ckpt.save(checkpoint_dir, done, _ckpt_tree(params, state),
                      extra={"algo": drv.algorithm,
                             "seed": int(getattr(drv.config, "seed", 0))})
    fence()
    # fault-tolerant plants (ExternalPlant/ChipFarm with a FaultPolicy)
    # expose a telemetry summary — surface it once so a run that survived
    # faults says so instead of looking clean
    fault_summary = getattr(drv.plant, "fault_summary", None)
    if log and callable(fault_summary):
        summary = fault_summary()
        if summary.get("events"):
            log(f"[mgd] fault-tolerance summary: {summary}")
    return TrainResult(params, state, history, done)


def train_backprop(
    loss_fn: Callable,
    params,
    sample_fn: Callable,
    num_steps: int,
    *,
    eta: float,
    momentum: float = 0.0,
    chunk: int = 100,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 0,
    log: Optional[Callable] = print,
) -> TrainResult:
    """The paper's comparison baseline: backprop + plain SGD."""
    opt_state = sgd_init(params, momentum)
    grad_fn = jax.grad(loss_fn)

    def body(carry, i):
        p, o = carry
        batch = sample_fn(i)
        g = grad_fn(p, batch)
        p, o = sgd_step(p, g, o, eta=eta, momentum=momentum)
        return (p, o), loss_fn(p, batch)

    @jax.jit
    def run_chunk(p, o, i0):
        (p, o), losses = jax.lax.scan(
            body, (p, o), i0 + jnp.arange(chunk))
        return p, o, losses[-1]

    history = []
    done = 0
    while done < num_steps:
        params, opt_state, loss = run_chunk(
            params, opt_state, jnp.asarray(done, jnp.int32))
        done += chunk
        rec = {"cost": float(loss)}
        if eval_fn and eval_every and (done % eval_every < chunk):
            rec.update({k: float(v) for k, v in eval_fn(params).items()})
        history.append((done, rec))
        if log:
            msg = " ".join(f"{k}={v:.4g}" for k, v in rec.items())
            log(f"[bp ] step {done}/{num_steps} {msg}")
    return TrainResult(params, opt_state, history, done)


def classification_accuracy(apply_fn, params, x, y_onehot):
    """Fraction of argmax matches — the paper's accuracy metric."""
    pred = apply_fn(params, x)
    return jnp.mean(
        (jnp.argmax(pred, -1) == jnp.argmax(y_onehot, -1)).astype(jnp.float32))
