"""Training drivers: MGD (the paper) and backprop+SGD (the baseline).

Both loops share the same loss_fn / sampler interfaces so every comparison
in benchmarks/ runs the two algorithms on identical models and data.  The
MGD loop scans ``chunk`` iterations per device program (τ_x handled inside
the scan via index-seeded samplers), checkpoints periodically, and resumes
deterministically — the perturbation sequence is a pure function of the
global step and checkpoints carry the FULL optimizer state (G accumulator,
momentum, replay window), so a resumed run is the uninterrupted run.  The
MGD loop drives any ``repro.hardware.Plant`` (ideal/noisy/quantized
devices; external chips need the un-scanned per-step driver — see
``make_mgd_epoch``'s note).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import MGDConfig, make_mgd_step, mgd_init
from repro.optim import sgd_init, sgd_step
from . import checkpoint as ckpt


@dataclasses.dataclass
class TrainResult:
    params: Any
    state: Any
    history: list          # list of (step, metric dict)
    steps_done: int


def _opt_buffers(state):
    """The pytree-valued MGDState buffers (None entries vanish from the
    flattened tree, so the structure is a pure function of the config)."""
    return {"g": state.g, "replay_c": state.replay_c, "m": state.m}


def _ckpt_tree(params, state):
    """Checkpoint payload: params + the FULL optimizer state.  Dropping
    G/momentum/replay buffers on resume would silently diverge a resumed
    run from the uninterrupted one mid-τ_θ-window."""
    return {"params": params, "opt": _opt_buffers(state)}


def train_mgd(
    loss_fn: Optional[Callable],
    params,
    cfg: MGDConfig,
    sample_fn: Callable,          # sample_fn(sample_index) -> batch
    num_steps: int,
    *,
    chunk: int = 100,
    eval_fn: Optional[Callable] = None,    # eval_fn(params) -> dict
    eval_every: int = 0,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    resume: bool = True,
    log: Optional[Callable] = print,
    probe_fn: Optional[Callable] = None,   # fused probe path (cfg.fused)
    plant=None,                   # hardware.Plant device (None → implicit)
) -> TrainResult:
    """Run MGD for ``num_steps`` iterations (τ_p ticks)."""
    state = mgd_init(params, cfg)
    start_step = 0
    if checkpoint_dir and resume and ckpt.latest_step(checkpoint_dir) is not None:
        try:
            tree, extra, start_step = ckpt.restore(
                checkpoint_dir, _ckpt_tree(params, state))
            params = tree["params"]
            state = state._replace(g=tree["opt"]["g"],
                                   replay_c=tree["opt"]["replay_c"],
                                   m=tree["opt"]["m"])
        except AssertionError:
            # legacy params-only checkpoint (pre full-state format)
            params, extra, start_step = ckpt.restore(checkpoint_dir, params)
            if log:
                log("[mgd] legacy checkpoint: optimizer buffers reset")
        state = state._replace(
            step=jnp.asarray(start_step, jnp.int32),
            c0=jnp.asarray(extra.get("c0", 0.0), jnp.float32),
            metric_cost=jnp.asarray(extra.get("metric_cost", 0.0),
                                    jnp.float32))
        if log:
            log(f"[mgd] resumed from step {start_step}")

    step_fn = make_mgd_step(loss_fn, cfg, probe_fn=probe_fn, plant=plant)

    def body(carry, _):
        p, s = carry
        batch = sample_fn(s.step // cfg.tau_x)
        p, s, m = step_fn(p, s, batch)
        return (p, s), m

    def make_runner(n):
        @jax.jit
        def run(p, s):
            (p, s), ms = jax.lax.scan(body, (p, s), None, length=n)
            return p, s, jax.tree_util.tree_map(lambda x: x[-1], ms)
        return run

    runners = {}
    history = []
    done = start_step
    t0 = time.time()
    while done < num_steps:
        n = min(chunk, num_steps - done)
        if n not in runners:
            runners[n] = make_runner(n)
        params, state, metrics = runners[n](params, state)
        done += n
        rec = {k: float(v) for k, v in metrics.items()}
        if eval_fn and eval_every and (done % eval_every < chunk):
            rec.update({k: float(v) for k, v in eval_fn(params).items()})
        history.append((done, rec))
        if log:
            msg = " ".join(f"{k}={v:.4g}" for k, v in rec.items())
            log(f"[mgd] step {done}/{num_steps} {msg} "
                f"({(time.time()-t0):.1f}s)")
        if checkpoint_dir and checkpoint_every and done % checkpoint_every == 0:
            ckpt.save(checkpoint_dir, done, _ckpt_tree(params, state),
                      extra={"c0": float(state.c0),
                             "metric_cost": float(state.metric_cost),
                             "algo": "mgd", "seed": cfg.seed})
    return TrainResult(params, state, history, done)


def train_backprop(
    loss_fn: Callable,
    params,
    sample_fn: Callable,
    num_steps: int,
    *,
    eta: float,
    momentum: float = 0.0,
    chunk: int = 100,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 0,
    log: Optional[Callable] = print,
) -> TrainResult:
    """The paper's comparison baseline: backprop + plain SGD."""
    opt_state = sgd_init(params, momentum)
    grad_fn = jax.grad(loss_fn)

    def body(carry, i):
        p, o = carry
        batch = sample_fn(i)
        g = grad_fn(p, batch)
        p, o = sgd_step(p, g, o, eta=eta, momentum=momentum)
        return (p, o), loss_fn(p, batch)

    @jax.jit
    def run_chunk(p, o, i0):
        (p, o), losses = jax.lax.scan(
            body, (p, o), i0 + jnp.arange(chunk))
        return p, o, losses[-1]

    history = []
    done = 0
    while done < num_steps:
        params, opt_state, loss = run_chunk(
            params, opt_state, jnp.asarray(done, jnp.int32))
        done += chunk
        rec = {"cost": float(loss)}
        if eval_fn and eval_every and (done % eval_every < chunk):
            rec.update({k: float(v) for k, v in eval_fn(params).items()})
        history.append((done, rec))
        if log:
            msg = " ".join(f"{k}={v:.4g}" for k, v in rec.items())
            log(f"[bp ] step {done}/{num_steps} {msg}")
    return TrainResult(params, opt_state, history, done)


def classification_accuracy(apply_fn, params, x, y_onehot):
    """Fraction of argmax matches — the paper's accuracy metric."""
    pred = apply_fn(params, x)
    return jnp.mean(
        (jnp.argmax(pred, -1) == jnp.argmax(y_onehot, -1)).astype(jnp.float32))
