"""Training substrate: MGD/backprop loops, checkpointing, fault tolerance."""
from . import checkpoint, train_loop
from .train_loop import (TrainLoopConfig, TrainResult, classification_accuracy,
                         resolve_driver, train_backprop, train_mgd)

__all__ = [
    "checkpoint", "train_loop", "TrainLoopConfig", "TrainResult",
    "classification_accuracy", "resolve_driver", "train_backprop",
    "train_mgd",
]
