"""Training substrate: MGD/backprop loops, checkpointing, fault tolerance."""
from . import checkpoint, train_loop
