"""Fault injection + fault policy for the host-side hardware boundary.

Real instruments do not fail like Gaussian noise: they hang (a serial
link drops mid-transaction), they crash (a driver raises), and they
return garbage (a spiked ADC reads NaN or a full-scale outlier with no
exception to signal it).  The paper's deployment endgame — and the
scaling follow-up's k-chip probe parallelism (Oripov et al. 2025) —
multiplies that fault surface by k: one hung chip in a farm deadlocks
the whole ordered-``io_callback`` training step, and one silent NaN
corrupts the averaged update ``−η·(1/k)ΣC̃_k·θ̃_k/Δθ²`` for every chip.

This module provides both sides of the robustness story:

* ``FaultSpec`` / ``FaultyChip`` — a composable wrapper over ANY host
  device (simulated, drifting, or a test fake) that injects
  counter-keyed, bit-reproducible faults: hangs, transient exceptions,
  NaN/Inf costs, stuck-at costs, outlier spikes, and intermittent flaky
  windows.  Fault draws are keyed on ``(fault seed, step, tag,
  attempt)``, so two identically-seeded runs inject the identical fault
  schedule, a RETRY of the same readout draws fresh (a transient fault
  clears on the next attempt, like a real glitch), and a resumed run
  replays the same faults at the same steps.
* ``FaultPolicy`` — the host boundary's tolerance configuration:
  per-read timeout, retry count with exponential backoff, non-finite
  rejection, quarantine threshold and re-probe period, and the robust
  aggregation mode the traced step applies to the gathered cost scalars
  (``core.probe_parallel``).  Frozen/hashable so step builders can close
  over it under jit.
* ``ChipHealth`` / ``FarmHealth`` — the per-chip health registry:
  consecutive-failure counts, EWMA latency, quarantine state and
  readmission bookkeeping.  Quarantine gates the PROBE path only: the
  farm keeps committing parameter writes to a quarantined chip (writes
  are cheap and keep it current for readmission), and periodically
  re-probes it; a successful re-probe readmits the chip with its
  counter-keyed noise stream untouched (device noise is a function of
  (step, tag), not of how many reads happened in between).
* ``FaultLog`` — a thread-safe record of every injected and observed
  fault event (injections, timeouts, errors, retries, quarantines,
  readmissions), for benchmarks and postmortems.
* ``guarded_call`` — the one retry/timeout primitive both ``ChipFarm``
  and ``ExternalPlant`` route device transactions through.

Everything here is host-side numpy/stdlib — never traced, never
dispatching JAX ops (host callbacks that do can deadlock the CPU
client; see ``external.py``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import TimeoutError as _FuturesTimeout
from typing import Any, List, Optional, Tuple

import numpy as np

#: Gathers at the host boundary NEVER block forever: even without a
#: ``FaultPolicy``, every ``future.result`` passes this generous timeout
#: so a hung instrument surfaces as a diagnosable ``ChipFaultError``
#: instead of an un-interruptible deadlock inside an ordered callback.
DEFAULT_TIMEOUT_S = 120.0


class ChipFaultError(RuntimeError):
    """A device transaction failed at the host boundary (timeout,
    worker exception, or non-finite readout), annotated with the chip
    index / device name the bare traceback would omit."""


class InjectedFault(RuntimeError):
    """An exception deliberately raised by ``FaultyChip`` (transient
    instrument crash, or a hang released after its sleep)."""


# ---------------------------------------------------------------------------
# Fault injection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-readout fault probabilities for ``FaultyChip``.

    Each readout attempt draws ONE fault kind (or none) from a generator
    keyed on ``(fault seed, step, tag, attempt)`` — bit-reproducible
    across runs, fresh per retry.  Probabilities must sum to ≤ 1.

    ``fail_attempts`` is the deterministic variant for tests: the first
    ``fail_attempts`` attempts at any (step, tag) raise a transient
    fault, later attempts pass through (no RNG involved).

    ``only_steps=(lo, hi)`` restricts injection to the half-open step
    range; ``flaky_every``/``flaky_for`` model an intermittently flaky
    instrument (faults active only while ``step % flaky_every <
    flaky_for``).  Readouts without (step, tag) counters — the bench
    harness — are never faulted: injection is a property of the
    *training* I/O stream.
    """

    transient: float = 0.0     # P(raise InjectedFault)
    hang: float = 0.0          # P(sleep hang_s, then raise — link dropped)
    hang_s: float = 0.5        # how long a hang holds the worker thread
    nan: float = 0.0           # P(return NaN cost)
    inf: float = 0.0           # P(return +Inf cost)
    stuck: float = 0.0         # P(return stuck_value regardless of input)
    stuck_value: float = 0.0
    outlier: float = 0.0       # P(true cost ± outlier_scale spike)
    outlier_scale: float = 100.0
    fail_attempts: int = 0     # deterministic: fail the first n attempts
    only_steps: Optional[Tuple[int, int]] = None
    flaky_every: int = 0
    flaky_for: int = 0

    def __post_init__(self):
        probs = (self.transient, self.hang, self.nan, self.inf,
                 self.stuck, self.outlier)
        if any(not 0.0 <= p <= 1.0 for p in probs):
            raise ValueError(f"fault probabilities must be in [0, 1]: {self}")
        if sum(probs) > 1.0 + 1e-9:
            raise ValueError(
                f"fault probabilities sum to {sum(probs)} > 1: {self}")
        if self.fail_attempts < 0:
            raise ValueError(f"fail_attempts must be >= 0, "
                             f"got {self.fail_attempts}")
        if self.flaky_every < 0 or self.flaky_for < 0:
            raise ValueError("flaky_every/flaky_for must be >= 0")

    def active(self, step: int) -> bool:
        """Whether injection is live at optimizer ``step``."""
        if self.only_steps is not None:
            lo, hi = self.only_steps
            if not lo <= step < hi:
                return False
        if self.flaky_every:
            return (step % self.flaky_every) < self.flaky_for
        return True


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected or observed fault (``FaultLog`` entry)."""

    kind: str                  # inject-* | timeout | error | nonfinite |
    chip: str                  # retry-exhausted | quarantine | readmit |
    step: Optional[int]        # write-error | accuracy-error
    tag: Optional[int]
    attempt: int = 0
    detail: str = ""


class FaultLog:
    """Thread-safe append-only record of fault events."""

    def __init__(self):
        self._lock = threading.Lock()
        self.events: List[FaultEvent] = []

    def record(self, kind: str, chip: Any, *, step=None, tag=None,
               attempt: int = 0, detail: str = "") -> None:
        event = FaultEvent(
            kind=kind, chip=str(chip),
            step=None if step is None else int(step),
            tag=None if tag is None else int(tag),
            attempt=int(attempt), detail=str(detail)[:300])
        with self._lock:
            self.events.append(event)

    def __len__(self) -> int:
        with self._lock:
            return len(self.events)

    def counts(self) -> dict:
        """Event counts by kind (telemetry/benchmarks)."""
        with self._lock:
            out: dict = {}
            for e in self.events:
                out[e.kind] = out.get(e.kind, 0) + 1
            return out

    def drain(self) -> List[FaultEvent]:
        """Return AND clear the recorded events — how a worker-local log
        (process/cluster farm backends) ships its entries back to the
        host with each reply, so the farm's log sees one merged stream."""
        with self._lock:
            events, self.events = self.events, []
            return events

    def extend(self, events) -> None:
        """Fold events shipped from a worker-local log into this one."""
        with self._lock:
            self.events.extend(events)


class FaultyChip:
    """Composable fault-injecting wrapper over any host device.

    Mirrors the wrapped device's capability surface so ``ExternalPlant``
    / ``ChipFarm`` signature inspection sees the same instrument:
    ``set_params`` accepts ``step`` (forwarded only if the inner device
    does), ``measure_cost`` accepts counters, and ``measure_pair`` /
    ``measure_accuracy`` exist exactly when the inner device has them.
    Faults are injected on the counter-carrying READOUT path only —
    writes and bench readouts pass through untouched.

    ``readouts`` counts every readout attempt (including faulted ones);
    ``injected`` counts injections — both feed the fault-tolerance
    benchmark's quarantine-efficiency metrics.
    """

    def __init__(self, device: Any, spec: Optional[FaultSpec] = None, *,
                 seed: int = 0, log: Optional[FaultLog] = None,
                 name: Optional[str] = None):
        for attr in ("set_params", "measure_cost"):
            if not callable(getattr(device, attr, None)):
                raise TypeError(f"FaultyChip wraps a device exposing "
                                f"{attr}(); got {type(device).__name__}")
        from .external import accepts_counters, accepts_step
        self.device = device
        self.spec = spec or FaultSpec()
        self.log = log
        self.name = name or f"faulty:{type(device).__name__}:{seed}"
        self._seed = int(seed)
        self._lock = threading.Lock()
        self._attempts: dict = {}
        self.readouts = 0
        self.injected = 0
        self._inner_counters = accepts_counters(device.measure_cost)
        self._inner_write_step = accepts_step(device.set_params)
        pair = getattr(device, "measure_pair", None)
        if callable(pair):
            self._inner_pair = pair
            self._inner_pair_counters = accepts_counters(pair)
            # instance attribute, so the capability probe
            # (callable(getattr(dev, "measure_pair", None))) mirrors
            # the inner device
            self.measure_pair = self._measure_pair_impl
        acc = getattr(device, "measure_accuracy", None)
        if callable(acc):
            self._inner_acc = acc
            self._inner_acc_step = accepts_step(acc)
            self.measure_accuracy = self._measure_accuracy_impl

    # -- pass-through surface ------------------------------------------------

    @property
    def writes(self) -> int:
        return int(getattr(self.device, "writes", 0))

    @property
    def meta(self):
        return getattr(self.device, "meta", None)

    def set_params(self, params, *, step=None):
        """Persistent write — never faulted, step forwarded when the
        inner device timestamps writes (drifting chips)."""
        if self._inner_write_step and step is not None:
            self.device.set_params(params, step=int(step))
        else:
            self.device.set_params(params)

    # -- fault engine --------------------------------------------------------

    def _draw(self, step, tag):
        """(kind, rng) for this attempt, or (None, None) when healthy.
        Counter-keyed: the SAME (seed, step, tag, attempt) always draws
        the same fault, a retry (attempt+1) draws fresh."""
        if step is None or tag is None:
            return None, None
        step, tag = int(step), int(tag)
        if not self.spec.active(step):
            return None, None
        with self._lock:
            key = (step, tag)
            attempt = self._attempts.get(key, 0)
            self._attempts[key] = attempt + 1
            if len(self._attempts) > 4096:   # keep the map bounded
                self._attempts = {k: v for k, v in self._attempts.items()
                                  if k[0] >= step - 2}
        if attempt < self.spec.fail_attempts:
            return "transient", None
        rng = np.random.default_rng(
            (self._seed, step, tag, attempt, 0xFA))
        u = rng.random()
        for kind, p in (("hang", self.spec.hang),
                        ("transient", self.spec.transient),
                        ("nan", self.spec.nan), ("inf", self.spec.inf),
                        ("stuck", self.spec.stuck),
                        ("outlier", self.spec.outlier)):
            if u < p:
                return kind, rng
            u -= p
        return None, None

    def _record(self, kind, step, tag, detail=""):
        with self._lock:
            self.injected += 1
        if self.log is not None:
            self.log.record(f"inject-{kind}", self.name, step=step,
                            tag=tag, detail=detail)

    def _raise_if_crash(self, kind, step, tag):
        if kind == "hang":
            self._record(kind, step, tag,
                         f"held worker {self.spec.hang_s}s")
            time.sleep(self.spec.hang_s)
            raise InjectedFault(
                f"{self.name}: link dropped (hang released after "
                f"{self.spec.hang_s}s) at step={step} tag={tag}")
        if kind == "transient":
            self._record(kind, step, tag)
            raise InjectedFault(
                f"{self.name}: transient instrument fault at "
                f"step={step} tag={tag}")

    # -- faulted readouts ----------------------------------------------------

    def measure_cost(self, batch, *, step=None, tag=None):
        with self._lock:
            self.readouts += 1
        kind, rng = self._draw(step, tag)
        self._raise_if_crash(kind, step, tag)
        if kind == "nan":
            self._record(kind, step, tag)
            return float("nan")
        if kind == "inf":
            self._record(kind, step, tag)
            return float("inf")
        if kind == "stuck":
            self._record(kind, step, tag)
            return float(self.spec.stuck_value)
        if self._inner_counters:
            c = self.device.measure_cost(batch, step=step, tag=tag)
        else:
            c = self.device.measure_cost(batch)
        if kind == "outlier":
            sign = 1.0 if rng.random() < 0.5 else -1.0
            self._record(kind, step, tag, f"spike {sign:+.0f}")
            return float(c) + sign * self.spec.outlier_scale
        return c

    def _measure_pair_impl(self, theta, batch, *, step=None, tag=None):
        with self._lock:
            self.readouts += 1
        kind, rng = self._draw(step, tag)
        self._raise_if_crash(kind, step, tag)
        if kind in ("nan", "inf"):
            self._record(kind, step, tag)
            v = float("nan") if kind == "nan" else float("inf")
            return v, v
        if kind == "stuck":
            self._record(kind, step, tag)
            return float(self.spec.stuck_value), float(self.spec.stuck_value)
        if self._inner_pair_counters:
            c_plus, c_minus = self._inner_pair(theta, batch, step=step,
                                               tag=tag)
        else:
            c_plus, c_minus = self._inner_pair(theta, batch)
        if kind == "outlier":
            sign = 1.0 if rng.random() < 0.5 else -1.0
            self._record(kind, step, tag, f"spike {sign:+.0f}")
            return float(c_plus) + sign * self.spec.outlier_scale, c_minus
        return c_plus, c_minus

    def _measure_accuracy_impl(self, batch, *, step=None):
        """Bench readout — never faulted (injection models the training
        I/O stream, not the experimenter's scope)."""
        if self._inner_acc_step:
            return self._inner_acc(batch, step=step)
        return self._inner_acc(batch)


# ---------------------------------------------------------------------------
# Fault policy + health registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Host-boundary tolerance configuration (frozen → hashable, safe to
    close over at trace time for the ``aggregate`` mode).

    Boundary knobs (host-side, applied per device transaction):

    * ``timeout_s`` — per-attempt deadline; a hung chip stalls its step
      by at most this (times retries), never forever.
    * ``retries`` / ``backoff_s`` / ``backoff_factor`` / ``backoff_max_s``
      — retry-with-exponential-backoff.  Retries re-run the WHOLE
      transaction (write + read) against the same (step, tag) counters,
      so a successful retry yields the identical counter-keyed readout a
      fault-free run would have seen — the traced trajectory stays a
      pure function of the gathered costs.
    * ``reject_nonfinite`` — treat NaN/Inf readouts as failures (retry,
      then mask) instead of letting them corrupt the averaged update.
    * ``quarantine_after`` — consecutive exhausted probe rounds before a
      chip is quarantined (0 = never).  ``reprobe_every`` — steps
      between readmission probes of a quarantined chip.

    Traced knob (read by ``core.probe_parallel`` at build time):

    * ``aggregate`` — ``"none"`` | ``"mad"`` (MAD-based outlier
      rejection over the 2k gathered cost scalars) | ``"trimmed"``
      (symmetric trimmed mean over the k C̃ values), with
      ``mad_threshold`` / ``trim_frac`` as their parameters.  This is
      the silent-corruption guard: a spiked-but-finite cost raises no
      exception at the boundary, only the statistics can reject it.
    """

    timeout_s: float = 30.0
    retries: int = 2
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_max_s: float = 2.0
    reject_nonfinite: bool = True
    quarantine_after: int = 0
    reprobe_every: int = 50
    aggregate: str = "none"
    mad_threshold: float = 6.0
    trim_frac: float = 0.2
    latency_alpha: float = 0.2     # EWMA weight for per-chip latency

    def __post_init__(self):
        if self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {self.timeout_s}")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.quarantine_after < 0:
            raise ValueError(f"quarantine_after must be >= 0, "
                             f"got {self.quarantine_after}")
        if self.reprobe_every < 1:
            raise ValueError(f"reprobe_every must be >= 1, "
                             f"got {self.reprobe_every}")
        if self.aggregate not in ("none", "mad", "trimmed"):
            raise ValueError(f"aggregate must be 'none', 'mad' or "
                             f"'trimmed', got {self.aggregate!r}")
        if not 0.0 <= self.trim_frac < 0.5:
            raise ValueError(f"trim_frac must be in [0, 0.5), "
                             f"got {self.trim_frac}")
        if not 0.0 < self.latency_alpha <= 1.0:
            raise ValueError(f"latency_alpha must be in (0, 1], "
                             f"got {self.latency_alpha}")

    def backoff_for(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (attempt >= 1)."""
        return min(self.backoff_s * self.backoff_factor ** (attempt - 1),
                   self.backoff_max_s)

    def round_deadline_s(self) -> float:
        """Worst-case wall-clock of one full probe round (all retries +
        backoffs) — the explicit outer-gather timeout."""
        backoffs = sum(self.backoff_for(a)
                       for a in range(1, self.retries + 1))
        return (self.retries + 1) * self.timeout_s + backoffs + 60.0


@dataclasses.dataclass
class ChipHealth:
    """Mutable per-chip health record.  Only that chip's supervisor
    thread touches it within a step (ordered callbacks serialize steps),
    so no per-field locking is needed."""

    chip: int
    name: str = ""
    successes: int = 0
    failures: int = 0              # exhausted probe rounds
    attempts_failed: int = 0       # individual failed attempts
    timeouts: int = 0
    consecutive_failures: int = 0
    ewma_latency_s: Optional[float] = None
    quarantined: bool = False
    quarantined_at: Optional[int] = None
    next_reprobe: Optional[int] = None
    readmissions: int = 0

    def record_success(self, latency_s: float, alpha: float) -> None:
        self.successes += 1
        self.consecutive_failures = 0
        if self.ewma_latency_s is None:
            self.ewma_latency_s = float(latency_s)
        else:
            self.ewma_latency_s = ((1.0 - alpha) * self.ewma_latency_s
                                   + alpha * float(latency_s))

    def record_failure(self) -> None:
        self.failures += 1
        self.consecutive_failures += 1

    def skip(self, step: int) -> bool:
        """Quarantined and not yet due a readmission probe — the fast
        path: no I/O at all this step."""
        return self.quarantined and (self.next_reprobe is None
                                     or int(step) < self.next_reprobe)

    def enter_quarantine(self, step: int, policy: FaultPolicy) -> None:
        self.quarantined = True
        self.quarantined_at = int(step)
        self.next_reprobe = int(step) + policy.reprobe_every

    def readmit(self) -> None:
        self.quarantined = False
        self.quarantined_at = None
        self.next_reprobe = None
        self.readmissions += 1


class FarmHealth:
    """The farm's per-chip health registry."""

    def __init__(self, names):
        self.chips = [ChipHealth(i, str(n)) for i, n in enumerate(names)]

    def live(self):
        return [h.chip for h in self.chips if not h.quarantined]

    def summary(self) -> dict:
        return {
            "quarantined": [h.chip for h in self.chips if h.quarantined],
            "readmissions": sum(h.readmissions for h in self.chips),
            "failures": sum(h.failures for h in self.chips),
            "timeouts": sum(h.timeouts for h in self.chips),
            "ewma_latency_s": {
                h.chip: round(h.ewma_latency_s, 6)
                for h in self.chips if h.ewma_latency_s is not None},
        }


# ---------------------------------------------------------------------------
# The one retry/timeout primitive
# ---------------------------------------------------------------------------


def guarded_call(pool, fn, args, *, policy: FaultPolicy, label: str,
                 log: Optional[FaultLog] = None,
                 health: Optional[ChipHealth] = None,
                 step=None, tag=None):
    """Run one device transaction under ``policy``: submit attempts to
    ``pool``, bound each by ``policy.timeout_s``, retry with exponential
    backoff, reject non-finite readouts.  Returns ``(value, latency_s,
    None)`` on success or ``(None, None, last_error)`` after exhausting
    retries.  Per-attempt failures are logged and counted on ``health``;
    step-level success/failure bookkeeping stays with the caller.
    """
    last: Optional[Exception] = None
    for attempt in range(policy.retries + 1):
        if attempt:
            time.sleep(policy.backoff_for(attempt))
        future = pool.submit(fn, *args)
        t0 = time.monotonic()
        try:
            out = future.result(timeout=policy.timeout_s)
        except _FuturesTimeout:
            future.cancel()
            last = ChipFaultError(
                f"{label}: no response within timeout_s="
                f"{policy.timeout_s}s at step={step} (attempt {attempt})")
            kind = "timeout"
        except Exception as e:   # noqa: BLE001 — any worker failure
            last, kind = e, "error"
        else:
            if policy.reject_nonfinite and not np.all(
                    np.isfinite(np.asarray(out, np.float64))):
                last = ChipFaultError(
                    f"{label}: non-finite readout {out!r} at step={step}")
                kind = "nonfinite"
            else:
                return out, time.monotonic() - t0, None
        if health is not None:
            health.attempts_failed += 1
            if kind == "timeout":
                health.timeouts += 1
        if log is not None:
            log.record(kind, label, step=step, tag=tag, attempt=attempt,
                       detail=str(last))
    return None, None, last
