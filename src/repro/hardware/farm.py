"""Chip farm: k external chips evaluating k probes concurrently (§6).

The paper's deployment endgame is a *farm of imperfect chips*: k devices,
each with its own fabrication defects and noise, each evaluating its own
perturbation probe, with the trainer averaging the k scalar error signals

    θ ← θ − η · (1/k) Σ_k C̃_k · θ̃_k / Δθ²

— k× probe-variance reduction at zero extra per-chip work (Oripov et al.
2025 show this axis is what makes perturbative training scale).  The
pure-JAX version of that picture is ``core.probe_parallel`` (shard_map
over a mesh axis); ``ChipFarm`` is the same math across a *process /
instrument* boundary the optimizer cannot trace into:

* ``read_cost_pairs(params, thetas, batch, step)`` lowers to ONE ordered
  ``io_callback`` per step that fans the k central-difference pairs out
  to the k devices on a thread pool and gathers all 2k cost scalars —
  the only values that ever cross back.
* Each chip sees the optimizer's (step, tag=2k/2k+1) counters when its
  readout accepts them, so counter-keyed device noise distinguishes
  every read and two identically-seeded runs are bit-identical.
* Devices with a differential probe line (``measure_pair``) pay one
  persistent base-θ write per pair; plain 2-method devices fall back to
  two perturbed-tree writes (see ``external.py``).

Everything host-side is NUMPY-PURE (JAX ops inside a host callback can
deadlock the CPU client — see ``external.py``); each chip's noise is its
own per-device stream, so the thread-pool schedule cannot perturb the
trajectory.
"""
from __future__ import annotations

import weakref
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .base import Plant, PlantMeta
from .devices import DriftingAnalogChip, SimulatedAnalogChip
from .external import (_io_callback, accepts_counters, accepts_step,
                       check_device)


def _np_axpy(sign, theta, params):
    """params + sign·theta, host-side numpy (never dispatches JAX ops)."""
    return jax.tree_util.tree_map(
        lambda w, t: np.asarray(w, np.float32)
        + np.float32(sign) * np.asarray(t, np.float32), params, theta)


class ChipFarm(Plant):
    """k opaque devices behind one host boundary, probed concurrently.

    Driven exclusively by ``repro.driver("probe_parallel_external", cfg,
    plant=farm)`` — the farm has no single-scalar ``read_cost`` (wrap one
    device in ``ExternalPlant`` for the single-chip drivers).
    """

    def __init__(self, devices: Sequence[Any], *,
                 meta: Optional[PlantMeta] = None,
                 max_workers: Optional[int] = None):
        devices = list(devices)
        if not devices:
            raise ValueError("ChipFarm needs at least one device")
        for device in devices:
            check_device(device)
        if _io_callback is None:        # pragma: no cover - old jax
            raise RuntimeError("ChipFarm needs jax.experimental."
                               "io_callback (jax >= 0.4.9)")
        self.devices = devices
        # capability inspection once per device, never on the hot loop
        self._caps = []
        for device in devices:
            pair = getattr(device, "measure_pair", None)
            pair = pair if callable(pair) else None
            self._caps.append({
                "counters": accepts_counters(device.measure_cost),
                "pair": pair,
                "pair_counters": pair is not None and accepts_counters(pair),
                "write_step": accepts_step(device.set_params),
            })
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers or len(devices),
            thread_name_prefix="chip-farm")
        # reclaim the worker threads when the farm is garbage-collected —
        # sweeps build many farms per process and idle non-daemon threads
        # would otherwise accumulate until interpreter exit
        self._finalizer = weakref.finalize(self, self._pool.shutdown,
                                           wait=False)
        self.meta = meta or PlantMeta(name=f"chip-farm-{len(devices)}",
                                      external=True, chips=len(devices))

    def close(self) -> None:
        """Shut the thread pool down now (also runs at GC)."""
        self._finalizer()

    @property
    def n_chips(self) -> int:
        return len(self.devices)

    # -- host side (numpy-pure, runs on the callback + pool threads) --------

    def _set_params(self, i, params, step=None):
        """One chip's persistent write, timestamped for step-capable
        (drifting) devices."""
        if step is not None and self._caps[i]["write_step"]:
            self.devices[i].set_params(params, step=int(step))
        else:
            self.devices[i].set_params(params)

    def _chip_pair(self, i, params, theta, batch, step):
        """One chip's central pair → (C₊, C₋).  Tags (2i, 2i+1) mirror the
        mesh driver's per-pod tag layout."""
        device, caps = self.devices[i], self._caps[i]
        tag = 2 * i
        if caps["pair"] is not None:
            self._set_params(i, params, step)  # ONE base-θ write per pair
            if caps["pair_counters"]:
                return caps["pair"](theta, batch, step=step, tag=tag)
            return caps["pair"](theta, batch)
        # plain 2-method device: two perturbed writes + two reads
        def read(perturbed, t):
            self._set_params(i, perturbed, step)
            if caps["counters"]:
                return device.measure_cost(batch, step=step, tag=t)
            return device.measure_cost(batch)
        return (read(_np_axpy(1.0, theta, params), tag),
                read(_np_axpy(-1.0, theta, params), tag + 1))

    def _host_pairs(self, params, thetas, batch, step):
        step = int(step)
        futures = [
            self._pool.submit(self._chip_pair, i, params, thetas[i],
                              batch, step)
            for i in range(self.n_chips)
        ]
        # gather in chip order — the schedule cannot reorder results
        return np.asarray([f.result() for f in futures], np.float32)

    def _host_write(self, params, step):
        for f in [self._pool.submit(self._set_params, i, params, step)
                  for i in range(self.n_chips)]:
            f.result()
        return np.int32(0)

    # -- traced side ---------------------------------------------------------

    def read_cost_pairs(self, params, thetas, batch, *, step):
        """All k chips' antithetic pairs in one ordered host round-trip.
        ``thetas`` is the list of k perturbation trees (chip k probes its
        own θ̃_k); returns an f32[k, 2] array of (C₊, C₋) per chip."""
        if len(thetas) != self.n_chips:
            raise ValueError(f"{len(thetas)} probe trees for "
                             f"{self.n_chips} chips")
        return _io_callback(
            self._host_pairs,
            jax.ShapeDtypeStruct((self.n_chips, 2), jnp.float32),
            params, thetas, batch, jnp.asarray(step, jnp.int32),
            ordered=True)

    def read_cost(self, params, batch, *, step, tag: int = 0):
        raise NotImplementedError(
            "ChipFarm has no single-chip cost read — drive it with "
            "repro.driver('probe_parallel_external', cfg, plant=farm), or "
            "wrap one device in ExternalPlant for the single-chip drivers")

    def write_params(self, params, *, step, prev=None):
        """Commit the post-update parameters to EVERY chip (open-loop, as
        in ``ExternalPlant``: per-chip write noise stays invisible)."""
        _io_callback(self._host_write, jax.ShapeDtypeStruct((), jnp.int32),
                     params, jnp.asarray(step, jnp.int32), ordered=True)
        return params

    # -- evaluation harness (eager, never inside the traced step) ------------

    def measure_accuracy(self, params, batch) -> float:
        """Mean on-chip accuracy across the farm after committing
        ``params`` — the experimenter's bench readout, not training I/O."""
        params = jax.tree_util.tree_map(
            lambda x: np.asarray(x, np.float32), params)

        def one(device):
            device.set_params(params)
            return device.measure_accuracy(batch)

        futures = [self._pool.submit(one, d) for d in self.devices
                   if callable(getattr(d, "measure_accuracy", None))]
        if not futures:
            raise NotImplementedError("no device exposes measure_accuracy")
        return float(np.mean([f.result() for f in futures]))

    @property
    def total_writes(self) -> int:
        """Summed ``writes`` counters of counting devices (test/telemetry)."""
        return sum(int(getattr(d, "writes", 0)) for d in self.devices)


def simulated_chip_farm(k: int, sizes: Sequence[int] = (49, 4, 4), *,
                        base_seed: int = 0, sigma_a: float = 0.15,
                        sigma_theta: float = 0.01, sigma_c: float = 1e-4,
                        drift_rate: float = 0.0,
                        drift_rates: Optional[Sequence[float]] = None,
                        drift_mode: str = "walk", drift_tau: float = 0.0,
                        max_workers: Optional[int] = None) -> ChipFarm:
    """A farm of k ``SimulatedAnalogChip``s with DISTINCT device seeds —
    k different physical chips (different defect draws, different noise
    streams), the same instrument replicated k× on the bench.

    ``drift_rate`` (every chip) or ``drift_rates`` (one σ_d per chip — a
    HETEROGENEOUS farm, where chip i ages at its own rate) build
    ``DriftingAnalogChip``s instead; aging stays per-device-seed keyed,
    so two chips with different rates remain distinguishable across a
    checkpoint/resume.  Zero-rate chips stay plain (bit-identical to the
    drift-free farm)."""
    if k < 1:
        raise ValueError(f"need at least one chip, got k={k}")
    if drift_rates is None:
        rates = [float(drift_rate)] * k
    else:
        rates = [float(r) for r in drift_rates]
        if len(rates) != k:
            raise ValueError(f"{len(rates)} drift_rates for {k} chips")
    devices = [
        SimulatedAnalogChip(sizes, seed=base_seed + i, sigma_a=sigma_a,
                            sigma_theta=sigma_theta, sigma_c=sigma_c)
        if not (rates[i] or drift_tau) else
        DriftingAnalogChip(sizes, seed=base_seed + i, sigma_a=sigma_a,
                           sigma_theta=sigma_theta, sigma_c=sigma_c,
                           drift_mode=drift_mode, drift_rate=rates[i],
                           drift_tau=drift_tau)
        for i in range(k)
    ]
    drifting = any(rates) or drift_tau
    return ChipFarm(
        devices, max_workers=max_workers,
        meta=PlantMeta(name=f"sim-farm-{k}" + ("-drift" if drifting else ""),
                       cost_noise=sigma_c, write_noise=sigma_theta,
                       sigma_a=sigma_a, external=True, chips=k,
                       drift_mode=drift_mode if drifting else None,
                       drift_rate=max(rates), drift_tau=drift_tau))
