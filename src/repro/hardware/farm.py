"""Chip farm: k external chips evaluating k probes concurrently (§6).

The paper's deployment endgame is a *farm of imperfect chips*: k devices,
each with its own fabrication defects and noise, each evaluating its own
perturbation probe, with the trainer averaging the k scalar error signals

    θ ← θ − η · (1/k) Σ_k C̃_k · θ̃_k / Δθ²

— k× probe-variance reduction at zero extra per-chip work (Oripov et al.
2025 show this axis is what makes perturbative training scale).  The
pure-JAX version of that picture is ``core.probe_parallel`` (shard_map
over a mesh axis); ``ChipFarm`` is the same math across a *process /
instrument* boundary the optimizer cannot trace into:

* ``read_cost_pairs(params, thetas, batch, step)`` lowers to ONE ordered
  ``io_callback`` per step that fans the k central-difference pairs out
  to the k devices on a thread pool and gathers all 2k cost scalars plus
  a per-chip validity mask — the only values that ever cross back.
* Each chip sees the optimizer's (step, tag=2k/2k+1) counters when its
  readout accepts them, so counter-keyed device noise distinguishes
  every read and two identically-seeded runs are bit-identical.
* Devices with a differential probe line (``measure_pair``) pay one
  persistent base-θ write per pair; plain 2-method devices fall back to
  two perturbed-tree writes (see ``external.py``).

**Fault tolerance** (``fault_policy=hardware.FaultPolicy(...)``): real
instruments hang, crash and return garbage, and k chips multiply that
fault surface by k.  Under a policy every chip's probe transaction runs
bounded by ``timeout_s`` with retry-and-exponential-backoff; a chip that
exhausts its retries (or returns non-finite costs) is MASKED for that
step rather than unwinding the jitted step: ``read_cost_pairs`` always
returns the fixed-shape pair ``(f32[k, 2] costs, bool[k] valid)`` so the
traced program stays static-shape.  Invalid chips carry NaN costs and
``valid[k]=False``.  Persistently failing chips (``quarantine_after``
consecutive exhausted rounds) are quarantined — skipped with NO I/O on
the probe path, still receiving parameter writes — and re-probed every
``reprobe_every`` steps for readmission; a readmitted chip's
counter-keyed noise stream is untouched (noise is a function of
(step, tag), not of how many reads happened in between).

**Mask semantics / η-rescaling rule** (``core.probe_parallel``): the
traced step zeroes invalid chips' C̃_k and keeps the per-chip coefficient
``−η/(k·Δθ²)`` unchanged.  Because η is tuned ∝ k (the farm's k× probe
averaging supports a k× larger step), dropping a chip's term at fixed
η/k IS the "rescale η by the live chip count" rule applied per chip:
the surviving chips' update is exactly the (η·k_live/k)-scaled masked
average.  With all chips valid the masked path is bit-identical to the
unmasked one (``where(True, x, 0) == x`` bitwise).

Even WITHOUT a policy, gathers at the host boundary pass a generous
default timeout (``faults.DEFAULT_TIMEOUT_S``) and re-raise worker
exceptions as ``ChipFaultError`` with the chip index and device name
attached — a hung instrument surfaces as a diagnosable error instead of
an un-interruptible deadlock inside an ordered callback.

Everything host-side is NUMPY-PURE (JAX ops inside a host callback can
deadlock the CPU client — see ``external.py``); each chip's noise is its
own per-device stream, so the thread-pool schedule cannot perturb the
trajectory.
"""
from __future__ import annotations

import weakref
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .base import Plant, PlantMeta
from .devices import DriftingAnalogChip, SimulatedAnalogChip
from .external import (_io_callback, accepts_counters, accepts_step,
                       check_device)
from .faults import (DEFAULT_TIMEOUT_S, ChipFaultError, FarmHealth,
                     FaultLog, FaultPolicy, FaultSpec, FaultyChip,
                     guarded_call)

#: Fixed-shape placeholder for a masked-out chip's cost pair — NaN, so a
#: bug that consumes an invalid pair without checking the mask poisons
#: the update loudly instead of silently biasing it.
_INVALID_PAIR = np.array([np.nan, np.nan], np.float32)


def _np_axpy(sign, theta, params):
    """params + sign·theta, host-side numpy (never dispatches JAX ops)."""
    return jax.tree_util.tree_map(
        lambda w, t: np.asarray(w, np.float32)
        + np.float32(sign) * np.asarray(t, np.float32), params, theta)


class ChipFarm(Plant):
    """k opaque devices behind one host boundary, probed concurrently.

    Driven exclusively by ``repro.driver("probe_parallel_external", cfg,
    plant=farm)`` — the farm has no single-scalar ``read_cost`` (wrap one
    device in ``ExternalPlant`` for the single-chip drivers).

    ``fault_policy`` arms the host boundary: per-attempt timeouts,
    retries with exponential backoff, per-chip masking on exhaustion,
    quarantine/readmission via the ``health`` registry, and the robust
    aggregation mode ``core.probe_parallel`` reads at build time.  See
    the module docstring for the mask semantics and η-rescaling rule.
    """

    def __init__(self, devices: Sequence[Any], *,
                 meta: Optional[PlantMeta] = None,
                 max_workers: Optional[int] = None,
                 fault_policy: Optional[FaultPolicy] = None,
                 fault_log: Optional[FaultLog] = None):
        devices = list(devices)
        if not devices:
            raise ValueError("ChipFarm needs at least one device")
        for device in devices:
            check_device(device)
        if _io_callback is None:        # pragma: no cover - old jax
            raise RuntimeError("ChipFarm needs jax.experimental."
                               "io_callback (jax >= 0.4.9)")
        if fault_policy is not None and not isinstance(fault_policy,
                                                       FaultPolicy):
            raise TypeError(f"fault_policy must be a hardware.FaultPolicy, "
                            f"got {type(fault_policy).__name__}")
        self.devices = devices
        self.policy = fault_policy
        self.fault_log = fault_log if fault_log is not None else FaultLog()
        self._names = [getattr(d, "name", None) or type(d).__name__
                       for d in devices]
        self.health = FarmHealth(self._names)
        # capability inspection once per device, never on the hot loop
        self._caps = []
        for device in devices:
            pair = getattr(device, "measure_pair", None)
            pair = pair if callable(pair) else None
            acc = getattr(device, "measure_accuracy", None)
            acc = acc if callable(acc) else None
            self._caps.append({
                "counters": accepts_counters(device.measure_cost),
                "pair": pair,
                "pair_counters": pair is not None and accepts_counters(pair),
                "write_step": accepts_step(device.set_params),
                "acc": acc,
                "acc_step": acc is not None and accepts_step(acc),
            })
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers or len(devices),
            thread_name_prefix="chip-farm")
        # reclaim the worker threads when the farm is garbage-collected —
        # sweeps build many farms per process and idle non-daemon threads
        # would otherwise accumulate until interpreter exit
        self._finalizer = weakref.finalize(self, self._pool.shutdown,
                                           wait=False)
        self._attempt_pool = None
        if fault_policy is not None:
            # two-level pools: supervisors block on attempt futures, and a
            # hung attempt holds its worker until the instrument releases
            # it — spare attempt threads keep retries and later steps from
            # starving behind a zombie
            self._attempt_pool = ThreadPoolExecutor(
                max_workers=len(devices) * (fault_policy.retries + 2),
                thread_name_prefix="chip-farm-attempt")
            self._attempt_finalizer = weakref.finalize(
                self, self._attempt_pool.shutdown, wait=False)
        self.meta = meta or PlantMeta(name=f"chip-farm-{len(devices)}",
                                      external=True, chips=len(devices),
                                      fault_tolerant=fault_policy is not None)

    def close(self) -> None:
        """Shut the thread pools down now (also runs at GC)."""
        self._finalizer()
        if self._attempt_pool is not None:
            self._attempt_finalizer()

    @property
    def n_chips(self) -> int:
        return len(self.devices)

    def _label(self, i: int) -> str:
        return f"chip {i} ({self._names[i]})"

    def fault_summary(self) -> dict:
        """Fault-tolerance telemetry: event counts by kind plus the
        health registry summary.  ``{"events": 0, ...}`` means a clean
        run."""
        return {"events": len(self.fault_log),
                "by_kind": self.fault_log.counts(),
                **self.health.summary()}

    # -- host side (numpy-pure, runs on the callback + pool threads) --------

    def _set_params(self, i, params, step=None):
        """One chip's persistent write, timestamped for step-capable
        (drifting) devices."""
        if step is not None and self._caps[i]["write_step"]:
            self.devices[i].set_params(params, step=int(step))
        else:
            self.devices[i].set_params(params)

    def _chip_pair(self, i, params, theta, batch, step):
        """One chip's central pair → (C₊, C₋).  Tags (2i, 2i+1) mirror the
        mesh driver's per-pod tag layout."""
        device, caps = self.devices[i], self._caps[i]
        tag = 2 * i
        if caps["pair"] is not None:
            self._set_params(i, params, step)  # ONE base-θ write per pair
            if caps["pair_counters"]:
                return caps["pair"](theta, batch, step=step, tag=tag)
            return caps["pair"](theta, batch)
        # plain 2-method device: two perturbed writes + two reads
        def read(perturbed, t):
            self._set_params(i, perturbed, step)
            if caps["counters"]:
                return device.measure_cost(batch, step=step, tag=t)
            return device.measure_cost(batch)
        return (read(_np_axpy(1.0, theta, params), tag),
                read(_np_axpy(-1.0, theta, params), tag + 1))

    def _chip_pair_robust(self, i, params, theta, batch, step):
        """One chip's probe round under the fault policy (supervisor
        thread): quarantine fast-path, guarded attempts with retries,
        health bookkeeping.  Returns ``(f32[2] pair, valid)`` — never
        raises."""
        policy, h = self.policy, self.health.chips[i]
        if h.skip(step):
            # quarantined, not yet due a readmission probe: NO I/O
            return _INVALID_PAIR, False
        out, latency, err = guarded_call(
            self._attempt_pool, self._chip_pair,
            (i, params, theta, batch, step),
            policy=policy, label=self._label(i), log=self.fault_log,
            health=h, step=step, tag=2 * i)
        if err is None:
            if h.quarantined:
                h.readmit()
                self.fault_log.record("readmit", self._label(i), step=step)
            h.record_success(latency, policy.latency_alpha)
            return np.asarray(out, np.float32), True
        h.record_failure()
        if h.quarantined:
            # failed readmission probe — back off until the next one
            h.next_reprobe = int(step) + policy.reprobe_every
        elif policy.quarantine_after and \
                h.consecutive_failures >= policy.quarantine_after:
            h.enter_quarantine(step, policy)
            self.fault_log.record(
                "quarantine", self._label(i), step=step,
                detail=f"{h.consecutive_failures} consecutive failures")
        return _INVALID_PAIR, False

    def _host_pairs(self, params, thetas, batch, step):
        step = int(step)
        k = self.n_chips
        if self.policy is None:
            futures = [
                self._pool.submit(self._chip_pair, i, params, thetas[i],
                                  batch, step)
                for i in range(k)
            ]
            pairs = []
            # gather in chip order — the schedule cannot reorder results
            for i, f in enumerate(futures):
                try:
                    pairs.append(f.result(timeout=DEFAULT_TIMEOUT_S))
                except Exception as e:
                    raise ChipFaultError(
                        f"{self._label(i)}: probe failed at step={step}: "
                        f"{e!r} — pass fault_policy=FaultPolicy(...) to "
                        f"retry and mask instead of failing the step"
                    ) from e
            return np.asarray(pairs, np.float32), np.ones(k, bool)
        futures = [
            self._pool.submit(self._chip_pair_robust, i, params, thetas[i],
                              batch, step)
            for i in range(k)
        ]
        deadline = self.policy.round_deadline_s()
        costs = np.empty((k, 2), np.float32)
        valid = np.zeros(k, bool)
        for i, f in enumerate(futures):
            try:
                pair, ok = f.result(timeout=deadline)
            except Exception as e:  # supervisor failure — mask, keep going
                self.fault_log.record("error", self._label(i), step=step,
                                      detail=f"supervisor: {e}")
                pair, ok = _INVALID_PAIR, False
            costs[i] = pair
            valid[i] = ok
        return costs, valid

    def _host_write(self, params, step):
        step = int(step)
        futures = [self._pool.submit(self._set_params, i, params, step)
                   for i in range(self.n_chips)]
        for i, f in enumerate(futures):
            try:
                f.result(timeout=DEFAULT_TIMEOUT_S)
            except Exception as e:
                if self.policy is None:
                    raise ChipFaultError(
                        f"{self._label(i)}: parameter write failed at "
                        f"step={step}: {e!r}") from e
                # under a policy a failed write must not unwind the step;
                # the chip keeps its stale parameters and the next probe
                # round surfaces (and masks) the damage
                self.fault_log.record("write-error", self._label(i),
                                      step=step, detail=str(e))
        return np.int32(0)

    # -- traced side ---------------------------------------------------------

    def read_cost_pairs(self, params, thetas, batch, *, step):
        """All k chips' antithetic pairs in one ordered host round-trip.
        ``thetas`` is the list of k perturbation trees (chip k probes its
        own θ̃_k); returns ``(f32[k, 2] costs, bool[k] valid)``.  Without
        a fault policy ``valid`` is all-True (any failure raises); with
        one, masked chips carry NaN costs and ``valid=False``."""
        if len(thetas) != self.n_chips:
            raise ValueError(f"{len(thetas)} probe trees for "
                             f"{self.n_chips} chips")
        return _io_callback(
            self._host_pairs,
            (jax.ShapeDtypeStruct((self.n_chips, 2), jnp.float32),
             jax.ShapeDtypeStruct((self.n_chips,), jnp.bool_)),
            params, thetas, batch, jnp.asarray(step, jnp.int32),
            ordered=True)

    def read_cost(self, params, batch, *, step, tag: int = 0):
        raise NotImplementedError(
            "ChipFarm has no single-chip cost read — drive it with "
            "repro.driver('probe_parallel_external', cfg, plant=farm), or "
            "wrap one device in ExternalPlant for the single-chip drivers")

    def write_params(self, params, *, step, prev=None):
        """Commit the post-update parameters to EVERY chip (open-loop, as
        in ``ExternalPlant``: per-chip write noise stays invisible).
        Quarantined chips are still written — writes are cheap and keep
        them current for readmission."""
        _io_callback(self._host_write, jax.ShapeDtypeStruct((), jnp.int32),
                     params, jnp.asarray(step, jnp.int32), ordered=True)
        return params

    # -- evaluation harness (eager, never inside the traced step) ------------

    def measure_accuracy(self, params, batch, *, step=None) -> float:
        """Mean on-chip accuracy across the farm after committing
        ``params`` — the experimenter's bench readout, not training I/O.

        Writes route through ``_set_params`` with ``step`` forwarded, so
        eval-time writes to step-capable drifting chips are timestamped
        (a bench readout of an aging chip must not silently reset its
        age).  Under a fault policy, quarantined chips are excluded from
        the bench average and per-chip errors are logged and skipped
        (falling back to all chips if every one is quarantined)."""
        params = jax.tree_util.tree_map(
            lambda x: np.asarray(x, np.float32), params)

        def one(i):
            self._set_params(i, params, step)
            if self._caps[i]["acc_step"]:
                return self._caps[i]["acc"](
                    batch, step=None if step is None else int(step))
            return self._caps[i]["acc"](batch)

        capable = [i for i in range(self.n_chips)
                   if self._caps[i]["acc"] is not None]
        if not capable:
            raise NotImplementedError("no device exposes measure_accuracy")
        indices = capable
        if self.policy is not None:
            live = [i for i in capable
                    if not self.health.chips[i].quarantined]
            indices = live or capable
        futures = {i: self._pool.submit(one, i) for i in indices}
        values = []
        for i, f in futures.items():
            try:
                values.append(f.result(timeout=DEFAULT_TIMEOUT_S))
            except Exception as e:
                if self.policy is None:
                    raise ChipFaultError(
                        f"{self._label(i)}: accuracy readout failed: "
                        f"{e!r}") from e
                self.fault_log.record("accuracy-error", self._label(i),
                                      step=step, detail=str(e))
        if not values:
            raise ChipFaultError(
                "no chip produced an accuracy readout "
                f"(all {len(indices)} attempts failed)")
        return float(np.mean(values))

    @property
    def total_writes(self) -> int:
        """Summed ``writes`` counters of counting devices (test/telemetry)."""
        return sum(int(getattr(d, "writes", 0)) for d in self.devices)


def simulated_chip_farm(k: int, sizes: Sequence[int] = (49, 4, 4), *,
                        base_seed: int = 0, sigma_a: float = 0.15,
                        sigma_theta: float = 0.01, sigma_c: float = 1e-4,
                        drift_rate: float = 0.0,
                        drift_rates: Optional[Sequence[float]] = None,
                        drift_mode: str = "walk", drift_tau: float = 0.0,
                        max_workers: Optional[int] = None,
                        faults=None, fault_seed: int = 1000,
                        fault_policy: Optional[FaultPolicy] = None
                        ) -> ChipFarm:
    """A farm of k ``SimulatedAnalogChip``s with DISTINCT device seeds —
    k different physical chips (different defect draws, different noise
    streams), the same instrument replicated k× on the bench.

    ``drift_rate`` (every chip) or ``drift_rates`` (one σ_d per chip — a
    HETEROGENEOUS farm, where chip i ages at its own rate) build
    ``DriftingAnalogChip``s instead; aging stays per-device-seed keyed,
    so two chips with different rates remain distinguishable across a
    checkpoint/resume.  Zero-rate chips stay plain (bit-identical to the
    drift-free farm).

    ``faults`` injects counter-keyed faults: a single ``FaultSpec``
    (every chip, per-chip fault seeds ``fault_seed + i``) or a k-long
    sequence with ``None`` entries for healthy chips.  ``fault_policy``
    arms the boundary (timeouts/retries/masking/quarantine) — the two
    compose but neither requires the other: inject faults with no policy
    to demonstrate the failure mode, or arm a policy over healthy chips
    at near-zero cost."""
    if k < 1:
        raise ValueError(f"need at least one chip, got k={k}")
    if drift_rates is None:
        rates = [float(drift_rate)] * k
    else:
        rates = [float(r) for r in drift_rates]
        if len(rates) != k:
            raise ValueError(f"{len(rates)} drift_rates for {k} chips")
    devices = [
        SimulatedAnalogChip(sizes, seed=base_seed + i, sigma_a=sigma_a,
                            sigma_theta=sigma_theta, sigma_c=sigma_c)
        if not (rates[i] or drift_tau) else
        DriftingAnalogChip(sizes, seed=base_seed + i, sigma_a=sigma_a,
                           sigma_theta=sigma_theta, sigma_c=sigma_c,
                           drift_mode=drift_mode, drift_rate=rates[i],
                           drift_tau=drift_tau)
        for i in range(k)
    ]
    fault_log = FaultLog()
    if faults is not None:
        specs = list(faults) if isinstance(faults, (list, tuple)) \
            else [faults] * k
        if len(specs) != k:
            raise ValueError(f"{len(specs)} fault specs for {k} chips")
        for spec in specs:
            if spec is not None and not isinstance(spec, FaultSpec):
                raise TypeError(f"faults entries must be FaultSpec or "
                                f"None, got {type(spec).__name__}")
        devices = [
            FaultyChip(d, spec, seed=fault_seed + i, log=fault_log)
            if spec is not None else d
            for i, (d, spec) in enumerate(zip(devices, specs))
        ]
    drifting = any(rates) or drift_tau
    return ChipFarm(
        devices, max_workers=max_workers, fault_policy=fault_policy,
        fault_log=fault_log,
        meta=PlantMeta(name=f"sim-farm-{k}" + ("-drift" if drifting else ""),
                       cost_noise=sigma_c, write_noise=sigma_theta,
                       sigma_a=sigma_a, external=True, chips=k,
                       drift_mode=drift_mode if drifting else None,
                       drift_rate=max(rates), drift_tau=drift_tau,
                       fault_tolerant=fault_policy is not None))
