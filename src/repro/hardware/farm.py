"""Chip farm: k external chips evaluating k probes concurrently (§6).

The paper's deployment endgame is a *farm of imperfect chips*: k devices,
each with its own fabrication defects and noise, each evaluating its own
perturbation probe, with the trainer averaging the k scalar error signals

    θ ← θ − η · (1/k) Σ_k C̃_k · θ̃_k / Δθ²

— k× probe-variance reduction at zero extra per-chip work (Oripov et al.
2025 show this axis is what makes perturbative training scale).  The
pure-JAX version of that picture is ``core.probe_parallel`` (shard_map
over a mesh axis); ``ChipFarm`` is the same math across a *process /
instrument* boundary the optimizer cannot trace into:

* ``read_cost_pairs(params, thetas, batch, step)`` lowers to ONE ordered
  ``io_callback`` per step that fans the k central-difference pairs out
  to the k devices and gathers all 2k cost scalars plus a per-chip
  validity mask — the only values that ever cross back.
* Each chip sees the optimizer's (step, tag=2k/2k+1) counters when its
  readout accepts them, so counter-keyed device noise distinguishes
  every read and two identically-seeded runs are bit-identical.
* Devices with a differential probe line (``measure_pair``) pay one
  persistent base-θ write per pair; plain 2-method devices fall back to
  two perturbed-tree writes (see ``external.py``).
* ``shard_batch=True`` feeds chip i the i-th contiguous leading-dim
  slice of each probe batch instead of the whole batch — the farm twin
  of the mesh driver's ``P("pod")`` batch placement, closing the
  every-chip-sees-the-same-data gap.  Probe I/O shrinks k× and the
  averaged C̃ estimates ∇(mean of the per-shard costs), the same target
  a batch-sharded k-pod mesh trains; ``measure_accuracy`` still
  evaluates every chip on the FULL bench batch.

**Execution backends** (``backend="thread" | "process" | "serial" |
"cluster"`` or a ``FarmBackend`` instance — see ``hardware/backend/``):
the farm owns only the MGD math and this host-boundary contract; WHERE a
chip's transactions run is the backend's job.  ``thread`` (default)
keeps live device instances in-process, one runner thread per chip;
``process`` runs one worker process per chip built from picklable
``DeviceSpec`` entries — GIL-bound instrument drivers scale to k and a
hung worker is actually KILLED rather than abandoned; ``serial`` is the
inline parity oracle; ``cluster`` is the wire-protocol stub.  Backends
only move execution: device noise is counter-keyed, so every backend
produces the bit-identical cost stream.

**Double-buffered pipeline** (``pipeline=True``): ``write_params``
enqueues the k per-chip writes and returns without waiting, so step
N+1's writes overlap step N's traced compute, and the next probe round
submits its pairs BEHIND the writes (per-chip FIFO — the device is
always written-then-probed in program order) before resolving either.
The schedule cannot perturb values — readout noise is (seed, step,
tag)-keyed — but state-dependent boundaries must not run with writes in
flight: ``fence()`` drains them, and the farm self-fences before
``measure_accuracy`` / ``total_writes``; ``train_mgd`` fences before
checkpoints, evals and recalibration so resume stays bit-exact.

**Fault tolerance** (``fault_policy=hardware.FaultPolicy(...)``): real
instruments hang, crash and return garbage, and k chips multiply that
fault surface by k.  Under a policy every chip's probe transaction runs
bounded by ``timeout_s`` with retry-and-exponential-backoff; a chip that
exhausts its retries (or returns non-finite costs) is MASKED for that
step rather than unwinding the jitted step: ``read_cost_pairs`` always
returns the fixed-shape pair ``(f32[k, 2] costs, bool[k] valid)`` so the
traced program stays static-shape.  Invalid chips carry NaN costs and
``valid[k]=False``.  Persistently failing chips (``quarantine_after``
consecutive exhausted rounds) are quarantined — skipped with NO I/O on
the probe path, still receiving parameter writes — and re-probed every
``reprobe_every`` steps for readmission; a readmitted chip's
counter-keyed noise stream is untouched (noise is a function of
(step, tag), not of how many reads happened in between).  A timed-out
attempt ABANDONS the chip's worker through the backend: the thread
backend parks the zombie and replaces the runner, the process backend
kills the worker process and respawns it from the spec.  Health,
quarantine and the ``FaultLog`` all live HOST-side; process workers
ship their injected-fault events back with each reply.

**Mask semantics / η-rescaling rule** (``core.probe_parallel``): the
traced step zeroes invalid chips' C̃_k and keeps the per-chip coefficient
``−η/(k·Δθ²)`` unchanged.  Because η is tuned ∝ k (the farm's k× probe
averaging supports a k× larger step), dropping a chip's term at fixed
η/k IS the "rescale η by the live chip count" rule applied per chip:
the surviving chips' update is exactly the (η·k_live/k)-scaled masked
average.  With all chips valid the masked path is bit-identical to the
unmasked one (``where(True, x, 0) == x`` bitwise).

Even WITHOUT a policy, gathers at the host boundary pass a generous
default timeout (``faults.DEFAULT_TIMEOUT_S``) and re-raise worker
exceptions as ``ChipFaultError`` with the chip index and device name
attached — a hung instrument surfaces as a diagnosable error instead of
an un-interruptible deadlock inside an ordered callback.

Everything host-side is NUMPY-PURE (JAX ops inside a host callback can
deadlock the CPU client — see ``external.py``); each chip's noise is its
own per-device stream, so the backend schedule cannot perturb the
trajectory.
"""
from __future__ import annotations

import time
import weakref
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FuturesTimeout
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import check_chip_shardable, shard_chip_batch

from .backend import DeviceSpec, FarmBackend, make_backend
from .base import Plant, PlantMeta
from .devices import DriftingAnalogChip, SimulatedAnalogChip
from .external import _io_callback, check_device
from .faults import (DEFAULT_TIMEOUT_S, ChipFaultError, FarmHealth,
                     FaultLog, FaultPolicy, FaultSpec, FaultyChip)

#: Fixed-shape placeholder for a masked-out chip's cost pair — NaN, so a
#: bug that consumes an invalid pair without checking the mask poisons
#: the update loudly instead of silently biasing it.
_INVALID_PAIR = np.array([np.nan, np.nan], np.float32)


def _teardown(backend: FarmBackend,
              supervisors: Optional[ThreadPoolExecutor]) -> None:
    """Farm teardown (close() and the GC finalizer): backend workers
    first, then the supervisor pool — sweeps build many farms per
    process and leaked threads/processes would otherwise accumulate
    until interpreter exit."""
    backend.shutdown(wait=False)
    if supervisors is not None:
        supervisors.shutdown(wait=False)


class ChipFarm(Plant):
    """k opaque devices behind one host boundary, probed concurrently.

    Driven exclusively by ``repro.driver("probe_parallel_external", cfg,
    plant=farm)`` — the farm has no single-scalar ``read_cost`` (wrap one
    device in ``ExternalPlant`` for the single-chip drivers).

    ``devices`` entries are live device instances (thread/serial
    backends) or picklable ``DeviceSpec``s (required by the process and
    cluster backends, accepted by all).  ``backend`` picks who executes
    the transactions; ``pipeline=True`` double-buffers parameter writes
    against the next probe round; ``shard_batch=True`` slices each probe
    batch into contiguous per-chip shards (mesh-``P("pod")`` layout).  ``fault_policy`` arms the host
    boundary: per-attempt timeouts, retries with exponential backoff,
    per-chip masking on exhaustion, quarantine/readmission via the
    ``health`` registry, and the robust aggregation mode
    ``core.probe_parallel`` reads at build time.  See the module
    docstring for the mask semantics and η-rescaling rule.

    The farm is a context manager; ``close()`` is idempotent and also
    runs at garbage collection.  ``max_workers`` is accepted for
    backward compatibility and ignored — execution is one worker per
    chip under every backend.
    """

    def __init__(self, devices: Sequence[Any], *,
                 meta: Optional[PlantMeta] = None,
                 max_workers: Optional[int] = None,
                 fault_policy: Optional[FaultPolicy] = None,
                 fault_log: Optional[FaultLog] = None,
                 backend="thread", pipeline: bool = False,
                 shard_batch: bool = False):
        del max_workers                 # legacy knob: one worker per chip
        entries = list(devices)
        if not entries:
            raise ValueError("ChipFarm needs at least one device")
        for entry in entries:
            if not isinstance(entry, DeviceSpec):
                check_device(entry)
        if _io_callback is None:        # pragma: no cover - old jax
            raise RuntimeError("ChipFarm needs jax.experimental."
                               "io_callback (jax >= 0.4.9)")
        if fault_policy is not None and not isinstance(fault_policy,
                                                       FaultPolicy):
            raise TypeError(f"fault_policy must be a hardware.FaultPolicy, "
                            f"got {type(fault_policy).__name__}")
        self.devices = entries
        self.shard_batch = bool(shard_batch)
        self.policy = fault_policy
        self.fault_log = fault_log if fault_log is not None else FaultLog()
        self.pipeline = bool(pipeline)
        self.backend = make_backend(backend)
        self._caps = self.backend.start(entries, fault_log=self.fault_log)
        self._names = [c["name"] for c in self._caps]
        self.health = FarmHealth(self._names)
        self._pending_writes: list = []   # [(chip, step, Task)]
        self._t_start: Optional[float] = None
        self._supervisors = None
        if fault_policy is not None:
            # one supervisor thread per chip runs the retry loop, so
            # per-chip timeouts/backoffs never serialize across chips
            self._supervisors = ThreadPoolExecutor(
                max_workers=len(entries),
                thread_name_prefix="chip-farm-sup")
        # reclaim workers when the farm is garbage-collected; close()
        # invokes the same finalizer, making it idempotent
        self._finalizer = weakref.finalize(
            self, _teardown, self.backend, self._supervisors)
        self.meta = meta or PlantMeta(name=f"chip-farm-{len(entries)}",
                                      external=True, chips=len(entries),
                                      fault_tolerant=fault_policy is not None)

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Tear down backend workers and supervisor threads.  Idempotent
        (also runs at GC).  In-flight pipelined writes are drained
        best-effort first — call ``fence()`` yourself when you need the
        commit guaranteed (or an error surfaced)."""
        if self._pending_writes:
            try:
                self.fence(timeout=5.0)
            except Exception:           # noqa: BLE001 — teardown path
                self._pending_writes = []
        self._finalizer()

    def __enter__(self) -> "ChipFarm":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    @property
    def closed(self) -> bool:
        return not self._finalizer.alive

    @property
    def n_chips(self) -> int:
        return len(self.devices)

    def _label(self, i: int) -> str:
        return f"chip {i} ({self._names[i]})"

    def fault_summary(self) -> dict:
        """Fault-tolerance telemetry: event counts by kind plus the
        health registry summary.  ``{"events": 0, ...}`` means a clean
        run."""
        return {"events": len(self.fault_log),
                "by_kind": self.fault_log.counts(),
                **self.health.summary()}

    def pipeline_stats(self) -> dict:
        """Utilization telemetry: ``utilization`` is Σ per-chip device
        busy seconds / (k × wall seconds since the first probe round) —
        1.0 means every chip was converting for the whole run, the
        ≥0.8 target of the double-buffered pipeline."""
        busy = self.backend.busy_seconds()
        wall = (0.0 if self._t_start is None
                else time.perf_counter() - self._t_start)
        return {
            "backend": type(self.backend).__name__,
            "pipeline": self.pipeline,
            "chips": self.n_chips,
            "busy_s": busy,
            "wall_s": wall,
            "utilization": (busy / (wall * self.n_chips)) if wall else 0.0,
        }

    # -- host side (numpy-pure, runs on the callback + supervisor threads) ---

    def fence(self, timeout: Optional[float] = None) -> None:
        """Drain in-flight pipelined parameter writes.  Write errors
        surface here with the failing chip named (or are logged and
        masked under a fault policy) — the explicit synchronization
        point before checkpoints, evals and recalibration."""
        pending, self._pending_writes = self._pending_writes, []
        self._resolve_writes(pending, timeout=timeout)

    def _resolve_writes(self, pending, timeout: Optional[float] = None):
        deadline = timeout if timeout is not None else DEFAULT_TIMEOUT_S
        for i, step, task in pending:
            try:
                task.result(timeout=deadline)
            except Exception as e:      # noqa: BLE001 — device failure
                if self.policy is None:
                    raise ChipFaultError(
                        f"{self._label(i)}: parameter write failed at "
                        f"step={step}: {e!r}") from e
                # under a policy a failed write must not unwind the step;
                # the chip keeps its stale parameters and the next probe
                # round surfaces (and masks) the damage
                self.fault_log.record("write-error", self._label(i),
                                      step=step, detail=str(e))

    def _guarded_submit(self, i, op, payload, *, step, tag, health):
        """One chip transaction under the fault policy: submit to the
        backend, bound each attempt by ``timeout_s``, ABANDON the
        chip's worker on timeout (thread: replace runner; process: kill
        + respawn), retry with exponential backoff, reject non-finite
        readouts.  Returns ``(value, latency_s, None)`` or ``(None,
        None, last_error)`` — the backend-native twin of
        ``faults.guarded_call``."""
        policy, label = self.policy, self._label(i)
        last: Optional[Exception] = None
        for attempt in range(policy.retries + 1):
            if attempt:
                time.sleep(policy.backoff_for(attempt))
            task = self.backend.submit(i, op, payload)
            t0 = time.monotonic()
            try:
                out = task.result(timeout=policy.timeout_s)
            except _FuturesTimeout:
                self.backend.abandon(i)
                last = ChipFaultError(
                    f"{label}: no response within timeout_s="
                    f"{policy.timeout_s}s at step={step} "
                    f"(attempt {attempt})")
                kind = "timeout"
            except Exception as e:      # noqa: BLE001 — any device failure
                last, kind = e, "error"
            else:
                if policy.reject_nonfinite and not np.all(
                        np.isfinite(np.asarray(out, np.float64))):
                    last = ChipFaultError(
                        f"{label}: non-finite readout {out!r} at "
                        f"step={step}")
                    kind = "nonfinite"
                else:
                    return out, time.monotonic() - t0, None
            if health is not None:
                health.attempts_failed += 1
                if kind == "timeout":
                    health.timeouts += 1
            self.fault_log.record(kind, label, step=step, tag=tag,
                                  attempt=attempt, detail=str(last))
        return None, None, last

    def _chip_pair_robust(self, i, params, theta, batch, step):
        """One chip's probe round under the fault policy (supervisor
        thread): quarantine fast-path, guarded attempts with retries,
        health bookkeeping.  Returns ``(f32[2] pair, valid)`` — never
        raises."""
        policy, h = self.policy, self.health.chips[i]
        if h.skip(step):
            # quarantined, not yet due a readmission probe: NO I/O
            return _INVALID_PAIR, False
        out, latency, err = self._guarded_submit(
            i, "pair", (params, theta, batch, step, 2 * i),
            step=step, tag=2 * i, health=h)
        if err is None:
            if h.quarantined:
                h.readmit()
                self.fault_log.record("readmit", self._label(i), step=step)
            h.record_success(latency, policy.latency_alpha)
            return np.asarray(out, np.float32), True
        h.record_failure()
        if h.quarantined:
            # failed readmission probe — back off until the next one
            h.next_reprobe = int(step) + policy.reprobe_every
        elif policy.quarantine_after and \
                h.consecutive_failures >= policy.quarantine_after:
            h.enter_quarantine(step, policy)
            self.fault_log.record(
                "quarantine", self._label(i), step=step,
                detail=f"{h.consecutive_failures} consecutive failures")
        return _INVALID_PAIR, False

    def _host_pairs(self, params, thetas, batch, step):
        step = int(step)
        k = self.n_chips
        if self._t_start is None:
            self._t_start = time.perf_counter()
        # pipelined writes from the previous step sit AHEAD of the pair
        # ops below in each chip's FIFO: dispatch the pairs first (the
        # workers run write→pair back to back), then resolve the write
        # tasks — by then effectively free — so write errors still
        # surface before this round's costs are consumed.
        pending, self._pending_writes = self._pending_writes, []
        if self.shard_batch:
            # contiguous per-chip slices — the block layout a k-pod mesh's
            # P("pod") batch spec produces, so chip i and pod i probe the
            # identical rows (the bit-equality law under batch sharding)
            batches = [shard_chip_batch(batch, k, i) for i in range(k)]
        else:
            batches = [batch] * k
        if self.policy is None:
            tasks = [
                self.backend.submit(i, "pair",
                                    (params, thetas[i], batches[i],
                                     step, 2 * i))
                for i in range(k)
            ]
            self._resolve_writes(pending)
            pairs = []
            # gather in chip order — the schedule cannot reorder results
            for i, t in enumerate(tasks):
                try:
                    pairs.append(np.asarray(t.result(
                        timeout=DEFAULT_TIMEOUT_S), np.float32))
                except Exception as e:
                    raise ChipFaultError(
                        f"{self._label(i)}: probe failed at step={step}: "
                        f"{e!r} — pass fault_policy=FaultPolicy(...) to "
                        f"retry and mask instead of failing the step"
                    ) from e
            return np.asarray(pairs, np.float32), np.ones(k, bool)
        futures = [
            self._supervisors.submit(self._chip_pair_robust, i, params,
                                     thetas[i], batches[i], step)
            for i in range(k)
        ]
        self._resolve_writes(pending)
        deadline = self.policy.round_deadline_s()
        costs = np.empty((k, 2), np.float32)
        valid = np.zeros(k, bool)
        for i, f in enumerate(futures):
            try:
                pair, ok = f.result(timeout=deadline)
            except Exception as e:  # supervisor failure — mask, keep going
                self.fault_log.record("error", self._label(i), step=step,
                                      detail=f"supervisor: {e}")
                pair, ok = _INVALID_PAIR, False
            costs[i] = pair
            valid[i] = ok
        return costs, valid

    def _host_write(self, params, step):
        step = int(step)
        tasks = [(i, step, self.backend.submit(i, "write", (params, step)))
                 for i in range(self.n_chips)]
        if self.pipeline:
            # double-buffer: the writes execute while the host runs the
            # traced compute toward the next probe round; per-chip FIFO
            # guarantees they land before that round's pair ops, and
            # errors surface at the next gather (or fence())
            self._pending_writes.extend(tasks)
            return np.int32(0)
        self._resolve_writes(tasks)
        return np.int32(0)

    # -- traced side ---------------------------------------------------------

    def read_cost_pairs(self, params, thetas, batch, *, step):
        """All k chips' antithetic pairs in one ordered host round-trip.
        ``thetas`` is the list of k perturbation trees (chip k probes its
        own θ̃_k); returns ``(f32[k, 2] costs, bool[k] valid)``.  Without
        a fault policy ``valid`` is all-True (any failure raises); with
        one, masked chips carry NaN costs and ``valid=False``."""
        if len(thetas) != self.n_chips:
            raise ValueError(f"{len(thetas)} probe trees for "
                             f"{self.n_chips} chips")
        if self.shard_batch:
            # shapes are static at trace time — fail the build, not the
            # host callback mid-run
            check_chip_shardable(batch, self.n_chips)
        return _io_callback(
            self._host_pairs,
            (jax.ShapeDtypeStruct((self.n_chips, 2), jnp.float32),
             jax.ShapeDtypeStruct((self.n_chips,), jnp.bool_)),
            params, thetas, batch, jnp.asarray(step, jnp.int32),
            ordered=True)

    def read_cost(self, params, batch, *, step, tag: int = 0):
        raise NotImplementedError(
            "ChipFarm has no single-chip cost read — drive it with "
            "repro.driver('probe_parallel_external', cfg, plant=farm), or "
            "wrap one device in ExternalPlant for the single-chip drivers")

    def write_params(self, params, *, step, prev=None):
        """Commit the post-update parameters to EVERY chip (open-loop, as
        in ``ExternalPlant``: per-chip write noise stays invisible).
        Quarantined chips are still written — writes are cheap and keep
        them current for readmission.  With ``pipeline=True`` the host
        does not wait for the writes to land (see ``fence``)."""
        _io_callback(self._host_write, jax.ShapeDtypeStruct((), jnp.int32),
                     params, jnp.asarray(step, jnp.int32), ordered=True)
        return params

    # -- evaluation harness (eager, never inside the traced step) ------------

    def measure_accuracy(self, params, batch, *, step=None) -> float:
        """Mean on-chip accuracy across the farm after committing
        ``params`` — the experimenter's bench readout, not training I/O.

        Self-fences first (a bench readout must not race an in-flight
        pipelined write).  Writes are timestamped with ``step`` for
        step-capable drifting chips (a bench readout of an aging chip
        must not silently reset its age).  Under a fault policy,
        quarantined chips are excluded from the bench average and
        per-chip errors are logged and skipped (falling back to all
        chips if every one is quarantined)."""
        params = jax.tree_util.tree_map(
            lambda x: np.asarray(x, np.float32), params)
        self.fence()
        capable = [i for i in range(self.n_chips)
                   if self._caps[i]["accuracy"]]
        if not capable:
            raise NotImplementedError("no device exposes measure_accuracy")
        indices = capable
        if self.policy is not None:
            live = [i for i in capable
                    if not self.health.chips[i].quarantined]
            indices = live or capable
        tasks = {i: self.backend.submit(i, "accuracy",
                                        (params, batch, step))
                 for i in indices}
        values = []
        for i, t in tasks.items():
            try:
                values.append(t.result(timeout=DEFAULT_TIMEOUT_S))
            except Exception as e:
                if self.policy is None:
                    raise ChipFaultError(
                        f"{self._label(i)}: accuracy readout failed: "
                        f"{e!r}") from e
                self.fault_log.record("accuracy-error", self._label(i),
                                      step=step, detail=str(e))
        if not values:
            raise ChipFaultError(
                "no chip produced an accuracy readout "
                f"(all {len(indices)} attempts failed)")
        return float(np.mean(values))

    @property
    def total_writes(self) -> int:
        """Summed ``writes`` counters across the farm (test/telemetry) —
        routed through the backend, so process-backend chips report
        their in-worker counters.  Self-fences first."""
        self.fence()
        tasks = [self.backend.submit(i, "writes", ())
                 for i in range(self.n_chips)]
        return sum(int(t.result(timeout=DEFAULT_TIMEOUT_S))
                   for t in tasks)


def simulated_chip_farm(k: int, sizes: Sequence[int] = (49, 4, 4), *,
                        base_seed: int = 0, sigma_a: float = 0.15,
                        sigma_theta: float = 0.01, sigma_c: float = 1e-4,
                        py_busy_ms: float = 0.0,
                        drift_rate: float = 0.0,
                        drift_rates: Optional[Sequence[float]] = None,
                        drift_mode: str = "walk", drift_tau: float = 0.0,
                        max_workers: Optional[int] = None,
                        faults=None, fault_seed: int = 1000,
                        fault_policy: Optional[FaultPolicy] = None,
                        backend="thread", pipeline: bool = False,
                        shard_batch: bool = False) -> ChipFarm:
    """A farm of k ``SimulatedAnalogChip``s with DISTINCT device seeds —
    k different physical chips (different defect draws, different noise
    streams), the same instrument replicated k× on the bench.

    ``backend`` picks the execution backend; spec-only backends
    (``process``/``cluster``) get picklable ``DeviceSpec`` entries that
    rebuild the identical chips — fault wrappers included — in their
    workers, everything else gets live instances.  ``pipeline=True``
    double-buffers parameter writes (see ``ChipFarm``).  ``py_busy_ms``
    makes each chip hold the GIL during readout conversions — the
    honest thread-vs-process scaling demonstration device.

    ``drift_rate`` (every chip) or ``drift_rates`` (one σ_d per chip — a
    HETEROGENEOUS farm, where chip i ages at its own rate) build
    ``DriftingAnalogChip``s instead; aging stays per-device-seed keyed,
    so two chips with different rates remain distinguishable across a
    checkpoint/resume.  Zero-rate chips stay plain (bit-identical to the
    drift-free farm).

    ``faults`` injects counter-keyed faults: a single ``FaultSpec``
    (every chip, per-chip fault seeds ``fault_seed + i``) or a k-long
    sequence with ``None`` entries for healthy chips.  ``fault_policy``
    arms the boundary (timeouts/retries/masking/quarantine) — the two
    compose but neither requires the other: inject faults with no policy
    to demonstrate the failure mode, or arm a policy over healthy chips
    at near-zero cost."""
    if k < 1:
        raise ValueError(f"need at least one chip, got k={k}")
    if drift_rates is None:
        rates = [float(drift_rate)] * k
    else:
        rates = [float(r) for r in drift_rates]
        if len(rates) != k:
            raise ValueError(f"{len(rates)} drift_rates for {k} chips")
    specs = [None] * k
    if faults is not None:
        specs = list(faults) if isinstance(faults, (list, tuple)) \
            else [faults] * k
        if len(specs) != k:
            raise ValueError(f"{len(specs)} fault specs for {k} chips")
        for spec in specs:
            if spec is not None and not isinstance(spec, FaultSpec):
                raise TypeError(f"faults entries must be FaultSpec or "
                                f"None, got {type(spec).__name__}")

    def chip_recipe(i):
        """(cls, kwargs) for chip i — one place, so the instance and
        DeviceSpec paths build the identical device."""
        kwargs = dict(seed=base_seed + i, sigma_a=sigma_a,
                      sigma_theta=sigma_theta, sigma_c=sigma_c,
                      py_busy_ms=py_busy_ms)
        if rates[i] or drift_tau:
            kwargs.update(drift_mode=drift_mode, drift_rate=rates[i],
                          drift_tau=drift_tau)
            return DriftingAnalogChip, kwargs
        return SimulatedAnalogChip, kwargs

    be = make_backend(backend)
    fault_log = FaultLog()
    if be.accepts_instances:
        devices = []
        for i in range(k):
            cls, kwargs = chip_recipe(i)
            device = cls(sizes, **kwargs)
            if specs[i] is not None:
                device = FaultyChip(device, specs[i], seed=fault_seed + i,
                                    log=fault_log)
            devices.append(device)
    else:
        devices = []
        for i in range(k):
            cls, kwargs = chip_recipe(i)
            devices.append(DeviceSpec(cls, (tuple(sizes),), kwargs,
                                      fault=specs[i],
                                      fault_seed=fault_seed + i))
    drifting = any(rates) or drift_tau
    return ChipFarm(
        devices, max_workers=max_workers, fault_policy=fault_policy,
        fault_log=fault_log, backend=be, pipeline=pipeline,
        shard_batch=shard_batch,
        meta=PlantMeta(name=f"sim-farm-{k}" + ("-drift" if drifting else ""),
                       cost_noise=sigma_c, write_noise=sigma_theta,
                       sigma_a=sigma_a, external=True, chips=k,
                       drift_mode=drift_mode if drifting else None,
                       drift_rate=max(rates), drift_tau=drift_tau,
                       fault_tolerant=fault_policy is not None))
