"""Hardware plant abstraction — one device interface for every MGD mode.

Every optimizer driver (Algorithm 1 discrete, Algorithm 2 continuous,
fused Pallas, probe-parallel) composes with every device model through
the ``Plant`` protocol:

    IdealPlant      pure JAX, bit-identical (f32) to the in-process path
    NoisyPlant      σ_C readout noise + σ_θ write noise (paper §3.5)
    QuantizedPlant  limited-bit DAC weight writes + slow-write τ lag
    DriftingPlant   weights random-walk / decay between writes (aging)
    ExternalPlant   host-callback boundary (chip in the loop, §4/§6)
    ChipFarm        k external chips probed concurrently (§6 chip farm)

See ``base.py`` for the protocol contract and ``devices.py`` for
per-device-seed builders (defective MLPs, simulated analog chips —
including the drifting chip variant for the external boundary).

``faults.py`` is the robustness layer for the external boundary:
``FaultyChip`` injects counter-keyed reproducible faults (hangs,
crashes, NaNs, outliers) over any device, and ``FaultPolicy`` arms
``ExternalPlant``/``ChipFarm`` with timeouts, retries, per-chip
masking, quarantine and robust aggregation.

``backend/`` is the farm's execution layer: ``ChipFarm(backend=...)``
picks WHO runs the device transactions — ``serial`` (inline parity
oracle), ``thread`` (one runner thread per chip, default), ``process``
(one worker process per chip, built from picklable ``DeviceSpec``s —
GIL-bound devices scale, hung workers are killed for real) or
``cluster`` (the wire-protocol stub for farm-over-network chips).
"""
from .backend import (BACKENDS, ClusterStubBackend, DeviceSpec,
                      FarmBackend, ProcessBackend, SerialBackend,
                      ThreadBackend, loopback_transport, make_backend)
from .base import IdealPlant, Plant, PlantMeta
from .devices import (DriftingAnalogChip, LinearLaneChip,
                      SimulatedAnalogChip, mlp_device_fns, noisy_mlp_plant,
                      quantized_mlp_plant)
from .external import ExternalPlant
from .farm import ChipFarm, simulated_chip_farm
from .faults import (DEFAULT_TIMEOUT_S, ChipFaultError, ChipHealth,
                     FarmHealth, FaultEvent, FaultLog, FaultPolicy,
                     FaultSpec, FaultyChip, InjectedFault)
from .plants import (DriftingPlant, NoisyPlant, QuantizedPlant,
                     plant_from_config)

__all__ = [
    "Plant", "PlantMeta", "IdealPlant", "NoisyPlant", "QuantizedPlant",
    "DriftingPlant", "ExternalPlant", "ChipFarm", "plant_from_config",
    "SimulatedAnalogChip", "DriftingAnalogChip", "LinearLaneChip",
    "mlp_device_fns",
    "noisy_mlp_plant", "quantized_mlp_plant", "simulated_chip_farm",
    "ChipFaultError", "ChipHealth", "DEFAULT_TIMEOUT_S", "FarmHealth",
    "FaultEvent", "FaultLog", "FaultPolicy", "FaultSpec", "FaultyChip",
    "InjectedFault",
    "BACKENDS", "ClusterStubBackend", "DeviceSpec", "FarmBackend",
    "ProcessBackend", "SerialBackend", "ThreadBackend",
    "loopback_transport", "make_backend",
]
