"""Concrete device builders: defective MLPs and a simulated analog chip.

Device-to-device variation (paper §3.5, Fig. 10) is expressed here by
keying every imperfection off one ``device_seed``: two plants built with
different seeds are two different physical chips — different activation
defects, different write/readout noise streams — while the same seed
reproduces the identical chip across restarts (the defect pattern is
part of the *device*, not of the training state).
"""
from __future__ import annotations

import ctypes
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.cost import mse
from repro.core.noise import sample_defects
from repro.models.simple import make_mlp_probe_fn, mlp_apply

from .base import IdealPlant, Plant, PlantMeta
from .plants import NoisyPlant, QuantizedPlant


def mlp_device_fns(sizes: Sequence[int], *, sigma_a: float = 0.0,
                   device_seed: int = 0, cost=mse):
    """(loss_fn, probe_fn, defects) for a sigmoidal MLP with per-neuron
    fabrication defects sampled from ``device_seed`` (σ_a = 0 → exact
    sigmoid and defects=None, keeping the ideal path bit-identical)."""
    if sigma_a:
        defects = [sample_defects(device_seed + i, n, sigma_a)
                   for i, n in enumerate(sizes[1:])]
    else:
        defects = None

    def loss_fn(params, batch):
        return cost(mlp_apply(params, batch["x"], defects=defects),
                    batch["y"])

    return loss_fn, make_mlp_probe_fn(defects), defects


def noisy_mlp_plant(sizes: Sequence[int], *, sigma_c: float = 0.0,
                    sigma_theta: float = 0.0, sigma_a: float = 0.0,
                    dtheta: float = 1e-2, device_seed: int = 0,
                    cost=mse) -> Plant:
    """A full §3.5 device: σ_C readout noise, σ_θ write noise, σ_a static
    activation defects, all drawn from ``device_seed``."""
    loss_fn, probe_fn, _ = mlp_device_fns(
        sizes, sigma_a=sigma_a, device_seed=device_seed, cost=cost)
    if not (sigma_c or sigma_theta):
        return IdealPlant(loss_fn, probe_fn=probe_fn, meta=PlantMeta(
            name="mlp-ideal", sigma_a=sigma_a))
    return NoisyPlant(
        loss_fn, cost_noise=sigma_c, write_noise=sigma_theta,
        dtheta=dtheta, seed=device_seed, probe_fn=probe_fn,
        meta=PlantMeta(name="mlp-noisy", cost_noise=sigma_c,
                       write_noise=sigma_theta, sigma_a=sigma_a))


def quantized_mlp_plant(sizes: Sequence[int], *, bits: int = 8,
                        w_clip: float = 2.0, write_tau: float = 0.0,
                        quantize_probes: bool = False,
                        adc_bits: Optional[int] = None,
                        adc_mode: str = "round", adc_range: float = 1.0,
                        sigma_a: float = 0.0,
                        device_seed: int = 0, cost=mse) -> QuantizedPlant:
    """An MLP whose weight memory sits behind a ``bits``-bit DAC and
    (optionally) whose cost readout passes an ``adc_bits``-bit ADC."""
    loss_fn, probe_fn, _ = mlp_device_fns(
        sizes, sigma_a=sigma_a, device_seed=device_seed, cost=cost)
    return QuantizedPlant(
        loss_fn, bits=bits, w_clip=w_clip, write_tau=write_tau,
        quantize_probes=quantize_probes, adc_bits=adc_bits,
        adc_mode=adc_mode, adc_range=adc_range, seed=device_seed,
        probe_fn=probe_fn,
        meta=PlantMeta(name=f"mlp-dac{bits}", weight_bits=bits,
                       adc_bits=adc_bits, sigma_a=sigma_a))


# GIL-bound instrument-driver model for SimulatedAnalogChip(py_busy_ms=…).
# Real lab stacks spend their readout time in pure-Python driver code and
# in C calls that do NOT release the GIL (ctypes.PyDLL is exactly that
# calling convention) — k such chips on a thread pool serialize to k×
# single-chip wall-clock, which is the failure mode the process farm
# backend exists to remove.  The busy loop below holds the GIL for a
# FIXED amount of held-GIL work (not a wall-clock deadline — a deadline
# would silently shrink under contention), chunked through a
# non-GIL-releasing 200 µs libc usleep so a single chip does not peg the
# CPU; without libc (non-POSIX) it degrades to a pure spin.
try:
    _LIBC = ctypes.PyDLL(None)
    _LIBC.usleep.argtypes = [ctypes.c_uint]
    _LIBC.usleep.restype = ctypes.c_int
except (OSError, AttributeError):       # pragma: no cover - non-POSIX
    _LIBC = None


def _hold_gil_busy(ms: float) -> None:
    """Hold the GIL for ≈``ms`` milliseconds of driver 'work'."""
    if _LIBC is not None:
        for _ in range(max(1, int(ms * 5))):
            _LIBC.usleep(200)           # PyDLL: the GIL stays held
        return
    deadline = time.perf_counter() + ms * 1e-3  # pragma: no cover
    while time.perf_counter() < deadline:       # pragma: no cover
        pass


class SimulatedAnalogChip:
    """Reference host device for ``ExternalPlant``: a sigmoidal network
    with fabrication defects, noisy analog writes and noisy readout.

    Nothing outside this class may see the defects or the internal
    parameters — only ``set_params`` / ``measure_cost`` /
    ``measure_pair`` / the public readouts, like a lab instrument.
    Deliberately implemented in PURE NUMPY: the instrument lives on the
    far side of the host-callback boundary, and host callbacks that
    dispatch JAX ops can deadlock against the in-flight XLA program that
    invoked them (two threads feeding one CPU client).  Writes mutate
    the instrument; READOUT noise is counter-keyed on the optimizer's
    (step, tag) pair when provided, so the +/− probe reads of a central
    pair draw distinct noise and a restarted run replays the identical
    readout stream (write noise stays a live RNG — an analog memory has
    no replayable write history).

    ``measure_pair`` is the differential probe line: θ̃ is applied
    transiently at the parameter (paper's dedicated-perturbation-line /
    LFSR-per-synapse picture), so a central pair costs ONE persistent
    base-θ write instead of two full perturbed-tree writes.

    ``py_busy_ms`` models a GIL-BOUND instrument driver: every readout
    conversion holds the GIL for that many milliseconds of pure-Python
    driver work (``_hold_gil_busy``), so k such chips on the farm's
    thread backend serialize to k× single-chip wall-clock while the
    process backend stays flat — the honest demonstration device for
    ``benchmarks/farm_scaling.py --backend``.
    """

    def __init__(self, sizes: Sequence[int] = (49, 4, 4), *, seed: int = 0,
                 sigma_a: float = 0.15, sigma_theta: float = 0.01,
                 sigma_c: float = 1e-4, py_busy_ms: float = 0.0):
        rng = np.random.default_rng(seed)
        # per-neuron logistic defects, one tuple (α, β, a0, b0) per layer
        # (the numpy twin of core.noise.sample_defects — same model, the
        # chip's own fabrication draw)
        self._defects = [
            (1.0 + sigma_a * rng.standard_normal(n),
             1.0 + sigma_a * rng.standard_normal(n),
             sigma_a * rng.standard_normal(n),
             sigma_a * rng.standard_normal(n))
            for n in sizes[1:]
        ]
        self._seed = int(seed)
        self._sigma_theta = sigma_theta
        self._sigma_c = sigma_c
        self._py_busy_ms = float(py_busy_ms)
        self._params = None
        self._rng = np.random.default_rng(seed + 101)
        self.writes = 0
        self.meta = PlantMeta(name="sim-chip", cost_noise=sigma_c,
                              write_noise=sigma_theta, sigma_a=sigma_a,
                              external=True)

    def set_params(self, params):
        """Analog memory write — each write lands with noise."""
        self.writes += 1
        self._params = jax.tree_util.tree_map(
            lambda w: (np.asarray(w, np.float32)
                       + self._sigma_theta * self._rng.standard_normal(
                           np.shape(w)).astype(np.float32)),
            params)

    def _stored(self, step):
        """The weights a readout at optimizer step ``step`` sees.  The
        stable chip returns the stored values as written; the drifting
        variant overrides this with the aged values."""
        return self._params

    def _forward(self, x, params=None):
        h = np.asarray(x, np.float32)
        for (a, b, a0, b0), layer in zip(
                self._defects, self._params if params is None else params):
            z = h @ layer["w"]
            if "b" in layer:
                z = z + layer["b"]
            h = a / (1.0 + np.exp(-b * (z - a0))) + b0
        return h

    def _readout_noise(self, step, tag):
        """One standard normal per readout.  Counter-keyed on
        (device seed, step, tag) when the optimizer supplies them —
        deterministic across restarts and distinguishing the +/− probe
        reads — else drawn from the live instrument RNG."""
        if step is None or tag is None:
            return float(self._rng.standard_normal())
        rng = np.random.default_rng((self._seed, int(step), int(tag)))
        return float(rng.standard_normal())

    def _cost(self, params, batch, step, tag):
        if self._py_busy_ms:
            # GIL-bound driver work per readout CONVERSION (a pair is
            # two conversions) — see _hold_gil_busy above
            _hold_gil_busy(self._py_busy_ms)
        err = self._forward(batch["x"], params) - np.asarray(
            batch["y"], np.float32)
        c = float(np.mean(err * err))
        return c + self._sigma_c * self._readout_noise(step, tag)

    def measure_cost(self, batch, *, step=None, tag=None):
        """Scalar cost readout (MSE) with measurement noise."""
        return self._cost(self._stored(step), batch, step, tag)

    def measure_pair(self, theta, batch, *, step=None, tag=None):
        """Differential probe readout (C(θ+θ̃), C(θ−θ̃)): θ̃ rides the
        transient probe line on top of the stored (write-noisy) θ; each
        half is a separate physical conversion with its own readout
        noise (consecutive tags, like the base-class two-read path)."""
        stored = self._stored(step)
        plus = jax.tree_util.tree_map(
            lambda w, t: w + np.asarray(t, np.float32), stored, theta)
        minus = jax.tree_util.tree_map(
            lambda w, t: w - np.asarray(t, np.float32), stored, theta)
        tag2 = None if tag is None else tag + 1
        return (self._cost(plus, batch, step, tag),
                self._cost(minus, batch, step, tag2))

    def measure_accuracy(self, batch, *, step=None):
        """Classification readout (evaluation harness only — the
        optimizer never calls this).  ``step`` reads the drifting
        variant's AGED weights; the stable chip ignores it."""
        pred = self._forward(batch["x"], self._stored(step))
        return float(np.mean(np.argmax(pred, -1)
                             == np.argmax(np.asarray(batch["y"]), -1)))


class DriftingAnalogChip(SimulatedAnalogChip):
    """A ``SimulatedAnalogChip`` whose stored weights AGE between writes.

    The drift model mirrors ``hardware.plants.DriftingPlant`` on the far
    side of the host boundary: a readout at optimizer step n sees the
    stored weights taken through one transition

        θ ← rest + a·(θ − rest) + σ_d·ξ(seed, step, leaf)

    per step j in [write_step, n], ``a = exp(−1/drift_tau)`` — the j =
    write_step transition is the write-settle interval, so even a read
    in the SAME step as its write sees one kick of aging.  ``set_params``
    records the optimizer's step counter when given (``ExternalPlant``/
    ``ChipFarm`` forward it to step-capable devices), so the aged weights
    any readout sees are a pure function of (device seed, write step,
    read step, written values) — a restarted run replays the identical
    aging, and two chips with different ``drift_rate`` stay
    distinguishable across the resume.  Writes or reads without a step
    counter (the bench harness) see the un-aged stored values.

    Under continuous training the trainer rewrites the chip every step,
    so exactly one transition lands per read — drift shows up as excess
    probe noise the optimizer must average through.  Once writes STOP (a
    deployed chip, or the interval between scheduled recalibrations) the
    walk accumulates freely; the cost of reconstructing it at a readout
    is O(elapsed steps).
    """

    def __init__(self, sizes: Sequence[int] = (49, 4, 4), *, seed: int = 0,
                 sigma_a: float = 0.15, sigma_theta: float = 0.01,
                 sigma_c: float = 1e-4, py_busy_ms: float = 0.0,
                 drift_mode: str = "walk",
                 drift_rate: float = 0.0, drift_tau: float = 0.0,
                 rest: float = 0.0):
        if drift_mode not in ("walk", "decay"):
            raise ValueError(f"drift mode must be 'walk' or 'decay', "
                             f"got {drift_mode!r}")
        super().__init__(sizes, seed=seed, sigma_a=sigma_a,
                         sigma_theta=sigma_theta, sigma_c=sigma_c,
                         py_busy_ms=py_busy_ms)
        self._drift_mode = drift_mode
        self._drift_rate = float(drift_rate)
        self._drift_tau = float(drift_tau)
        self._rest = float(rest)
        self._write_step = None
        self.meta = PlantMeta(name="sim-chip-drift", cost_noise=sigma_c,
                              write_noise=sigma_theta, sigma_a=sigma_a,
                              external=True, drift_mode=drift_mode,
                              drift_rate=self._drift_rate,
                              drift_tau=self._drift_tau, drift_rest=rest)

    def set_params(self, params, *, step=None):
        """Analog memory write; ``step`` (when the plant forwards it)
        timestamps the write so later readouts know how long the stored
        values have been aging."""
        super().set_params(params)
        self._write_step = None if step is None else int(step)

    def _drift_once(self, params, step):
        a = (np.exp(-1.0 / self._drift_tau) if self._drift_tau else 1.0)

        def leaf(i, w):
            w = np.asarray(w, np.float32)
            if self._drift_tau:
                w = self._rest + a * (w - self._rest)
            if self._drift_rate:
                rng = np.random.default_rng(
                    (self._seed + 313, int(step), i))
                w = w + self._drift_rate * rng.standard_normal(
                    w.shape).astype(np.float32)
            return w

        flat, treedef = jax.tree_util.tree_flatten(params)
        return jax.tree_util.tree_unflatten(
            treedef, [leaf(i, w) for i, w in enumerate(flat, start=1)])

    def _stored(self, step):
        """Stored weights aged from the recorded write step to ``step``,
        inclusive — the readouts inherited from ``SimulatedAnalogChip``
        all see the aged values through this one hook."""
        params = self._params
        if (step is None or self._write_step is None
                or (not self._drift_rate and not self._drift_tau)):
            return params
        for j in range(self._write_step, int(step) + 1):
            params = self._drift_once(params, j)
        return params


class LinearLaneChip:
    """Bit-transparent affine readout lane: ``C = mean(|x @ w + b − y|)``
    with NO noise, NO defects and NO nonlinearity.

    This is the calibration device for the farm ≡ mesh bit-equality
    law.  Driven with dyadic-rational parameters (multiples of 2^-m),
    probe amplitudes that are powers of two and {0,1} data, every
    intermediate value of the cost — products, partial sums, |·|, the
    power-of-two batch mean — is exactly representable in f32, so the
    numpy arithmetic here and the XLA arithmetic of the jax twin
    (``models.simple.linear_apply`` + ``mae``) produce identical bits
    no matter how either side associates or fuses the operations.
    Tests use it to pin the batch-sharded k-chip farm against the
    k-pod mesh where a defective-sigmoid chip would diverge in the
    last ulp for libm reasons unrelated to the optimizer.

    Same transaction surface as ``SimulatedAnalogChip``: ``set_params``
    (counted, exact), ``measure_cost``, the differential ``measure_pair``
    probe line, and a threshold ``measure_accuracy`` readout.  Pure
    numpy — host-callback safe.
    """

    def __init__(self, *, seed: int = 0):
        del seed  # noiseless; accepted so farm builders can fan out seeds
        self._params = None
        self.writes = 0
        self.meta = PlantMeta(name="linear-lane", external=True)

    def set_params(self, params):
        """Exact (noise-free) weight write."""
        self.writes += 1
        self._params = jax.tree_util.tree_map(
            lambda w: np.asarray(w, np.float32), params)

    def _forward(self, x, params=None):
        h = np.asarray(x, np.float32)
        for layer in (self._params if params is None else params):
            h = h @ layer["w"]
            if "b" in layer:
                h = h + layer["b"]
        return h

    def _cost(self, params, batch):
        err = self._forward(batch["x"], params) - np.asarray(
            batch["y"], np.float32)
        return float(np.mean(np.abs(err), dtype=np.float32))

    def measure_cost(self, batch, *, step=None, tag=None):
        """Exact L1 cost readout."""
        return self._cost(self._params, batch)

    def measure_pair(self, theta, batch, *, step=None, tag=None):
        """(C(θ+θ̃), C(θ−θ̃)) with θ̃ applied exactly on the probe line."""
        plus = jax.tree_util.tree_map(
            lambda w, t: w + np.asarray(t, np.float32), self._params, theta)
        minus = jax.tree_util.tree_map(
            lambda w, t: w - np.asarray(t, np.float32), self._params, theta)
        return self._cost(plus, batch), self._cost(minus, batch)

    def measure_accuracy(self, batch, *, step=None):
        """Fraction of outputs on the correct side of 1/2."""
        pred = self._forward(batch["x"])
        return float(np.mean((pred > 0.5)
                             == (np.asarray(batch["y"]) > 0.5)))
