"""Pluggable farm execution backends (see ``base.py`` for the contract).

    serial   inline on the calling thread — the parity oracle
    thread   one runner thread per chip (live instances OK; GIL-bound
             devices serialize)
    process  one worker process per chip (DeviceSpec entries; real kills
             on hangs, GIL-bound devices scale)
    cluster  wire-protocol stub for farm-over-network chips

``ChipFarm(devices, backend=...)`` accepts any registered name or a
``FarmBackend`` instance.
"""
from .base import (BACKENDS, ChipOps, DeviceSpec, FarmBackend,
                   SerialBackend, Task, make_backend)
from .cluster_stub import ClusterStubBackend, loopback_transport
from .process import ProcessBackend
from .thread import ThreadBackend

__all__ = [
    "BACKENDS", "ChipOps", "ClusterStubBackend", "DeviceSpec",
    "FarmBackend", "ProcessBackend", "SerialBackend", "Task",
    "ThreadBackend", "loopback_transport", "make_backend",
]
