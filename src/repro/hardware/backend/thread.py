"""Thread backend: one long-lived runner thread per chip.

The PR-4 farm fanned probes out on a shared ``ThreadPoolExecutor``; this
backend keeps the same in-process execution (live device instances work
unchanged — the zero-migration default) but gives each chip its OWN
serial runner thread fed by a FIFO queue.  That buys two things the
shared pool could not:

* **Per-chip ordering for free** — the double-buffered pipeline enqueues
  step N+1's ``write`` and returns; the following ``pair`` op sits
  behind it in the same queue, so the device is always written before
  it is probed, with no host-side synchronization.
* **Structured abandonment** — when the fault policy times an op out,
  ``abandon(i)`` marks the runner stale and starts a replacement.  The
  zombie thread stays parked inside the hung instrument call (Python
  cannot kill a thread — that is the process backend's upgrade), but it
  can no longer resolve tasks or steal queued ones: pending ops migrate
  to the replacement's fresh queue, and the zombie exits at the next
  loop check once the instrument releases it.

GIL caveat (the reason the process backend exists): runner threads give
CONCURRENCY, not parallelism.  Devices that hold the GIL during their
transactions — pure-Python instrument drivers, ``SimulatedAnalogChip(
py_busy_ms=...)`` — serialize to k× single-chip wall-clock here;
numpy-heavy devices (which release the GIL inside BLAS) scale fine.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import List, Optional

from ..faults import ChipFaultError
from .base import BACKENDS, ChipOps, FarmBackend, Task

#: Queue sentinel: tells a runner (stale or live) to exit.
_STOP = object()


class _Runner:
    """One chip's serial executor: a daemon thread draining a FIFO of
    ``(op, payload, Task)`` triples.  ``stale`` flips when the backend
    abandons this runner — after the in-flight device call returns, the
    zombie fails its task (if still unresolved) and exits instead of
    touching the queue again."""

    def __init__(self, backend: "ThreadBackend", chip: int, ops: ChipOps,
                 generation: int):
        self.backend = backend
        self.chip = chip
        self.ops = ops
        self.generation = generation
        self.stale = False
        self.queue: "queue.Queue" = queue.Queue()
        self.thread = threading.Thread(
            target=self._loop, name=f"chip-farm-{chip}-g{generation}",
            daemon=True)
        self.thread.start()

    def _loop(self):
        while True:
            item = self.queue.get()  # mgdlint: disable=MGD003 (idle FIFO wait; the _STOP sentinel always wakes it on shutdown)
            if item is _STOP:
                return
            if self.stale:
                # replaced while parked in get(): hand the op to the
                # live runner and exit
                self.backend._requeue(self.chip, item)
                return
            op, payload, task = item
            t0 = time.perf_counter()
            try:
                value = self.ops.run(op, payload)
            except Exception as e:      # noqa: BLE001 — device failure
                err: Optional[BaseException] = e
                value = None
            else:
                err = None
            busy = time.perf_counter() - t0
            if self.stale:
                # abandoned mid-call: the supervisor moved on, nothing
                # may consume a zombie's result
                task.set_exception(ChipFaultError(
                    f"chip {self.chip}: op {op!r} abandoned after "
                    f"{busy:.3f}s (worker replaced)"), busy)
                continue                # next get() sees _STOP
            self.backend._account(busy)
            if err is not None:
                task.set_exception(err, busy)
            else:
                task.set_result(value, busy)


class ThreadBackend(FarmBackend):
    """One runner thread per chip; accepts live device instances or
    ``DeviceSpec``s (specs build in-process against the host log)."""

    accepts_instances = True

    def __init__(self):
        self._runners: List[_Runner] = []
        self._lock = threading.Lock()
        self._busy = 0.0
        self._down = False

    def start(self, entries, *, fault_log=None):
        ops = self._build_ops(entries, fault_log)
        self._runners = [_Runner(self, i, op, generation=0)
                         for i, op in enumerate(ops)]
        return [op.caps() for op in ops]

    def submit(self, i, op, payload):
        task = Task()
        if self._down:
            task.set_exception(ChipFaultError(
                f"chip {i}: farm backend is shut down"))
            return task
        with self._lock:
            runner = self._runners[i]
        runner.queue.put((op, payload, task))
        return task

    def abandon(self, i):
        """Replace chip ``i``'s runner.  Pending queued ops migrate to
        the replacement; the zombie parks until the instrument releases
        it, then exits without resolving anything."""
        with self._lock:
            old = self._runners[i]
            old.stale = True
            new = _Runner(self, i, old.ops, old.generation + 1)
            # the zombie is blocked inside the hung device call, not in
            # get(), so draining its queue here does not race a consumer
            while True:
                try:
                    new.queue.put(old.queue.get_nowait())
                except queue.Empty:
                    break
            old.queue.put(_STOP)
            self._runners[i] = new

    def _requeue(self, i, item):
        with self._lock:
            self._runners[i].queue.put(item)

    def shutdown(self, wait=False):
        if self._down:
            return
        self._down = True
        with self._lock:
            runners = list(self._runners)
        for r in runners:
            r.queue.put(_STOP)
        if wait:
            for r in runners:
                r.thread.join(timeout=5.0)

    def busy_seconds(self):
        with self._lock:
            return self._busy

    def _account(self, busy: float):
        with self._lock:
            self._busy += busy


BACKENDS["thread"] = ThreadBackend
