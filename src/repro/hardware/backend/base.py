"""Farm execution backends: who runs a chip's device transactions, where.

``ChipFarm`` (``hardware/farm.py``) owns the MGD math and the
host-boundary contract — fixed-shape ``(f32[k,2] costs, bool[k] valid)``
gathers through ONE ordered ``io_callback``, fault-policy orchestration,
health/quarantine bookkeeping.  Everything about *executing* a device
transaction (on which thread, in which process, against which rebuilt
device object) lives behind the ``FarmBackend`` interface in this
package:

    backend.start(entries, fault_log=...)   -> per-chip capability dicts
    backend.submit(i, op, payload)          -> Task (future-like)
    task.result(timeout=...)                -> op value (or raises)
    backend.abandon(i)                      -> kill/replace chip i's worker
    backend.shutdown()                      -> idempotent teardown

Three properties every backend must provide:

* **Per-chip FIFO** — ops submitted to one chip execute in submission
  order.  The farm's double-buffered pipeline leans on this: step N+1's
  ``write`` is enqueued without waiting, and the following ``pair`` op
  cannot overtake it, so device state is always written-then-probed in
  program order even though the host never blocked.
* **Deterministic values** — a backend only moves WHERE an op runs.
  Device readout noise is counter-keyed on (device seed, step, tag), so
  serial, thread and process backends produce bit-identical cost streams
  from identically-seeded devices (σ_θ write noise is a live RNG, but
  the per-chip write sequence is schedule-independent, so it replays
  identically too).
* **Abandonment** — ``abandon(i)`` makes chip ``i`` responsive again
  after a hang: the thread backend replaces the runner (the zombie
  thread parks until the instrument releases it), the process backend
  KILLS the worker process — a strictly stronger guarantee — and
  respawns it from the chip's ``DeviceSpec``.

``ChipOps`` is the shared device-call logic (capability inspection +
write/pair/accuracy transactions) every backend executes, host-side or
in-worker.  ``DeviceSpec`` is the picklable recipe a worker process (or
a cluster node) rebuilds its device from — including the ``FaultyChip``
wrapper, so fault injection travels across the process boundary.

Everything here is host-side numpy/stdlib — never traced, never
dispatching JAX ops (host callbacks that do can deadlock the CPU
client; see ``hardware/external.py``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..external import accepts_counters, accepts_step, check_device
from ..faults import FaultLog, FaultSpec, FaultyChip

#: Ops a backend must execute.  ``pair`` is the probe transaction
#: (base-θ write + antithetic readout), ``write`` the persistent
#: parameter commit, ``accuracy`` the bench readout, ``writes`` the
#: device write-counter telemetry.
OPS = ("pair", "write", "accuracy", "writes")


def _np_axpy(sign: float, theta, params):
    """params + sign·theta, host-side numpy (never dispatches JAX ops)."""
    return jax.tree_util.tree_map(
        lambda w, t: np.asarray(w, np.float32)
        + np.float32(sign) * np.asarray(t, np.float32), params, theta)


@dataclasses.dataclass
class DeviceSpec:
    """Picklable recipe for building a chip's device in-worker.

    The process (and cluster) backends cannot ship live device objects —
    a device is stateful, unpicklable in general, and MUST live where
    its transactions execute.  A spec ships the constructor instead:
    ``cls(*args, **kwargs)``, optionally wrapped in a ``FaultyChip``
    (``fault``/``fault_seed``), built via ``build(log=...)`` on the far
    side.  Identical specs build identical chips (device imperfections
    are keyed off the seed in ``kwargs``), which is what makes the
    thread and process backends bit-interchangeable.
    """

    cls: Any                     # device class — importable/picklable
    args: Tuple = ()
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    fault: Optional[FaultSpec] = None
    fault_seed: int = 0
    name: Optional[str] = None

    def __post_init__(self):
        if not callable(self.cls):
            raise TypeError(f"DeviceSpec.cls must be a device class, "
                            f"got {type(self.cls).__name__}")
        for attr in ("set_params", "measure_cost"):
            if not callable(getattr(self.cls, attr, None)):
                raise TypeError(
                    f"DeviceSpec.cls must define {attr}(); got "
                    f"{getattr(self.cls, '__name__', self.cls)!r}")
        if self.fault is not None and not isinstance(self.fault, FaultSpec):
            raise TypeError(f"DeviceSpec.fault must be a FaultSpec or "
                            f"None, got {type(self.fault).__name__}")

    def build(self, log: Optional[FaultLog] = None):
        """Construct the device (and its fault wrapper) where the ops
        will run.  ``log`` receives injected-fault events — the host
        ``FaultLog`` for in-process backends, a worker-local log whose
        events ship back in replies for the process backend."""
        device = self.cls(*self.args, **self.kwargs)
        if self.fault is not None:
            device = FaultyChip(device, self.fault, seed=self.fault_seed,
                                log=log, name=self.name)
        return device

    @property
    def display_name(self) -> str:
        """The chip label the farm shows before the device is built —
        matches ``getattr(device, 'name', ...)`` of the built object."""
        if self.name:
            return self.name
        cls_name = getattr(self.cls, "__name__", str(self.cls))
        if self.fault is not None:
            return f"faulty:{cls_name}:{self.fault_seed}"
        return cls_name


class ChipOps:
    """One chip's transaction executor: capability inspection at
    construction (never on the hot loop) + the shared write/pair/
    accuracy logic every backend runs, host-side or in-worker.

    ``pair`` is the full probe transaction for tags (2i, 2i+1): devices
    with a differential probe line (``measure_pair``) pay ONE persistent
    base-θ write per central pair; plain 2-method devices fall back to
    two perturbed-tree writes + reads."""

    def __init__(self, device: Any):
        check_device(device)
        self.device = device
        self.name = getattr(device, "name", None) or type(device).__name__
        pair = getattr(device, "measure_pair", None)
        self._pair = pair if callable(pair) else None
        self._pair_counters = (self._pair is not None
                               and accepts_counters(self._pair))
        self._counters = accepts_counters(device.measure_cost)
        self._write_step = accepts_step(device.set_params)
        acc = getattr(device, "measure_accuracy", None)
        self._acc = acc if callable(acc) else None
        self._acc_step = self._acc is not None and accepts_step(self._acc)

    def caps(self) -> dict:
        """Static capability record shipped to the farm at ``start``."""
        return {"name": self.name, "pair": self._pair is not None,
                "accuracy": self._acc is not None}

    def write(self, params, step=None) -> int:
        """One persistent write, timestamped for step-capable (drifting)
        devices."""
        if step is not None and self._write_step:
            self.device.set_params(params, step=int(step))
        else:
            self.device.set_params(params)
        return 0

    def pair(self, params, theta, batch, step, tag) -> np.ndarray:
        """One central-difference probe transaction → f32[2]."""
        if self._pair is not None:
            self.write(params, step)        # ONE base-θ write per pair
            if self._pair_counters:
                out = self._pair(theta, batch, step=step, tag=tag)
            else:
                out = self._pair(theta, batch)
            return np.asarray(out, np.float32)

        def read(perturbed, t):
            self.write(perturbed, step)
            if self._counters:
                return self.device.measure_cost(batch, step=step, tag=t)
            return self.device.measure_cost(batch)

        return np.asarray([read(_np_axpy(1.0, theta, params), tag),
                           read(_np_axpy(-1.0, theta, params), tag + 1)],
                          np.float32)

    def accuracy(self, params, batch, step=None) -> float:
        if self._acc is None:
            raise NotImplementedError(
                f"{self.name} exposes no measure_accuracy")
        self.write(params, step)
        if self._acc_step:
            return float(self._acc(
                batch, step=None if step is None else int(step)))
        return float(self._acc(batch))

    def writes(self) -> int:
        return int(getattr(self.device, "writes", 0))

    def run(self, op: str, payload: tuple):
        """Dispatch one op — the single entry point workers loop on."""
        if op == "pair":
            return self.pair(*payload)
        if op == "write":
            return self.write(*payload)
        if op == "accuracy":
            return self.accuracy(*payload)
        if op == "writes":
            return self.writes()
        raise ValueError(f"unknown chip op {op!r} (expected one of {OPS})")


class Task:
    """Future-like handle for one submitted op.  ``result(timeout=...)``
    blocks until the op resolves; raises ``concurrent.futures.
    TimeoutError`` on deadline (so callers can tell a hang from a device
    error) and re-raises the op's exception on failure.  ``busy_s`` is
    the device-execution time the backend measured — the numerator of
    the farm's pipeline-utilization metric."""

    __slots__ = ("_event", "_value", "_error", "busy_s")

    def __init__(self):
        self._event = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None
        self.busy_s = 0.0

    def set_result(self, value, busy_s: float = 0.0) -> None:
        self._value = value
        self.busy_s = float(busy_s)
        self._event.set()

    def set_exception(self, error: BaseException,
                      busy_s: float = 0.0) -> None:
        if self._event.is_set():        # late zombie resolution: keep first
            return
        self._error = error
        self.busy_s = float(busy_s)
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise FuturesTimeout(
                f"op did not complete within timeout={timeout}s")
        if self._error is not None:
            raise self._error
        return self._value


class FarmBackend:
    """Abstract farm execution backend.  See the module docstring for
    the contract (per-chip FIFO, deterministic values, abandonment)."""

    #: True when ``start`` accepts live device instances; spec-only
    #: backends (process/cluster) reject instances with a TypeError.
    accepts_instances: bool = True

    def start(self, entries: Sequence[Any], *,
              fault_log: Optional[FaultLog] = None) -> List[dict]:
        """Bring up one worker per entry (device instance or
        ``DeviceSpec``); returns each chip's capability dict
        (``ChipOps.caps()``)."""
        raise NotImplementedError

    def submit(self, i: int, op: str, payload: tuple) -> Task:
        """Enqueue one op on chip ``i`` (FIFO per chip); never blocks on
        the device."""
        raise NotImplementedError

    def abandon(self, i: int) -> None:
        """Give chip ``i`` a fresh worker after a hang (see class doc)."""
        raise NotImplementedError

    def shutdown(self, wait: bool = False) -> None:
        """Tear down every worker; idempotent."""
        raise NotImplementedError

    def busy_seconds(self) -> float:
        """Total device-execution seconds across all chips since
        ``start`` — the utilization numerator."""
        raise NotImplementedError

    # -- shared helpers ------------------------------------------------------

    def _build_ops(self, entries, fault_log) -> List[ChipOps]:
        """Instances pass through; specs build against the host log
        (in-process backends share the farm's ``FaultLog`` directly)."""
        ops = []
        for entry in entries:
            if isinstance(entry, DeviceSpec):
                ops.append(ChipOps(entry.build(log=fault_log)))
            else:
                ops.append(ChipOps(entry))
        return ops


class SerialBackend(FarmBackend):
    """Inline execution on the submitting thread — zero concurrency,
    zero extra threads.  The parity oracle: a farm on this backend is
    the plain sequential program, so thread/process trajectories are
    verified against it bit-for-bit, and it is the fallback when a
    deployment forbids spawning anything."""

    def __init__(self):
        self._ops: List[ChipOps] = []
        self._busy = 0.0
        self._lock = threading.Lock()
        self._down = False

    def start(self, entries, *, fault_log=None):
        self._ops = self._build_ops(entries, fault_log)
        return [op.caps() for op in self._ops]

    def submit(self, i, op, payload):
        task = Task()
        t0 = time.perf_counter()
        try:
            value = self._ops[i].run(op, payload)
        except Exception as e:          # noqa: BLE001 — device failure
            task.set_exception(e, time.perf_counter() - t0)
        else:
            busy = time.perf_counter() - t0
            with self._lock:
                self._busy += busy
            task.set_result(value, busy)
        return task

    def abandon(self, i):
        """Nothing to replace — the op ran (and hung) on the caller."""

    def shutdown(self, wait=False):
        self._down = True

    def busy_seconds(self):
        with self._lock:
            return self._busy


#: Registry: name -> zero-config constructor.  ``thread``/``process``/
#: ``cluster`` register themselves on import (``backend/__init__.py``).
BACKENDS: Dict[str, Callable[[], FarmBackend]] = {"serial": SerialBackend}


def make_backend(backend) -> FarmBackend:
    """Resolve ``backend``: a ``FarmBackend`` instance passes through, a
    registered name constructs one."""
    if isinstance(backend, FarmBackend):
        return backend
    if isinstance(backend, str):
        if backend not in BACKENDS:
            raise ValueError(f"unknown farm backend {backend!r} — "
                             f"registered: {sorted(BACKENDS)}")
        return BACKENDS[backend]()
    raise TypeError(f"backend must be a name or FarmBackend instance, "
                    f"got {type(backend).__name__}")
