"""Process backend: one worker process per chip, real kills on hangs.

Why processes: the thread backend can only *abandon* a hung instrument
call — the zombie thread parks until the device releases it, and a
GIL-holding device driver (pure-Python instrument stacks are the common
case) serializes k chips to k× single-chip wall-clock.  One worker
process per chip removes both limits: each chip's transactions run under
their own GIL (k GIL-bound chips probe in parallel), and ``abandon(i)``
is ``SIGTERM`` — the hung worker actually dies and a fresh one respawns
from the chip's ``DeviceSpec``.  PR 6's hung-thread abandonment becomes
a real process kill, strictly stronger.

State contract across the boundary:

* Devices are built IN-WORKER from picklable ``DeviceSpec``s (live
  instances are rejected — a device must live where its transactions
  run).  Identical specs build identical chips, and readout noise is
  counter-keyed on (device seed, step, tag), so the process backend is
  bit-identical to thread/serial execution.
* ``FarmHealth``/quarantine and the ``FaultLog`` stay HOST-SIDE with the
  farm.  Workers record injected-fault events into a worker-local log
  and ship them back with each reply; the host runner folds them into
  the farm's log, so ``fault_summary()`` sees one merged stream.
* A retry after a kill re-runs the whole probe transaction, which
  starts by writing the base θ — a respawned worker needs no state
  restore beyond its spec.  (A ``FaultyChip``'s per-(step, tag) attempt
  counters die with the worker; non-kill retries — the bit-exactness
  path — never lose them because device exceptions leave the worker
  alive.)

Each chip pairs a long-lived worker process (duplex pipe, FIFO by
construction) with a host-side runner thread that services the chip's
task queue; the runner survives worker deaths and respawns the process.

The default start method is ``fork`` (workers only run numpy + pure
Python, and fork makes respawn-after-kill milliseconds); pass
``context="spawn"`` for environments where forking a JAX-initialized
parent misbehaves.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import queue
import threading
import time
from typing import List, Optional

from ..faults import ChipFaultError, FaultLog
from .base import BACKENDS, ChipOps, DeviceSpec, FarmBackend, Task

#: Queue sentinel: tells a chip runner to stop servicing its worker.
_STOP = object()

#: Deadline for a freshly spawned worker's ready handshake.
START_TIMEOUT_S = 60.0


def _worker_main(conn, spec: DeviceSpec):
    """Worker process entry point: build the device from its spec, then
    loop recv (op, payload) → run → send (value, err, events, busy_s).
    Exits on EOF/sentinel via ``os._exit`` (no inherited atexit)."""
    log = FaultLog()
    try:
        ops = ChipOps(spec.build(log=log))
    except Exception as e:              # noqa: BLE001 — report, then die
        try:
            conn.send(("__init_error__", f"{type(e).__name__}: {e}"))
        finally:
            os._exit(1)
    conn.send(("__ready__", ops.caps()))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break
        if msg is None:
            break
        op, payload = msg
        t0 = time.perf_counter()
        try:
            value, err = ops.run(op, payload), None
        except Exception as e:          # noqa: BLE001 — device failure
            value, err = None, f"{type(e).__name__}: {e}"
        busy = time.perf_counter() - t0
        try:
            conn.send((value, err, log.drain(), busy))
        except (BrokenPipeError, OSError):
            break
    os._exit(0)


class _ChipWorker:
    """One chip's worker process + the host runner thread that services
    its task queue.  The runner outlives worker deaths: a kill (or a
    worker crash) fails the in-flight task and respawns the process from
    the spec, then keeps draining the queue."""

    def __init__(self, backend: "ProcessBackend", chip: int,
                 spec: DeviceSpec):
        self.backend = backend
        self.chip = chip
        self.spec = spec
        self.queue: "queue.Queue" = queue.Queue()
        self.proc = None
        self.conn = None
        self.caps: Optional[dict] = None
        self._lock = threading.Lock()   # guards proc/conn swaps
        self._spawn()
        self.thread = threading.Thread(
            target=self._loop, name=f"chip-farm-proc-{chip}", daemon=True)
        self.thread.start()

    def _spawn(self):
        ctx = self.backend._ctx
        host, remote = ctx.Pipe(duplex=True)
        proc = ctx.Process(target=_worker_main, args=(remote, self.spec),
                           name=f"chip-worker-{self.chip}", daemon=True)
        proc.start()
        remote.close()                  # child holds its own end
        if not host.poll(START_TIMEOUT_S):
            proc.terminate()
            raise ChipFaultError(
                f"chip {self.chip} ({self.spec.display_name}): worker "
                f"did not come up within {START_TIMEOUT_S}s")
        kind, info = host.recv()
        if kind != "__ready__":
            proc.join(timeout=5.0)
            raise ChipFaultError(
                f"chip {self.chip} ({self.spec.display_name}): device "
                f"construction failed in worker: {info}")
        with self._lock:
            self.proc, self.conn, self.caps = proc, host, info

    def kill(self):
        """Terminate the worker NOW (abandon): the runner's blocked
        recv sees EOF, fails the in-flight task, and respawns."""
        with self._lock:
            proc = self.proc
        if proc is not None and proc.is_alive():
            proc.terminate()

    def _loop(self):
        while True:
            item = self.queue.get()  # mgdlint: disable=MGD003 (idle FIFO wait; the _STOP sentinel always wakes it on shutdown)
            if item is _STOP:
                self._teardown()
                return
            op, payload, task = item
            if self.backend._down:
                task.set_exception(ChipFaultError(
                    f"chip {self.chip}: farm backend is shut down"))
                continue
            try:
                self.conn.send((op, payload))
                reply = self.conn.recv()
            except (EOFError, OSError, BrokenPipeError) as e:
                busy = 0.0
                task.set_exception(ChipFaultError(
                    f"chip {self.chip} ({self.spec.display_name}): "
                    f"worker died mid-transaction ({type(e).__name__}) "
                    f"— killed on timeout or crashed"), busy)
                if self.backend._down:
                    self._teardown()
                    return
                try:
                    self._respawn()
                except Exception as spawn_err:  # noqa: BLE001
                    self._fail_pending(spawn_err)
                    return
                continue
            value, err, events, busy = reply
            self.backend._account(busy)
            if events and self.backend._fault_log is not None:
                self.backend._fault_log.extend(events)
            if err is not None:
                task.set_exception(ChipFaultError(
                    f"chip {self.chip} ({self.spec.display_name}): "
                    f"{err}"), busy)
            else:
                task.set_result(value, busy)

    def _respawn(self):
        with self._lock:
            old_proc, old_conn = self.proc, self.conn
            self.proc = self.conn = None
        if old_conn is not None:
            old_conn.close()
        if old_proc is not None:
            old_proc.join(timeout=5.0)
        self._spawn()

    def _fail_pending(self, error):
        """Respawn failed — drain the queue so nothing blocks forever."""
        while True:
            try:
                item = self.queue.get_nowait()
            except queue.Empty:
                return
            if item is not _STOP:
                item[2].set_exception(ChipFaultError(
                    f"chip {self.chip}: worker respawn failed: {error}"))

    def _teardown(self):
        with self._lock:
            proc, conn = self.proc, self.conn
            self.proc = self.conn = None
        if conn is not None:
            try:
                conn.send(None)         # graceful exit request
            except (BrokenPipeError, OSError):
                pass
            conn.close()
        if proc is not None:
            proc.join(timeout=2.0)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=2.0)


class ProcessBackend(FarmBackend):
    """One worker process per chip.  Requires ``DeviceSpec`` entries —
    live instances cannot cross the process boundary."""

    accepts_instances = False

    def __init__(self, context: Optional[str] = None):
        if context is None:
            context = "fork" if "fork" in mp.get_all_start_methods() \
                else None
        self._ctx = mp.get_context(context)
        self._workers: List[_ChipWorker] = []
        self._lock = threading.Lock()
        self._busy = 0.0
        self._down = False
        self._fault_log: Optional[FaultLog] = None

    def start(self, entries, *, fault_log=None):
        for i, entry in enumerate(entries):
            if not isinstance(entry, DeviceSpec):
                raise TypeError(
                    f"the process backend rebuilds each device in its "
                    f"worker and needs DeviceSpec entries; chip {i} is a "
                    f"live {type(entry).__name__} instance (build the "
                    f"farm with backend='thread', or pass DeviceSpecs)")
        self._fault_log = fault_log
        workers = []
        try:
            for i, spec in enumerate(entries):
                workers.append(_ChipWorker(self, i, spec))
        except Exception:
            self._workers = workers
            self.shutdown()
            raise
        self._workers = workers
        return [w.caps for w in workers]

    def submit(self, i, op, payload):
        task = Task()
        if self._down:
            task.set_exception(ChipFaultError(
                f"chip {i}: farm backend is shut down"))
            return task
        self._workers[i].queue.put((op, payload, task))
        return task

    def abandon(self, i):
        """KILL chip ``i``'s worker — the process-backend upgrade over
        thread abandonment: the hung transaction dies with it, and the
        runner respawns a fresh worker from the spec."""
        self._workers[i].kill()

    def shutdown(self, wait=False):
        if self._down:
            return
        self._down = True
        with self._lock:
            workers = list(self._workers)
        for w in workers:
            w.queue.put(_STOP)
        for w in workers:
            # runners blocked in recv (op in flight) only unblock when
            # the worker dies; don't wait for a hung instrument
            w.kill()
        if wait:
            for w in workers:
                w.thread.join(timeout=5.0)
                proc = w.proc
                if proc is not None:
                    proc.join(timeout=5.0)

    def busy_seconds(self):
        with self._lock:
            return self._busy

    def _account(self, busy: float):
        with self._lock:
            self._busy += busy


BACKENDS["process"] = ProcessBackend
