"""Cluster backend stub: the wire contract for farm-over-network chips.

The scaling endgame (ROADMAP: data-parallel farms, the Oripov et al.
2025 k-chip axis) eventually puts chips on OTHER HOSTS — a rack of
instrument servers, each owning one device.  This stub pins down the
wire protocol now, so the farm/backend split is proven against it and a
real transport (gRPC, ZeroMQ, a lab message bus) only has to implement
one function:

    transport(chip_index, request_bytes) -> reply_bytes

Request/reply schema (pickled tuples, version-tagged):

    request:  (PROTOCOL_VERSION, op, payload)
        op      — one of ``base.OPS`` ("pair" | "write" | "accuracy" |
                  "writes")
        payload — the op's argument tuple (numpy trees/scalars only —
                  the same host-boundary types the process backend
                  ships over its pipe)
    reply:    (PROTOCOL_VERSION, value, err, events, busy_s)
        value   — the op result (None when ``err`` is set)
        err     — None, or a string describing the remote failure
        events  — drained worker-local ``FaultLog`` entries (the host
                  folds them into the farm's log)
        busy_s  — remote device-execution seconds (utilization metric)

Chips are addressed by index; each node builds its device from the
``DeviceSpec`` it is handed at provisioning time — exactly the process
backend's contract with the network substituted for the pipe.  Without
a transport, ``start`` raises ``NotImplementedError`` (this is a stub);
``loopback_transport`` runs the full serialize → execute → deserialize
round trip in-process so the protocol is testable today.

Ops are executed through a per-chip runner thread (FIFO preserved —
requests to one chip must not be reordered by the transport layer), so
a slow network chip overlaps with its peers just like a slow local one.
"""
from __future__ import annotations

import pickle
import threading
import time
from typing import Callable, List, Optional, Sequence

from ..faults import ChipFaultError, FaultLog
from .base import BACKENDS, ChipOps, DeviceSpec, FarmBackend
from .thread import ThreadBackend

PROTOCOL_VERSION = 1

#: transport(chip_index, request_bytes) -> reply_bytes
Transport = Callable[[int, bytes], bytes]


def encode_request(op: str, payload: tuple) -> bytes:
    return pickle.dumps((PROTOCOL_VERSION, op, payload))


def decode_request(blob: bytes):
    version, op, payload = pickle.loads(blob)
    if version != PROTOCOL_VERSION:
        raise ChipFaultError(f"cluster protocol version mismatch: "
                             f"node speaks {version}, host "
                             f"{PROTOCOL_VERSION}")
    return op, payload


def encode_reply(value, err: Optional[str], events, busy_s: float) -> bytes:
    return pickle.dumps((PROTOCOL_VERSION, value, err, events, busy_s))


def decode_reply(blob: bytes):
    version, value, err, events, busy_s = pickle.loads(blob)
    if version != PROTOCOL_VERSION:
        raise ChipFaultError(f"cluster protocol version mismatch: "
                             f"node speaks {version}, host "
                             f"{PROTOCOL_VERSION}")
    return value, err, events, busy_s


def serve_request(ops: ChipOps, log: Optional[FaultLog],
                  request: bytes) -> bytes:
    """One node-side dispatch: what a cluster node's request handler
    runs per message (the worker loop of ``process.py``, reshaped as a
    function of bytes)."""
    op, payload = decode_request(request)
    t0 = time.perf_counter()
    try:
        value, err = ops.run(op, payload), None
    except Exception as e:              # noqa: BLE001 — device failure
        value, err = None, f"{type(e).__name__}: {e}"
    busy = time.perf_counter() - t0
    return encode_reply(value, err, log.drain() if log else [], busy)


def loopback_transport(specs: Sequence[DeviceSpec]) -> Transport:
    """An in-process transport running the full wire round trip —
    request bytes → node dispatch → reply bytes — against devices built
    from ``specs``.  Proves the protocol (and pickling of every payload
    type) without a network."""
    logs = [FaultLog() for _ in specs]
    built = [ChipOps(spec.build(log=log))
             for spec, log in zip(specs, logs)]

    def transport(i: int, request: bytes) -> bytes:
        return serve_request(built[i], logs[i], request)

    return transport


class _RemoteOps:
    """ChipOps-shaped adapter: runs every op through the transport, so
    the per-chip runner machinery (reused from ``ThreadBackend``) drives
    remote chips unchanged."""

    def __init__(self, backend: "ClusterStubBackend", chip: int,
                 spec: DeviceSpec):
        self.backend = backend
        self.chip = chip
        self.spec = spec
        self.name = spec.display_name

    def run(self, op: str, payload: tuple):
        reply = self.backend.transport(
            self.chip, encode_request(op, payload))
        value, err, events, busy_s = decode_reply(reply)
        if events and self.backend._fault_log is not None:
            self.backend._fault_log.extend(events)
        if err is not None:
            raise ChipFaultError(
                f"chip {self.chip} ({self.name}) [remote]: {err}")
        return value

    def caps(self) -> dict:
        """Capability probe: remote accuracy/pair support is resolved
        from the spec host-side (nodes build from the same spec)."""
        cls = self.spec.cls
        return {"name": self.name,
                "pair": callable(getattr(cls, "measure_pair", None)),
                "accuracy": callable(getattr(cls, "measure_accuracy",
                                             None))}


class ClusterStubBackend(ThreadBackend):
    """Farm backend speaking the cluster wire protocol.  A stub: without
    a ``transport`` it refuses to start; with one (e.g.
    ``loopback_transport`` for tests, a real RPC client in deployment)
    it drives remote chips through per-chip runner threads, FIFO per
    chip.  ``abandon`` replaces the runner (the stub cannot kill a
    remote process — a real transport would add a node-reset RPC)."""

    accepts_instances = False

    def __init__(self, transport: Optional[Transport] = None):
        super().__init__()
        self.transport = transport
        self._fault_log: Optional[FaultLog] = None

    def start(self, entries, *, fault_log=None):
        if self.transport is None:
            raise NotImplementedError(
                "ClusterStubBackend is the wire-contract stub: pass "
                "transport=... (see loopback_transport) or run a real "
                "cluster client implementing transport(chip, request_"
                "bytes) -> reply_bytes")
        for i, entry in enumerate(entries):
            if not isinstance(entry, DeviceSpec):
                raise TypeError(
                    f"the cluster backend provisions nodes from "
                    f"DeviceSpec entries; chip {i} is a live "
                    f"{type(entry).__name__} instance")
        self._fault_log = fault_log
        from .thread import _Runner
        remotes: List[_RemoteOps] = [
            _RemoteOps(self, i, spec) for i, spec in enumerate(entries)]
        self._runners = [_Runner(self, i, ops, generation=0)
                         for i, ops in enumerate(remotes)]
        return [ops.caps() for ops in remotes]


BACKENDS["cluster"] = ClusterStubBackend
