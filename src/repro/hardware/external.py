"""Chip-in-the-loop: a plant on the far side of a host boundary.

``ExternalPlant`` wraps any host-side device object exposing the minimal
lab-instrument API

    device.set_params(params)          # persistent analog write
    device.measure_cost(batch) -> float  # present input, read ONE scalar

and turns it into a ``Plant`` the jitted MGD step can drive: every
``read_cost`` lowers to an *ordered* ``io_callback`` (write θ̃-perturbed
params → present batch → read cost), so the optimizer stays the same
pure-JAX program whether the device is a JAX function, a subprocess, or
a physical chip behind a serial link.  The optimizer never sees device
internals — defects, write noise and readout noise all live in the host
object (paper §4/§6: the regime where backprop-through-a-model breaks
and model-free MGD does not).

Ordered callbacks sequence the host I/O with program order but are not
allowed inside ``lax.cond`` branches, so external plants run the one
cond-free MGD step: ``MGDConfig(mode="central", tau_theta=1)`` without
replay (forward mode's C₀ refresh and every windowed update are conds);
``make_mgd_step`` enforces this.  Temporal integration windows belong in
the host loop driving the chip, not inside the traced step.

Host devices must be NUMPY-PURE: a callback that dispatches JAX ops can
deadlock against the in-flight XLA program that invoked it (two threads
feeding one CPU client) — see ``devices.SimulatedAnalogChip``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .base import Plant, PlantMeta

try:                                    # jax >= 0.4.9
    from jax.experimental import io_callback as _io_callback
except ImportError:                     # pragma: no cover - old jax
    _io_callback = None


class ExternalPlant(Plant):
    """Host-callback boundary around an opaque device object."""

    def __init__(self, device: Any, *, meta: Optional[PlantMeta] = None):
        for attr in ("set_params", "measure_cost"):
            if not callable(getattr(device, attr, None)):
                raise TypeError(
                    f"external device must expose {attr}(); got "
                    f"{type(device).__name__}")
        if _io_callback is None:        # pragma: no cover - old jax
            raise RuntimeError("ExternalPlant needs jax.experimental."
                               "io_callback (jax >= 0.4.9)")
        self.device = device
        self.meta = meta or PlantMeta(name="external", external=True)

    def _host_read(self, params, batch):
        self.device.set_params(params)
        return np.float32(self.device.measure_cost(batch))

    def read_cost(self, params, batch, *, step, tag: int = 0):
        return _io_callback(
            self._host_read, jax.ShapeDtypeStruct((), jnp.float32),
            params, batch, ordered=True)

    def _host_write(self, params):
        self.device.set_params(params)
        return np.int32(0)

    def write_params(self, params, *, step, prev=None):
        """Commit the post-update parameters to the chip.  The trainer's
        belief (the returned value) stays its own: analog write noise on
        the device is invisible by construction — exactly the open-loop
        write the paper's chip-in-the-loop setup performs."""
        _io_callback(self._host_write, jax.ShapeDtypeStruct((), jnp.int32),
                     params, ordered=True)
        return params
