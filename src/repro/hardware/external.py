"""Chip-in-the-loop: a plant on the far side of a host boundary.

``ExternalPlant`` wraps any host-side device object exposing the minimal
lab-instrument API

    device.set_params(params)          # persistent analog write
    device.measure_cost(batch) -> float  # present input, read ONE scalar

and turns it into a ``Plant`` the jitted MGD step can drive: every
``read_cost`` lowers to an *ordered* ``io_callback`` (write θ̃-perturbed
params → present batch → read cost), so the optimizer stays the same
pure-JAX program whether the device is a JAX function, a subprocess, or
a physical chip behind a serial link.  The optimizer never sees device
internals — defects, write noise and readout noise all live in the host
object (paper §4/§6: the regime where backprop-through-a-model breaks
and model-free MGD does not).

Three optional device capabilities refine the boundary:

* ``measure_cost(batch, *, step, tag)`` — devices whose readout noise is
  counter-keyed accept the optimizer's (step, tag) pair, so the +/−
  probe reads of a central pair are distinguishable and a restarted run
  replays the identical noise stream.  The signature is inspected ONCE
  at construction; plain 1-arg devices keep working unchanged.
* ``measure_pair(theta, batch, *, step, tag) -> (C₊, C₋)`` — a
  differential probe line: the perturbation θ̃ is applied transiently at
  the parameter (the paper's dedicated-perturbation-line picture), never
  through the persistent write path.  ``read_cost_pair`` then costs ONE
  ``set_params`` of the base θ per central pair instead of two full
  writes of the perturbed tree, in a single host round-trip.
* ``set_params(params, *, step)`` — drifting devices (see
  ``devices.DriftingAnalogChip``) timestamp every persistent write with
  the optimizer's step counter, so readouts reconstruct how long the
  stored weights have been aging — deterministically across restarts.

Ordered callbacks sequence the host I/O with program order but are not
allowed inside ``lax.cond`` branches, so external plants run the one
cond-free MGD step: ``MGDConfig(mode="central", tau_theta=1)`` without
replay (forward mode's C₀ refresh and every windowed update are conds);
``build_mgd_step`` enforces this.  Temporal integration windows belong in
the host loop driving the chip, not inside the traced step.

Host devices must be NUMPY-PURE: a callback that dispatches JAX ops can
deadlock against the in-flight XLA program that invoked it (two threads
feeding one CPU client) — see ``devices.SimulatedAnalogChip``.

Real instruments hang and crash, not just add noise.  Passing
``fault_policy=FaultPolicy(...)`` bounds every device transaction by a
timeout and retries it with exponential backoff; because readout noise
is counter-keyed on (step, tag), a successful retry is bit-identical to
the read a fault-free run would have produced, so checkpoint/resume
stays exact through transient faults.  A single external chip that
exhausts its retries raises ``ChipFaultError`` with the device name and
counters attached (masking out a failed read needs a farm — see
``farm.ChipFarm``).
"""
from __future__ import annotations

import inspect
import weakref
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .base import Plant, PlantMeta
from .faults import ChipFaultError, FaultLog, FaultPolicy, guarded_call

try:                                    # jax >= 0.4.9
    from jax.experimental import io_callback as _io_callback
except ImportError:                     # pragma: no cover - old jax
    _io_callback = None


def accepts_counters(fn) -> bool:
    """True when ``fn`` accepts the optimizer's ``step``/``tag`` keywords
    (directly or through **kwargs).  Inspected once at plant construction
    — a per-read signature probe would sit on the training hot loop."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):     # builtins/C callables: be safe
        return False
    if any(p.kind == inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return True
    return "step" in params and "tag" in params


def accepts_step(fn) -> bool:
    """True when ``fn`` (a device's ``set_params``) accepts the optimizer's
    ``step`` keyword — drifting devices timestamp each persistent write so
    readouts know how long the stored weights have been aging.  Inspected
    once at construction, like ``accepts_counters``."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):     # builtins/C callables: be safe
        return False
    if any(p.kind == inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return True
    return "step" in params


def check_device(device: Any) -> None:
    """Validate the minimal lab-instrument surface of ``device``."""
    for attr in ("set_params", "measure_cost"):
        if not callable(getattr(device, attr, None)):
            raise TypeError(
                f"external device must expose {attr}(); got "
                f"{type(device).__name__}")


class ExternalPlant(Plant):
    """Host-callback boundary around an opaque device object.

    **Fault tolerance** (``fault_policy=hardware.FaultPolicy(...)``):
    every device transaction (write + read, as one unit) runs on a side
    thread bounded by ``timeout_s`` and retried with exponential backoff
    — a retry re-runs the whole transaction against the same (step, tag)
    counters, so a successful retry returns the identical counter-keyed
    readout a fault-free run would have seen.  A single chip has no
    farm to mask it, so exhausting the retries raises ``ChipFaultError``
    (naming the device, step and tag) instead of hanging or surfacing an
    anonymous worker traceback.  Without a policy, device exceptions are
    still re-raised with the device name attached.
    """

    def __init__(self, device: Any, *, meta: Optional[PlantMeta] = None,
                 fault_policy: Optional[FaultPolicy] = None,
                 fault_log: Optional[FaultLog] = None):
        check_device(device)
        if _io_callback is None:        # pragma: no cover - old jax
            raise RuntimeError("ExternalPlant needs jax.experimental."
                               "io_callback (jax >= 0.4.9)")
        if fault_policy is not None and not isinstance(fault_policy,
                                                       FaultPolicy):
            raise TypeError(f"fault_policy must be a hardware.FaultPolicy, "
                            f"got {type(fault_policy).__name__}")
        self.device = device
        self.policy = fault_policy
        self.fault_log = fault_log if fault_log is not None else FaultLog()
        # capability inspection happens here, once — not per read
        self._measure_counters = accepts_counters(device.measure_cost)
        self._write_step = accepts_step(device.set_params)
        pair = getattr(device, "measure_pair", None)
        self._measure_pair = pair if callable(pair) else None
        self._pair_counters = (self._measure_pair is not None
                               and accepts_counters(self._measure_pair))
        self._label = (f"device {getattr(device, 'name', None) or ''}"
                       f"({type(device).__name__})").replace(" (", "(")
        self._attempt_pool = None
        if fault_policy is not None:
            # attempt threads: hung attempts hold a worker until their
            # sleep releases, so keep spares beyond retries+1
            self._attempt_pool = ThreadPoolExecutor(
                max_workers=fault_policy.retries + 2,
                thread_name_prefix="ext-plant")
            self._finalizer = weakref.finalize(
                self, self._attempt_pool.shutdown, wait=False)
        self.meta = meta or PlantMeta(
            name="external", external=True,
            fault_tolerant=fault_policy is not None)

    def fault_summary(self) -> dict:
        """Fault telemetry (events by kind) — empty dict means a clean
        run."""
        n = len(self.fault_log)
        return {"events": n, "by_kind": self.fault_log.counts()} if n else {}

    def close(self) -> None:
        """Shut the attempt pool down now.  Idempotent (also runs at GC);
        a no-op for policy-free plants, which own no threads."""
        if self._attempt_pool is not None:
            self._finalizer()

    def fence(self, timeout=None) -> None:
        """Drain in-flight work — part of the uniform lifecycle contract
        (``ChipFarm``/``OnlineService`` share it).  ExternalPlant issues
        every device transaction synchronously inside the ordered
        ``io_callback``, so there is never anything in flight: a no-op
        that exists so callers can fence any plant before a parameter
        swap or checkpoint without type-sniffing."""

    def __enter__(self) -> "ExternalPlant":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _set_params(self, params, step):
        """One persistent device write, timestamped for step-capable
        (drifting) devices."""
        if self._write_step:
            self.device.set_params(params, step=int(step))
        else:
            self.device.set_params(params)

    def _guarded(self, fn, args, step, tag):
        """One transaction under the fault policy; raises ChipFaultError
        with full context after exhausting the retries."""
        out, _, err = guarded_call(
            self._attempt_pool, fn, args, policy=self.policy,
            label=self._label, log=self.fault_log, step=step, tag=tag)
        if err is not None:
            self.fault_log.record("retry-exhausted", self._label,
                                  step=step, tag=tag, detail=str(err))
            raise ChipFaultError(
                f"{self._label}: transaction failed after "
                f"{self.policy.retries + 1} attempts at step={step} "
                f"tag={tag}: {err}") from err
        return out

    def _read_txn(self, params, batch, step, tag):
        self._set_params(params, step)
        if self._measure_counters:
            return np.float32(self.device.measure_cost(
                batch, step=int(step), tag=int(tag)))
        return np.float32(self.device.measure_cost(batch))

    def _host_read(self, params, batch, step, tag):
        if self.policy is not None:
            return np.float32(self._guarded(
                self._read_txn, (params, batch, step, tag), step, tag))
        try:
            return self._read_txn(params, batch, step, tag)
        except Exception as e:
            raise ChipFaultError(
                f"{self._label}: read failed at step={int(step)} "
                f"tag={int(tag)}: {e}") from e

    def read_cost(self, params, batch, *, step, tag: int = 0):
        return _io_callback(
            self._host_read, jax.ShapeDtypeStruct((), jnp.float32),
            params, batch, jnp.asarray(step, jnp.int32),
            jnp.asarray(tag, jnp.int32), ordered=True)

    def _pair_txn(self, params, theta, batch, step, tag):
        # ONE persistent write of the base θ; the antithetic pair rides
        # the device's transient probe line (no second full-tree write).
        self._set_params(params, step)
        if self._pair_counters:
            c_plus, c_minus = self._measure_pair(
                theta, batch, step=int(step), tag=int(tag))
        else:
            c_plus, c_minus = self._measure_pair(theta, batch)
        return np.asarray([c_plus, c_minus], np.float32)

    def _host_read_pair(self, params, theta, batch, step, tag):
        if self.policy is not None:
            return self._guarded(
                self._pair_txn, (params, theta, batch, step, tag), step, tag)
        try:
            return self._pair_txn(params, theta, batch, step, tag)
        except Exception as e:
            raise ChipFaultError(
                f"{self._label}: pair read failed at step={int(step)} "
                f"tag={int(tag)}: {e}") from e

    def read_cost_pair(self, params, theta, batch, *, step, tag: int = 0):
        """Antithetic readout C(θ±θ̃).  Devices with a differential probe
        line (``measure_pair``) pay one base-θ write and one host round
        trip per pair; plain devices fall back to the base class's two
        independent reads (two full perturbed-tree writes)."""
        if self._measure_pair is None:
            return super().read_cost_pair(params, theta, batch,
                                          step=step, tag=tag)
        out = _io_callback(
            self._host_read_pair, jax.ShapeDtypeStruct((2,), jnp.float32),
            params, theta, batch, jnp.asarray(step, jnp.int32),
            jnp.asarray(tag, jnp.int32), ordered=True)
        return out[0], out[1]

    def _write_txn(self, params, step):
        self._set_params(params, step)
        return np.int32(0)

    def _host_write(self, params, step):
        if self.policy is not None:
            return self._guarded(self._write_txn, (params, step), step, -1)
        try:
            return self._write_txn(params, step)
        except Exception as e:
            raise ChipFaultError(
                f"{self._label}: write failed at step={int(step)}: {e}"
            ) from e

    def write_params(self, params, *, step, prev=None):
        """Commit the post-update parameters to the chip.  The trainer's
        belief (the returned value) stays its own: analog write noise on
        the device is invisible by construction — exactly the open-loop
        write the paper's chip-in-the-loop setup performs."""
        _io_callback(self._host_write, jax.ShapeDtypeStruct((), jnp.int32),
                     params, jnp.asarray(step, jnp.int32), ordered=True)
        return params
