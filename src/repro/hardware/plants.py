"""In-process hardware models: noisy and quantized plants.

``NoisyPlant`` absorbs the imperfection logic the paper studies in §3.5
(Figs 8–10) that used to be inlined across ``core/mgd.py`` (σ_C, σ_θ) and
``core/noise.py`` (σ_a defects live in the device's loss/probe functions;
see ``hardware.devices`` for per-device-seed samplers):

* σ_C cost-readout noise — one gaussian per scalar read, keyed on
  (device seed, step, tag).
* σ_θ persistent-write noise — θ lands as θ + N(0, σ_θ·Δθ) per element,
  keyed on (device seed + 77, leaf index, step).

Both key derivations reproduce the historical ``MGDConfig.cost_noise`` /
``update_noise`` paths of the DISCRETE driver bit-for-bit, so σ = 0 is
bit-identical (f32) to ``IdealPlant`` and cfg-built Algorithm-1 plants
replay old trajectories exactly.  The continuous driver's σ_C stream was
re-keyed onto the same (seed, tag, step) scheme in this refactor — old
``AnalogMGDConfig(cost_noise>0)`` runs draw a different (statistically
identical) noise sequence; σ = 0 analog runs are unchanged.

``QuantizedPlant`` expresses the scenario the paper motivates but the
repo previously could not: persistent weight writes go through a
limited-bit DAC (clip to ±w_clip, round to 2^bits − 1 levels) and an
optional slow-write lag — each write only moves the stored value a
fraction 1 − e^{−1/τ_w} toward the commanded target.  Probe
perturbations bypass the DAC by default (the paper's picture of a
dedicated perturbation line / LFSR at each synapse); set
``quantize_probes=True`` to model probes that must also round-trip the
DAC (Δθ below the LSB then becomes invisible and training stalls — see
benchmarks/hardware_plants.py).

The dual imperfection — the cost READOUT through a k-bit ADC — is
``adc_bits``/``adc_mode``: every ``read_cost``/``read_cost_pair`` scalar
is clipped to [0, adc_range] and rounded to the ADC grid,
deterministically (``"round"``) or with counter-keyed stochastic
rounding (``"stochastic"``, unbiased: E[q] = C).  Because MGD's only
feedback is C̃ = C(θ+θ̃) − C₀, an ADC LSB above the typical |C̃| floors
the error signal at 0 and training stalls — the Δθ·|∇C| signal floor the
paper's Fig. 8 implies, mapped onto ADC bits (stochastic rounding
recovers the signal in expectation at the cost of readout variance; see
benchmarks/hardware_plants.py and EXPERIMENTS.md §Hardware).

``DriftingPlant`` is the time-VARYING device the follow-up scaling study
(Oripov et al. 2025) flags as the open deployment question: the stored
weights move *between* writes — an Ornstein–Uhlenbeck random walk
(``mode="walk"``: per-step gaussian kicks, optionally mean-reverting)
or a relaxation toward a rest state (``mode="decay"``: analog memory
leakage).  One drift transition lands after every committed write event,
keyed on the optimizer's step counter — the same determinism contract as
``NoisyPlant``/``SimulatedAnalogChip``, so checkpoint/resume replays the
identical device trajectory.  MGD's continuous zero-order feedback then
re-trims the aging device online; ``benchmarks/drift_aging.py`` measures
the drift rate at which that feedback, scheduled recalibration, and no
mitigation each collapse.
"""
from __future__ import annotations

import copy
import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .base import IdealPlant, Plant, PlantMeta


def _gauss_noise(seed, step, tag, shape=()):
    """Standard-normal noise from a counter-based key — no threaded PRNG
    state, so checkpoint/restart replays the identical hardware noise."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), tag)
    key = jax.random.fold_in(key, step)
    return jax.random.normal(key, shape, jnp.float32)


class NoisyPlant(Plant):
    """Device with gaussian readout noise and noisy persistent writes."""

    def __init__(self, loss_fn: Callable, *,
                 cost_noise: float = 0.0,
                 write_noise: float = 0.0,
                 dtheta: float = 1e-3,
                 seed: int = 0,
                 probe_fn: Optional[Callable] = None,
                 meta: Optional[PlantMeta] = None):
        self.loss_fn = loss_fn
        self.cost_noise = float(cost_noise)
        self.write_noise = float(write_noise)
        self.dtheta = float(dtheta)
        self.seed = int(seed)
        self.probe_fn = probe_fn
        self.meta = meta or PlantMeta(
            name="noisy", cost_noise=self.cost_noise,
            write_noise=self.write_noise)

    def _noisy(self, cost, step, tag):
        if self.cost_noise:
            cost = cost + self.cost_noise * _gauss_noise(self.seed, step, tag)
        return cost

    def read_cost(self, params, batch, *, step, tag: int = 0):
        return self._noisy(self.loss_fn(params, batch), step, tag)

    def write_params(self, params, *, step, prev=None):
        if not self.write_noise:
            return params
        # σ_θ in units of Δθ (paper §3.5 / Fig. 9): each element lands as
        # θ + N(0, σ_θ·Δθ), leaf keys counted from 1 (historical layout).
        leaves, treedef = jax.tree_util.tree_flatten(params)
        out = []
        for i, x in enumerate(leaves, start=1):
            k = jax.random.fold_in(jax.random.PRNGKey(self.seed + 77), i)
            k = jax.random.fold_in(k, step)
            out.append(x + self.write_noise * self.dtheta * jax.random.normal(
                k, x.shape, jnp.float32).astype(x.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    def apply_perturbed(self, params, batch, probe, *, step, tags):
        costs = super().apply_perturbed(params, batch, probe,
                                        step=step, tags=tags)
        if self.cost_noise:
            noise = jnp.stack([_gauss_noise(self.seed, step, t)
                               for t in tags])
            costs = costs + self.cost_noise * noise
        return costs


class QuantizedPlant(Plant):
    """Device whose persistent weight memory sits behind a limited-bit DAC
    with an optional first-order slow-write lag, and (optionally) whose
    cost readout passes a limited-bit ADC."""

    def __init__(self, loss_fn: Callable, *,
                 bits: int = 8,
                 w_clip: float = 2.0,
                 write_tau: float = 0.0,
                 quantize_probes: bool = False,
                 adc_bits: Optional[int] = None,
                 adc_mode: str = "round",
                 adc_range: float = 1.0,
                 seed: int = 0,
                 probe_fn: Optional[Callable] = None,
                 meta: Optional[PlantMeta] = None):
        if bits < 1:
            raise ValueError(f"weight DAC needs >= 1 bit, got {bits}")
        if adc_bits is not None and adc_bits < 1:
            raise ValueError(f"cost ADC needs >= 1 bit, got {adc_bits}")
        if adc_mode not in ("round", "stochastic"):
            raise ValueError(f"adc_mode must be 'round' or 'stochastic', "
                             f"got {adc_mode!r}")
        self.loss_fn = loss_fn
        self.bits = int(bits)
        self.w_clip = float(w_clip)
        self.write_tau = float(write_tau)
        self.quantize_probes = bool(quantize_probes)
        self.adc_bits = None if adc_bits is None else int(adc_bits)
        self.adc_mode = adc_mode
        self.adc_range = float(adc_range)
        self.seed = int(seed)
        self.probe_fn = probe_fn
        self.meta = meta or PlantMeta(name=f"dac{bits}", weight_bits=self.bits,
                                      adc_bits=self.adc_bits)

    @property
    def lsb(self) -> float:
        return 2.0 * self.w_clip / (2 ** self.bits - 1)

    @property
    def adc_lsb(self) -> float:
        if self.adc_bits is None:
            raise ValueError("plant has no cost ADC (adc_bits=None)")
        return self.adc_range / (2 ** self.adc_bits - 1)

    def _quantize_leaf(self, x):
        scale = jnp.float32(self.lsb)
        q = jnp.round((jnp.clip(x, -self.w_clip, self.w_clip)
                       + self.w_clip) / scale)
        return (q * scale - self.w_clip).astype(x.dtype)

    def quantize(self, params):
        return jax.tree_util.tree_map(self._quantize_leaf, params)

    def write_params(self, params, *, step, prev=None):
        target = params
        if self.write_tau and prev is not None:
            # slow write: the memory cell only slews a fraction of the
            # commanded step per write event (first-order lag, τ_w in
            # units of write events).
            alpha = 1.0 - math.exp(-1.0 / self.write_tau)
            target = jax.tree_util.tree_map(
                lambda p, t: (p.astype(jnp.float32)
                              + alpha * (t.astype(jnp.float32)
                                         - p.astype(jnp.float32))
                              ).astype(t.dtype),
                prev, target)
        return self.quantize(target)

    def _adc(self, cost, step, tag):
        """k-bit cost readout: clip to [0, adc_range], land on the ADC
        grid.  Stochastic mode rounds up with probability equal to the
        fractional code (unbiased), counter-keyed on (seed, step, tag) so
        checkpoint/restart replays the identical readout stream."""
        if self.adc_bits is None:
            return cost
        scale = jnp.float32(self.adc_lsb)
        code = jnp.clip(cost.astype(jnp.float32), 0.0, self.adc_range) / scale
        if self.adc_mode == "stochastic":
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 131), tag)
            key = jax.random.fold_in(key, step)
            code = jnp.floor(code + jax.random.uniform(key, (), jnp.float32))
        else:
            code = jnp.round(code)
        return code * scale

    def read_cost(self, params, batch, *, step, tag: int = 0):
        if self.quantize_probes:
            params = self.quantize(params)
        return self._adc(self.loss_fn(params, batch), step, tag)

    # read_cost_pair needs no override: the base class issues two
    # read_cost calls with consecutive tags, so each half of the
    # antithetic pair round-trips the ADC independently (two physical
    # conversions), exactly like hardware.

    def apply_perturbed(self, params, batch, probe, *, step, tags):
        # persistent params are already on the DAC grid (write_params);
        # the probe line bypasses the DAC unless quantize_probes, which
        # the fused kernels cannot express (θ̃ is generated in-kernel).
        if self.quantize_probes:
            raise NotImplementedError(
                "quantize_probes=True has no fused kernel path")
        costs = super().apply_perturbed(params, batch, probe,
                                        step=step, tags=tags)
        if self.adc_bits is not None:
            costs = jnp.stack([self._adc(costs[i], step, t)
                               for i, t in enumerate(tags)])
        return costs


class DriftingPlant(Plant):
    """Device whose stored weights age BETWEEN writes (drift/aging model).

    Wraps any in-process plant (composition: DAC quantization, write
    noise, ADC readout all keep applying through ``inner``).  After every
    committed write event the landed weights take one drift transition

        θ ← rest + a·(θ − rest) + σ_d·ξ(seed, leaf, step)

    with ``a = exp(−1/drift_tau)`` (``a = 1`` when ``drift_tau = 0``):

    * ``mode="walk"`` — Ornstein–Uhlenbeck random walk: per-step gaussian
      kicks of std ``drift_rate`` (σ_d), optionally mean-reverting toward
      ``rest`` when ``drift_tau`` is set.  ``drift_tau = 0`` is the pure
      random walk (free diffusion of the stored values).
    * ``mode="decay"`` — relaxation toward ``rest`` with time constant
      ``drift_tau`` write events (analog memory leakage / state decay);
      ``drift_rate`` may ride along as diffusion on top.

    The kick is keyed on (device seed, leaf index, step counter) — never
    on threaded RNG state — so a checkpointed/restarted run replays the
    IDENTICAL device trajectory (the same contract as ``NoisyPlant`` and
    ``SimulatedAnalogChip``).  Because the optimizer carries the landed
    tree, the walk accumulates naturally across steps, and MGD's online
    feedback measures cost at the drifted weights and re-trims from
    wherever the device actually is.  ``drift``/``age`` expose the bare
    transition so benchmarks can age a device with NO optimizer writes
    (the no-mitigation / scheduled-recalibration baselines in
    ``benchmarks/drift_aging.py``).

    External plants are rejected: their true weights live behind the host
    boundary, so drifting the trainer-side belief would age the wrong
    copy — use ``hardware.devices.DriftingAnalogChip`` behind
    ``ExternalPlant``/``ChipFarm`` for the chip-in-the-loop version.
    """

    def __init__(self, inner: Plant, *, mode: str = "walk",
                 drift_rate: float = 0.0, drift_tau: float = 0.0,
                 rest: float = 0.0, seed: int = 0,
                 meta: Optional[PlantMeta] = None):
        if not isinstance(inner, Plant):
            raise TypeError(f"inner must be a repro.hardware.Plant, got "
                            f"{type(inner).__name__}")
        if inner.meta.external:
            raise ValueError(
                "DriftingPlant cannot wrap an external plant — the device's "
                "stored weights live behind the host boundary; put the drift "
                "IN the device (hardware.devices.DriftingAnalogChip) instead")
        if mode not in ("walk", "decay"):
            raise ValueError(f"drift mode must be 'walk' or 'decay', "
                             f"got {mode!r}")
        if mode == "walk" and drift_rate <= 0.0:
            raise ValueError("mode='walk' needs drift_rate > 0 (σ_d, the "
                             "per-step random-walk std)")
        if mode == "decay" and drift_tau <= 0.0:
            raise ValueError("mode='decay' needs drift_tau > 0 (the "
                             "relaxation time constant, in write events)")
        self.inner = inner
        self.mode = mode
        self.drift_rate = float(drift_rate)
        self.drift_tau = float(drift_tau)
        self.rest = float(rest)
        self.seed = int(seed)
        self.probe_fn = inner.probe_fn
        self.meta = meta or dataclasses.replace(
            inner.meta, name=f"drifting-{inner.meta.name}", drift_mode=mode,
            drift_rate=self.drift_rate, drift_tau=self.drift_tau,
            drift_rest=self.rest)

    # -- the aging transition (public: benchmarks age devices write-free) ----
    def drift(self, params, step):
        """One drift transition of the stored weights, keyed on ``step``."""
        a = math.exp(-1.0 / self.drift_tau) if self.drift_tau else 1.0
        leaves, treedef = jax.tree_util.tree_flatten(params)
        out = []
        for i, x in enumerate(leaves, start=1):
            y = x.astype(jnp.float32)
            if self.drift_tau:
                y = self.rest + a * (y - self.rest)
            if self.drift_rate:
                k = jax.random.fold_in(
                    jax.random.PRNGKey(self.seed + 313), i)
                k = jax.random.fold_in(k, step)
                y = y + self.drift_rate * jax.random.normal(
                    k, x.shape, jnp.float32)
            out.append(y.astype(x.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    def age(self, params, start_step, n_steps: int):
        """``n_steps`` drift transitions with NO writes (a held device):
        steps ``start_step .. start_step + n_steps − 1``.  Jit/scan-safe."""
        return jax.lax.fori_loop(
            0, n_steps, lambda j, p: self.drift(p, start_step + j), params)

    # -- plant protocol: reads delegate (the carried tree IS the drifted
    # device state); writes land through the inner device, then age once --
    def write_params(self, params, *, step, prev=None):
        return self.drift(
            self.inner.write_params(params, step=step, prev=prev), step)

    def read_cost(self, params, batch, *, step, tag: int = 0):
        return self.inner.read_cost(params, batch, step=step, tag=tag)

    def read_cost_pair(self, params, theta, batch, *, step, tag: int = 0):
        return self.inner.read_cost_pair(params, theta, batch,
                                         step=step, tag=tag)

    def apply_perturbed(self, params, batch, probe, *, step, tags):
        inner = self.inner
        if self.probe_fn is not None and inner.probe_fn is not self.probe_fn:
            # a probe_fn attached to the wrapper (driver resolution) rides
            # down so the inner device's imperfections still apply
            inner = copy.copy(inner)
            inner.probe_fn = self.probe_fn
        return inner.apply_perturbed(params, batch, probe,
                                     step=step, tags=tags)


def plant_from_config(loss_fn, cfg, *, probe_fn=None) -> Plant:
    """The implicit device of an ``MGDConfig``: its historical
    ``cost_noise``/``update_noise`` fields become a ``NoisyPlant`` with
    the exact historical key derivation (σ = 0 → ``IdealPlant``)."""
    if getattr(cfg, "cost_noise", 0.0) or getattr(cfg, "update_noise", 0.0):
        return NoisyPlant(
            loss_fn,
            cost_noise=cfg.cost_noise,
            write_noise=getattr(cfg, "update_noise", 0.0),
            dtheta=cfg.dtheta,
            seed=cfg.seed,
            probe_fn=probe_fn,
        )
    return IdealPlant(loss_fn, probe_fn=probe_fn)
