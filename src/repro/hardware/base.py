"""The hardware plant abstraction — MGD's view of the device it trains.

The paper's central premise is that the optimizer treats the network as an
opaque *plant*: it may (1) write parameters, (2) present an input, and
(3) read back ONE scalar cost.  Everything else — activation defects,
write noise, DAC quantization, readout noise, even whether the "device"
is a JAX function or a physical chip across a process boundary — lives
behind this interface (McCaughan et al. 2023 §4/§6; Oripov et al. 2025
treat the device as a cost oracle throughout).

``Plant`` is the protocol the optimizer drives:

* ``write_params(params, *, step, prev=None)`` — commit a persistent
  parameter write; returns what actually *landed* on the device (ideal
  devices return the input unchanged; noisy/quantized devices do not).
  ``prev`` is the previously landed value, for slow-write modeling.
* ``read_cost(params, batch, *, step, tag)`` — transient probe write +
  cost readout.  ``tag`` disambiguates multiple reads at the same step so
  counter-keyed readout noise stays deterministic across restarts.
* ``read_cost_pair(params, theta, batch, *, step, tag)`` — antithetic
  probe C(θ+θ̃), C(θ−θ̃).  The default does two ``read_cost`` calls;
  devices with a cheaper paired readout (the Pallas pair kernel, a chip
  with differential probe lines) may override.
* ``apply_perturbed(params, batch, probe, *, step, tags)`` — the fused
  probe path: evaluate the model under θ ± θ̃ with the perturbation
  generated *at the parameter* (in-kernel / on-device), never
  materialized host-side.  Optional; ``supports_fused`` reports it.

Pure-JAX plants (Ideal/Noisy/Quantized) are traceable — the whole MGD
step jits/scans/shards exactly as before.  ``ExternalPlant`` lowers each
read to an ordered host callback instead (see ``external.py``).

``PlantMeta`` carries static device metadata (noise figures, DAC bits,
latencies) used by benchmarks to project wall-clock training time the way
the paper's Table 3 does.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax.numpy as jnp

from repro.core.utils import tree_add, tree_axpy

Pytree = Any


@dataclasses.dataclass(frozen=True)
class PlantMeta:
    """Static device metadata (hashable → safe to close over under jit)."""

    name: str = "ideal"
    cost_noise: float = 0.0          # σ_C, std of the cost readout noise
    write_noise: float = 0.0         # σ_θ, persistent-write noise in units of Δθ
    sigma_a: float = 0.0             # σ_a, static activation-defect scale
    weight_bits: Optional[int] = None  # DAC resolution of persistent writes
    adc_bits: Optional[int] = None     # ADC resolution of the cost readout
    write_latency_s: float = 0.0     # τ per persistent parameter write
    read_latency_s: float = 0.0      # τ per cost readout (≈ τ_p floor)
    external: bool = False           # True → host-callback / process boundary
    chips: int = 1                   # devices probed concurrently (chip farm)
    # drift/aging: the stored weights move BETWEEN writes (random walk per
    # step and/or relaxation toward a rest state) — the time-varying device
    # regime Oripov et al. 2025 flag as the open deployment question.
    drift_mode: Optional[str] = None  # walk | decay | None (stable device)
    drift_rate: float = 0.0          # σ_d, per-step random-walk std
    drift_tau: float = 0.0           # relaxation τ toward drift_rest (steps)
    drift_rest: float = 0.0          # rest value the weights decay toward
    # True → the host boundary is armed with a FaultPolicy (timeouts,
    # retries, per-chip masking); see hardware.faults.
    fault_tolerant: bool = False

    def step_latency_s(self, reads_per_step: int = 2,
                       writes_per_step: int = 1, *,
                       differential: bool = False,
                       pipelined: bool = False) -> float:
        """Projected seconds per MGD iteration on this device (Table 3
        style: reads dominate; one amortized persistent write per τ_θ).
        ``reads_per_step``/``writes_per_step`` count PER-CHIP operations:
        a k-chip farm issues its k probe pairs concurrently, so the
        wall-clock per step is one chip's latency while the C̃-estimator
        variance drops ∝ 1/k (benchmarks/farm_scaling.py).

        ``differential=True`` prices a differential probe line
        (``measure_pair``): the antithetic pair C(θ+θ̃), C(θ−θ̃) resolves
        in ONE readout conversion — the ±θ̃ branches settle concurrently
        and the ADC digitizes their difference — so the pair costs one
        ``read_latency_s`` instead of two.

        ``pipelined=True`` prices the double-buffered farm schedule
        (``ChipFarm(pipeline=True)``): step N+1's parameter write
        overlaps step N's readout, so a step pays
        ``max(read_time, write_time)`` instead of their sum — the device
        is never idle waiting on the other phase."""
        reads = reads_per_step * (0.5 if differential else 1.0)
        read_time = reads * self.read_latency_s
        write_time = writes_per_step * self.write_latency_s
        if pipelined:
            return max(read_time, write_time)
        return read_time + write_time


class Plant:
    """Base plant: ideal pass-through semantics; subclasses override the
    pieces their hardware model perturbs.  See the module docstring for
    the contract."""

    meta: PlantMeta = PlantMeta()
    probe_fn: Optional[Callable] = None

    # -- persistent writes --------------------------------------------------
    def write_params(self, params: Pytree, *, step, prev: Optional[Pytree] = None
                     ) -> Pytree:
        """Commit ``params`` to the device; return what actually landed."""
        return params

    # -- transient probe write + scalar readout -----------------------------
    def read_cost(self, params: Pytree, batch, *, step, tag: int = 0):
        raise NotImplementedError

    def read_cost_pair(self, params: Pytree, theta: Pytree, batch, *,
                       step, tag: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Antithetic readout (C(θ+θ̃), C(θ−θ̃)).  The default issues two
        independent reads with consecutive tags — bit-identical to the
        historical inlined central-difference path.  Devices with a
        cheaper paired readout override: the Pallas pair kernel reads
        each W tile once, and external devices with a differential probe
        line (``measure_pair``) write the base θ once per pair instead
        of two full perturbed trees (see ``external.py``)."""
        c_plus = self.read_cost(tree_add(params, theta), batch,
                                step=step, tag=tag)
        c_minus = self.read_cost(tree_axpy(-1.0, theta, params), batch,
                                 step=step, tag=tag + 1)
        return c_plus, c_minus

    # -- fused probe path ---------------------------------------------------
    @property
    def supports_fused(self) -> bool:
        return self.probe_fn is not None

    def apply_perturbed(self, params: Pytree, batch, probe, *, step, tags):
        """Evaluate costs under θ ± θ̃ with θ̃ generated at the parameter
        (Pallas kernels for in-process plants).  Returns a [len(tags)]
        array of costs, one per sign in ``probe.ctx.signs``."""
        if self.probe_fn is None:
            raise NotImplementedError(
                f"{type(self).__name__} has no perturbed-apply interface "
                "(construct it with probe_fn=... for the fused path)")
        return self.probe_fn(params, batch, probe)


class IdealPlant(Plant):
    """Pure-JAX device: bit-identical (f32) to the historical in-process
    path — ``read_cost`` IS the loss function, writes land exactly."""

    def __init__(self, loss_fn: Callable, *, probe_fn: Optional[Callable] = None,
                 meta: Optional[PlantMeta] = None):
        self.loss_fn = loss_fn
        self.probe_fn = probe_fn
        self.meta = meta or PlantMeta(name="ideal")

    def read_cost(self, params, batch, *, step, tag: int = 0):
        return self.loss_fn(params, batch)
