"""repro — multiplexed gradient descent, reproduced and scaled.

The package front door is three verbs:

    import repro
    mgd = repro.driver("discrete", repro.DriverConfig(dtheta=1e-2, eta=1.0),
                       loss_fn)
    state = mgd.init(params)
    params, state, aux = mgd.step(params, state, batch)

    result = repro.train(loss_fn, params, cfg, sample_fn, num_steps,
                         loop=repro.TrainLoopConfig(chunk=100))

    svc = repro.serve(repro.ServiceConfig(slots=8), predict_fn, params,
                      trim=repro.TrimConfig(cfg, loss_fn, plant=farm))

Attributes resolve lazily so ``import repro`` stays free of jax imports
until the API is actually used (subpackages import directly as before).
"""
_API_NAMES = (
    "ALGORITHMS", "DriverConfig", "MGDDriver", "ProbeParallelState",
    "driver", "make_epoch", "register_driver", "replace_step", "state_step",
    # consolidated front door (lazy: train pulls the loop, serve the tier)
    "train", "train_mgd", "TrainLoopConfig", "TrainResult",
    "serve", "OnlineService", "ServiceConfig", "TrimConfig",
)

__all__ = list(_API_NAMES)


def __getattr__(name):
    if name in _API_NAMES:
        from repro import api
        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
