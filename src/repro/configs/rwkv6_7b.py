"""rwkv6-7b — RWKV-6 "Finch" 7B [arXiv:2404.05892; hf].

32L, d_model 4096 (attention-free), d_ff 14336, vocab 65536; head size 64
→ 64 WKV heads.  Runs long_500k (O(1) recurrent state).
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,            # head_size 64
        d_ff=14336,
        vocab=65536,
        la_chunk=32,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=128,
        dtype="float32", la_chunk=8,
    )
