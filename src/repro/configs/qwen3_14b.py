"""qwen3-14b — Qwen3 14B [hf:Qwen/Qwen3-14B family; hf].

40L, d_model 5120, 40H (GQA kv=8, head_dim 128), d_ff 17408, vocab 151936,
qk_norm (per-head RMSNorm on Q and K).
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=17408,
        vocab=151936,
        qk_norm=True,
        rope_theta=1e6,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=128, dtype="float32",
        attn_q_block=16, attn_kv_block=16,
    )
