"""granite-34b — IBM Granite 34B Code [arXiv:2405.04324; hf].

88L, d_model 6144, 48H (MQA kv=1, head_dim 128), d_ff 24576, vocab 49152.
Llama-style architecture; deep-narrow, so FSDP weight sharding is on.
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_head=128,
        d_ff=24576,
        vocab=49152,
        rope_theta=1e4,
        fsdp=True,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
        d_ff=128, vocab=128, dtype="float32", fsdp=False,
        attn_q_block=16, attn_kv_block=16,
    )
