"""llama4-scout-17b-a16e — Llama-4 Scout 17B-active/16-expert
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L, d_model 5120, 40H (GQA kv=8, head_dim 128), expert d_ff 8192, vocab
202048; MoE 16 experts top-1 + 1 shared expert.  Treated as full attention
(iRoPE global layers) → long_500k skipped.
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab=202048,
        n_experts=16,
        n_experts_active=1,
        n_shared_experts=1,
        moe_group_size=512,
        rope_theta=5e5,
        fsdp=True,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=128, n_experts=4, n_experts_active=1,
        n_shared_experts=1, moe_group_size=32, dtype="float32", fsdp=False,
        attn_q_block=16, attn_kv_block=16,
    )
