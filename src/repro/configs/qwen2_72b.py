"""qwen2-72b — Qwen2 72B [arXiv:2407.10671; hf].

80L, d_model 8192, 64H (GQA kv=8, head_dim 128), d_ff 29568, vocab 152064,
QKV bias.  FSDP weight sharding on.
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-72b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=29568,
        vocab=152064,
        qkv_bias=True,
        rope_theta=1e6,
        fsdp=True,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=128, dtype="float32", fsdp=False,
        attn_q_block=16, attn_kv_block=16,
    )
