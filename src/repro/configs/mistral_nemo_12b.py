"""mistral-nemo-12b — Mistral-Nemo-Base-2407 [hf:mistralai; hf].

40L, d_model 5120, 32H (GQA kv=8, head_dim 128), d_ff 14336, vocab 131072,
128k context (rope_theta 1e6).  long_500k skipped: full attention.
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mistral-nemo-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab=131072,
        rope_theta=1e6,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=128, dtype="float32",
        attn_q_block=16, attn_kv_block=16,
    )
