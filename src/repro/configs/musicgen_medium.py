"""musicgen-medium — MusicGen medium decoder [arXiv:2306.05284; hf].

48L, d_model 1536, 24H (MHA kv=24, head_dim 64), d_ff 6144, vocab 2048 per
EnCodec codebook (4 codebooks, delay pattern handled by the frontend stub).
The EnCodec frontend is a stub per the assignment: input_specs feeds
precomputed frame embeddings; the tokens path (sum of 4 codebook
embeddings, 4×2048 head) is exercised by the smoke test.
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_head=64,
        d_ff=6144,
        vocab=2048,
        n_codebooks=4,
        rope_theta=1e4,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=64, n_codebooks=4, dtype="float32",
        attn_q_block=16, attn_kv_block=16,
    )
