"""qwen2-vl-2b — Qwen2-VL 2B backbone [arXiv:2409.12191; hf].

28L, d_model 1536, 12H (GQA kv=2, head_dim 128), d_ff 8960, vocab 151936.
M-RoPE sections (16, 24, 24) over the 64-dim rotary half.  The vision
frontend is a stub per the assignment: input_specs feeds patch embeddings
plus 3-D position ids.
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_head=128,
        d_ff=8960,
        vocab=151936,
        qkv_bias=True,
        mrope_sections=(16, 24, 24),
        rope_theta=1e6,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=128, mrope_sections=(2, 3, 3), dtype="float32",
        attn_q_block=16, attn_kv_block=16,
    )
