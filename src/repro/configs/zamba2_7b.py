"""zamba2-7b — Zamba2 7B hybrid [arXiv:2411.15242; unverified].

81 "layers" = 54 Mamba-2 blocks + 27 invocations of a single SHARED
attention+MLP block (applied after every 2 mamba blocks; weights reused).
d_model 3584, attn 32H (kv=32, head_dim 112), d_ff 14336, vocab 32000,
ssm_state 64, ssm head_dim 64 (→ 112 SSD heads at expand 2).
Simplification noted in DESIGN.md: the per-invocation LoRA adapters on the
shared block are omitted.  Runs long_500k (SSM state is O(1); the shared
blocks' KV caches are sequence-sharded).
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,               # 54 mamba + 27 shared-attn invocations
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_head=112,
        d_ff=14336,
        vocab=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        attn_every=2,
        rope_theta=1e4,
        la_chunk=64,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=6,                # 4 mamba + 2 shared-attn invocations
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
        vocab=128, ssm_state=16, ssm_head_dim=16, attn_every=2,
        dtype="float32", la_chunk=8,
        attn_q_block=16, attn_kv_block=16,
    )
