"""Architecture registry: the 10 assigned configs + input shapes.

``get_config(name)`` / ``get_smoke_config(name)`` resolve an --arch id;
``SHAPES`` carries the assigned input-shape set; ``runnable_cells()``
enumerates the 40 assigned (arch × shape) cells, marking the long_500k
skips for pure full-attention architectures (documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

ARCH_IDS = [
    "rwkv6-7b",
    "qwen2-vl-2b",
    "mistral-nemo-12b",
    "qwen3-14b",
    "granite-34b",
    "qwen2-72b",
    "deepseek-v3-671b",
    "llama4-scout-17b-a16e",
    "musicgen-medium",
    "zamba2-7b",
]


def _module(name: str):
    return importlib.import_module(
        f"repro.configs.{name.replace('-', '_')}")


def get_config(name: str):
    return _module(name).config()


def get_smoke_config(name: str):
    return _module(name).smoke_config()


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# architectures with sub-quadratic sequence handling run long_500k
LONG_CONTEXT_OK = {"rwkv6-7b", "zamba2-7b"}


def runnable_cells() -> List[Tuple[str, str, bool]]:
    """All 40 assigned cells as (arch, shape, runnable)."""
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            runnable = shape != "long_500k" or arch in LONG_CONTEXT_OK
            cells.append((arch, shape, runnable))
    return cells
