"""deepseek-v3-671b — DeepSeek-V3 [arXiv:2412.19437; hf].

61L, d_model 7168, 128H MLA (q_lora 1536, kv_lora 512, nope 128, rope 64,
v 128), vocab 129280; MoE: 1 shared + 256 routed experts, top-8, expert
d_ff 2048.  Simplifications recorded in DESIGN.md: softmax top-k routing
(no aux-loss-free bias term) and no MTP head; MGD trains the router with
the same scalar feedback as every other parameter.
"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        d_ff=2048,                 # routed-expert inner dim
        vocab=129280,
        n_experts=256,
        n_experts_active=8,
        n_shared_experts=1,
        moe_group_size=128,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        rope_theta=1e4,
        fsdp=True,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, d_ff=64, vocab=128,
        n_experts=8, n_experts_active=2, n_shared_experts=1,
        moe_group_size=32,
        q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16,
        qk_rope_head_dim=8, v_head_dim=16, dtype="float32", fsdp=False,
        attn_q_block=16, attn_kv_block=16,
    )
