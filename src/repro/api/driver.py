"""One driver API: ``repro.driver()`` builds every MGD algorithm.

The paper's central claim is that MGD is *one* framework whose time
constants (τ_p, τ_θ, τ_x) interpolate between the discrete Algorithm 1,
the continuous Algorithm 2, and multi-probe variants.  This module makes
the code say the same thing: every algorithm is constructed through one
registry call and driven through one optax-style ``(init, step)`` pair —

    mgd = repro.driver("discrete", DriverConfig(dtheta=1e-2, eta=1.0),
                       loss_fn, plant=my_plant)
    state = mgd.init(params)
    params, state, aux = mgd.step(params, state, batch)

``MGDDriver`` is a NamedTuple (jit/closure friendly) with

* ``init(params) -> state``      — fresh algorithm state for ``params``
* ``step(params, state, batch) -> (params, state, aux)``

and a standardized ``aux`` dict that every algorithm emits:

* ``cost``            — the device's cost readout this step (telemetry)
* ``c_tilde``         — the scalar error signal C̃ (the ONLY feedback)
* ``grad_norm_proxy`` — |C̃|/Δθ, the per-element magnitude of the
  homodyne error signal e = C̃·θ̃/Δθ² (each |θ̃ᵢ| = Δθ); a cheap online
  stand-in for |∇C| that needs no extra cost reads.

Algorithm-specific keys (``updated`` for the discrete driver,
``c_tilde_mean`` for probe-parallel) ride along unchanged.

The state stays algorithm-specific (``MGDState`` / ``AnalogMGDState`` /
``ProbeParallelState``) — a pytree of arrays, so generic code
checkpoints it whole (``training.train_loop`` does) and reads the global
step through ``state_step(state)``.

Constructing through the registry is trajectory-preserving: the builders
delegate to the exact step factories the legacy ``make_*_step`` entry
points used, so f32 trajectories are bit-identical to pre-registry code
(tests/test_driver_api.py pins this).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

ALGORITHMS = ("discrete", "analog", "probe_parallel",
              "probe_parallel_external")


# ---------------------------------------------------------------------------
# The superset config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DriverConfig:
    """Algorithm-agnostic MGD configuration (frozen → hashable/jit-static).

    Shared fields default to ``None`` and resolve to the algorithm's
    historical default at ``driver()`` time (Δθ = 1e-3/1e-2, η = 1e-2/1e-3
    and rademacher/sinusoidal for discrete/analog respectively — exactly
    the legacy ``MGDConfig`` / ``AnalogMGDConfig`` defaults, so converted
    configs replay old trajectories bit-for-bit).

    The discrete and analog sections are plain fields; ``driver()``
    rejects a config whose *other*-section knobs were touched (e.g.
    ``probes=4`` handed to the analog driver) — silent ignoring is how
    mixed-up experiments happen.
    """

    # -- shared (None → per-algorithm default) ------------------------------
    ptype: Optional[str] = None       # rademacher | walsh | sequential | sinusoidal
    dtheta: Optional[float] = None    # Δθ, perturbation amplitude
    eta: Optional[float] = None       # η, learning rate
    tau_theta: Optional[float] = None  # integration time (int steps / float τ)
    tau_p: int = 1                    # perturbation time constant
    seed: int = 0
    cost_noise: float = 0.0           # σ_C of the implicit device

    # -- discrete section (Algorithm 1 / probe-parallel) --------------------
    mode: str = "forward"             # forward (paper) | central
    tau_x: int = 1                    # input-sample change time
    replay: bool = False              # scalar-replay O(1)-memory updates
    probes: int = 1                   # probe-averaging count
    probe_impl: str = "map"           # map | vmap
    momentum: float = 0.0             # heavy-ball coefficient on G
    staleness: int = 0                # bounded-staleness feedback
    fused: bool = False               # Pallas fused probe/update path
    kernel_impl: Optional[str] = None  # pallas | interpret | ref | None=auto
    update_noise: float = 0.0         # σ_θ of the implicit device

    # -- analog section (Algorithm 2) ---------------------------------------
    tau_hp: float = 100.0             # highpass (baseline-removal) τ
    dt: float = 1.0                   # integration timestep

    def replace(self, **kw) -> "DriverConfig":
        return dataclasses.replace(self, **kw)


# Fields owned by one section, with their defaults: setting any of them
# away from the default while asking for the *other* algorithm is an
# ambiguous mix and is rejected with an actionable message.
_DISCRETE_ONLY = {
    "mode": "forward", "tau_x": 1, "replay": False, "probes": 1,
    "probe_impl": "map", "momentum": 0.0, "staleness": 0, "fused": False,
    "kernel_impl": None, "update_noise": 0.0,
}
_ANALOG_ONLY = {"tau_hp": 100.0, "dt": 1.0}


def _reject_foreign(cfg: DriverConfig, algorithm: str) -> None:
    foreign = _DISCRETE_ONLY if algorithm == "analog" else _ANALOG_ONLY
    section = "analog" if foreign is _ANALOG_ONLY else "discrete"
    for field, default in foreign.items():
        if getattr(cfg, field) != default:
            raise ValueError(
                f"DriverConfig.{field}={getattr(cfg, field)!r} is a "
                f"{section}-section knob the {algorithm!r} driver cannot "
                f"honor — did you mean repro.driver({section!r}, ...)? "
                f"(leave {field} at its default {default!r} otherwise)")


def as_mgd_config(cfg):
    """Resolve ``cfg`` to the discrete driver's ``MGDConfig``."""
    from repro.core.analog import AnalogMGDConfig
    from repro.core.mgd import MGDConfig

    if isinstance(cfg, MGDConfig):
        return cfg
    if isinstance(cfg, AnalogMGDConfig):
        raise TypeError("AnalogMGDConfig describes Algorithm 2 — use "
                        "repro.driver('analog', cfg, ...) or a DriverConfig")
    if not isinstance(cfg, DriverConfig):
        raise TypeError(f"expected DriverConfig or MGDConfig, got "
                        f"{type(cfg).__name__}")
    tau_theta = 1 if cfg.tau_theta is None else cfg.tau_theta
    if int(tau_theta) != tau_theta:
        raise ValueError(
            f"the discrete driver integrates over an integer number of "
            f"steps; tau_theta={tau_theta} is fractional — fractional "
            f"time constants belong to repro.driver('analog', ...)")
    return MGDConfig(
        ptype="rademacher" if cfg.ptype is None else cfg.ptype,
        dtheta=1e-3 if cfg.dtheta is None else cfg.dtheta,
        eta=1e-2 if cfg.eta is None else cfg.eta,
        tau_p=cfg.tau_p, tau_theta=int(tau_theta), tau_x=cfg.tau_x,
        mode=cfg.mode, replay=cfg.replay, probes=cfg.probes,
        probe_impl=cfg.probe_impl, momentum=cfg.momentum, seed=cfg.seed,
        cost_noise=cfg.cost_noise, update_noise=cfg.update_noise,
        staleness=cfg.staleness, fused=cfg.fused,
        kernel_impl=cfg.kernel_impl)


def as_analog_config(cfg):
    """Resolve ``cfg`` to the continuous driver's ``AnalogMGDConfig``."""
    from repro.core.analog import AnalogMGDConfig
    from repro.core.mgd import MGDConfig

    if isinstance(cfg, AnalogMGDConfig):
        return cfg
    if isinstance(cfg, MGDConfig):
        raise TypeError("MGDConfig describes the discrete Algorithm 1 — "
                        "use repro.driver('discrete', cfg, ...) or a "
                        "DriverConfig")
    if not isinstance(cfg, DriverConfig):
        raise TypeError(f"expected DriverConfig or AnalogMGDConfig, got "
                        f"{type(cfg).__name__}")
    return AnalogMGDConfig(
        ptype="sinusoidal" if cfg.ptype is None else cfg.ptype,
        dtheta=1e-2 if cfg.dtheta is None else cfg.dtheta,
        eta=1e-3 if cfg.eta is None else cfg.eta,
        tau_theta=10.0 if cfg.tau_theta is None else float(cfg.tau_theta),
        tau_hp=cfg.tau_hp, tau_p=cfg.tau_p, dt=cfg.dt, seed=cfg.seed,
        cost_noise=cfg.cost_noise)


# ---------------------------------------------------------------------------
# The uniform driver contract
# ---------------------------------------------------------------------------


class MGDDriver(NamedTuple):
    """The optax-style ``(init, step)`` pair every algorithm exposes.

    ``step(params, state, batch) -> (params, state, aux)`` with the
    standardized ``aux`` keys (``cost``, ``c_tilde``, ``grad_norm_proxy``).
    The trailing fields are construction metadata generic drivers use:
    ``tau_x`` for sampler pacing, ``config`` the resolved algorithm
    config, ``plant`` the device handed in (None for the implicit one).
    """

    init: Callable[[Pytree], Any]
    step: Callable[[Pytree, Any, Any], Tuple[Pytree, Any, Dict]]
    algorithm: str = "discrete"
    config: Any = None
    tau_x: int = 1
    plant: Any = None


class ProbeParallelState(NamedTuple):
    """Probe-parallel carries no optimizer buffers — parameters update
    every step from the all-gathered scalars; only the counter remains."""

    step: jnp.ndarray


def state_step(state) -> jnp.ndarray:
    """The global iteration counter of any driver state (works traced)."""
    if hasattr(state, "step"):
        return state.step
    if hasattr(state, "t"):
        return state.t
    raise TypeError(f"{type(state).__name__} has no step/t counter")


def replace_step(state, step):
    """``state`` with its iteration counter set to ``step``."""
    step = jnp.asarray(step, jnp.int32)
    if hasattr(state, "step"):
        return state._replace(step=step)
    if hasattr(state, "t"):
        return state._replace(t=step)
    raise TypeError(f"{type(state).__name__} has no step/t counter")


# ---------------------------------------------------------------------------
# Deprecation hygiene for the legacy shims
# ---------------------------------------------------------------------------

_WARNED: set = set()


def warn_deprecated(name: str, replacement: str, *,
                    category=DeprecationWarning) -> None:
    """Single-fire deprecation warning per legacy entry point."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use the consolidated surface instead: "
        f"{replacement}", category, stacklevel=3)


# ---------------------------------------------------------------------------
# The registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., MGDDriver]] = {}


def register_driver(name: str):
    """Register a builder under ``name`` (decorator).  Builders receive
    ``(cfg, loss_fn, **kwargs)`` and return an ``MGDDriver``."""
    def deco(builder):
        _REGISTRY[name] = builder
        return builder
    return deco


def driver(algorithm: str, cfg=None, loss_fn: Optional[Callable] = None, *,
           plant=None, probe_fn: Optional[Callable] = None, mesh=None,
           total_params: Optional[int] = None, **kwargs) -> MGDDriver:
    """Construct any MGD algorithm behind the uniform driver contract.

    ``algorithm`` is one of ``"discrete"`` (paper Algorithm 1, incl. the
    fused Pallas path), ``"analog"`` (Algorithm 2), ``"probe_parallel"``
    (pod-level probe averaging; needs ``mesh``), or
    ``"probe_parallel_external"`` (the same averaged update over k
    external chips; needs ``plant=ChipFarm(...)``).
    ``cfg`` is a ``DriverConfig`` (or the algorithm's legacy config —
    accepted for migration) and ``loss_fn(params, batch) -> cost`` is the
    model interface; with an explicit ``plant`` it may be None (the plant
    is the cost oracle).
    """
    if algorithm not in _REGISTRY:
        raise ValueError(f"unknown algorithm {algorithm!r}; registered: "
                         f"{sorted(_REGISTRY)}")
    if cfg is None:
        cfg = DriverConfig()
    if isinstance(cfg, DriverConfig):
        _reject_foreign(cfg, algorithm)
    return _REGISTRY[algorithm](
        cfg, loss_fn, plant=plant, probe_fn=probe_fn, mesh=mesh,
        total_params=total_params, **kwargs)


def _standard_aux(metrics: Dict, c_tilde, dtheta: float) -> Dict:
    aux = dict(metrics)
    aux["grad_norm_proxy"] = jnp.abs(
        jnp.asarray(c_tilde, jnp.float32)) / jnp.float32(dtheta)
    return aux


@register_driver("discrete")
def _build_discrete(cfg, loss_fn, *, plant=None, probe_fn=None, mesh=None,
                    total_params=None) -> MGDDriver:
    from repro.core.mgd import build_mgd_step, mgd_init

    if mesh is not None:
        raise ValueError("the discrete driver is single-program — a mesh "
                         "only parameterizes repro.driver('probe_parallel', "
                         "...); under pjit the discrete step shards through "
                         "the params/batch shardings instead")
    mcfg = as_mgd_config(cfg)
    raw = build_mgd_step(loss_fn, mcfg, total_params, probe_fn=probe_fn,
                         plant=plant)

    def step(params, state, batch):
        params, state, m = raw(params, state, batch)
        return params, state, _standard_aux(m, m["c_tilde"], mcfg.dtheta)

    return MGDDriver(
        init=lambda params: mgd_init(params, mcfg), step=step,
        algorithm="discrete", config=mcfg, tau_x=mcfg.tau_x, plant=plant)


@register_driver("analog")
def _build_analog(cfg, loss_fn, *, plant=None, probe_fn=None, mesh=None,
                  total_params=None) -> MGDDriver:
    from repro.core.analog import analog_init, build_analog_step

    if mesh is not None:
        raise ValueError("the analog driver is single-program; mesh only "
                         "parameterizes repro.driver('probe_parallel', ...)")
    if probe_fn is not None:
        raise ValueError("the analog driver has no fused probe path — "
                         "probe_fn belongs to repro.driver('discrete', "
                         "DriverConfig(fused=True), ...)")
    if isinstance(cfg, DriverConfig) and cfg.probes != 1:
        raise ValueError(f"probes={cfg.probes} is a discrete-section knob; "
                         "Algorithm 2 multiplexes probes in frequency, not "
                         "by count — use repro.driver('discrete', ...) for "
                         "probe averaging")
    acfg = as_analog_config(cfg)
    raw = build_analog_step(loss_fn, acfg, total_params, plant=plant)

    def step(params, state, batch):
        params, state, m = raw(params, state, batch)
        return params, state, _standard_aux(m, m["c_tilde"], acfg.dtheta)

    return MGDDriver(
        init=lambda params: analog_init(params, acfg), step=step,
        algorithm="analog", config=acfg, tau_x=1, plant=plant)


@register_driver("probe_parallel")
def _build_probe_parallel(cfg, loss_fn, *, plant=None, probe_fn=None,
                          mesh=None, total_params=None, probe_axis="pod",
                          data_axis=None, param_specs=None,
                          batch_specs=None) -> MGDDriver:
    from repro.core.probe_parallel import build_probe_parallel_step

    if mesh is None:
        raise ValueError("repro.driver('probe_parallel', ...) needs a mesh= "
                         "with the probe axis (default name 'pod') — each "
                         "mesh slice along it evaluates one probe")
    fused = getattr(cfg, "fused", False)
    if probe_fn is not None and not fused:
        raise ValueError("probe_parallel only takes a probe_fn on its fused "
                         "path — set DriverConfig(fused=True) so every pod "
                         "probes through the Pallas kernels")
    if isinstance(cfg, DriverConfig) and cfg.probes != 1:
        raise ValueError(f"probes={cfg.probes} conflicts with "
                         "probe_parallel: the probe count IS the mesh's "
                         f"{probe_axis!r} axis size — leave probes=1")
    mcfg = as_mgd_config(cfg)
    if mcfg.tau_theta != 1 or mcfg.replay or mcfg.staleness:
        raise ValueError("probe_parallel updates every step (tau_theta=1, "
                         "no replay/staleness) — temporal integration "
                         "composes at the driver level, not inside the "
                         "shard_map step")
    raw = build_probe_parallel_step(
        loss_fn, mcfg, mesh, probe_axis=probe_axis, data_axis=data_axis,
        param_specs=param_specs, batch_specs=batch_specs, plant=plant,
        probe_fn=probe_fn)

    def init(params):
        return ProbeParallelState(step=jnp.zeros((), jnp.int32))

    def step(params, state, batch):
        params, m = raw(params, state.step, batch)
        aux = _standard_aux(m, m["c_tilde_mean"], mcfg.dtheta)
        aux["c_tilde"] = m["c_tilde_mean"]
        return params, ProbeParallelState(step=state.step + 1), aux

    return MGDDriver(init=init, step=step, algorithm="probe_parallel",
                     config=mcfg, tau_x=mcfg.tau_x, plant=plant)


@register_driver("probe_parallel_external")
def _build_probe_parallel_external(cfg, loss_fn, *, plant=None, probe_fn=None,
                                   mesh=None, total_params=None) -> MGDDriver:
    """Probe-parallel MGD over k EXTERNAL chips (the §6 chip farm): the
    same averaged update as ``probe_parallel``, fanned out host-side to a
    ``hardware.farm.ChipFarm`` instead of a mesh axis.

    A farm armed with a ``hardware.FaultPolicy`` gains the fault-tolerant
    step: failed/quarantined/outlier chips are masked out of the average
    (η effectively rescaled by the live chip count — see
    ``core.probe_parallel``) and the aux metrics gain ``n_valid`` /
    ``n_used`` live-chip counts."""
    from repro.core.probe_parallel import build_probe_parallel_external_step
    from repro.hardware.farm import ChipFarm

    if mesh is not None:
        raise ValueError("probe_parallel_external fans probes out host-side "
                         "— a mesh only parameterizes "
                         "repro.driver('probe_parallel', ...)")
    if probe_fn is not None:
        raise ValueError("probe_parallel_external has no fused probe path — "
                         "the chips evaluate their own probes behind the "
                         "host boundary")
    if not isinstance(plant, ChipFarm):
        raise ValueError("repro.driver('probe_parallel_external', ...) needs "
                         "plant=ChipFarm(...) — k external chips behind one "
                         "host boundary (repro.hardware.simulated_chip_farm "
                         "builds a reference farm)")
    if loss_fn is not None:
        raise ValueError("probe_parallel_external has no in-process loss — "
                         "the chips ARE the cost oracle; pass loss_fn=None")
    if isinstance(cfg, DriverConfig) and cfg.probes != 1:
        raise ValueError(f"probes={cfg.probes} conflicts with "
                         "probe_parallel_external: the probe count IS the "
                         "farm size — leave probes=1")
    mcfg = as_mgd_config(cfg)
    if mcfg.tau_theta != 1 or mcfg.replay or mcfg.staleness:
        raise ValueError("probe_parallel_external updates every step "
                         "(tau_theta=1, no replay/staleness) — temporal "
                         "integration composes at the driver level, not "
                         "across the host boundary")
    raw = build_probe_parallel_external_step(mcfg, plant)

    def init(params):
        return ProbeParallelState(step=jnp.zeros((), jnp.int32))

    def step(params, state, batch):
        params, m = raw(params, state.step, batch)
        aux = _standard_aux(m, m["c_tilde_mean"], mcfg.dtheta)
        aux["c_tilde"] = m["c_tilde_mean"]
        return params, ProbeParallelState(step=state.step + 1), aux

    return MGDDriver(init=init, step=step,
                     algorithm="probe_parallel_external", config=mcfg,
                     tau_x=mcfg.tau_x, plant=plant)


# ---------------------------------------------------------------------------
# Generic multi-step runner (τ_x semantics + lax.scan), driver-agnostic
# ---------------------------------------------------------------------------


def make_epoch(drv: MGDDriver, steps_per_call: int,
               sample_fn: Callable[[jnp.ndarray], Any]):
    """Scan ``steps_per_call`` driver iterations inside one jitted call.

    ``sample_fn(sample_index) -> batch`` implements τ_x: iteration n uses
    sample index n // τ_x.  Works for any pure-JAX driver; external
    plants (ordered host callbacks) must be driven step-by-step instead.
    Returns ``run(params, state) -> (params, state, stacked_aux)``.
    """
    def body(carry, _):
        params, state = carry
        batch = sample_fn(state_step(state) // drv.tau_x)
        params, state, aux = drv.step(params, state, batch)
        return (params, state), aux

    @jax.jit
    def run(params, state):
        (params, state), aux = jax.lax.scan(
            body, (params, state), None, length=steps_per_call)
        return params, state, aux

    return run
