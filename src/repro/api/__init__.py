"""Public API — the consolidated front door.

Three verbs cover the repo's workloads:

* ``repro.driver(algorithm, cfg, loss_fn, ...)`` — build an
  ``(init, step)`` MGD driver from the registry.
* ``repro.train(loss_fn, params, cfg, sample_fn, num_steps,
  loop=TrainLoopConfig(...))`` — run the offline training loop.
* ``repro.serve(cfg, predict_fn, params, trim=TrimConfig(...))`` — run
  the online serving tier with background MGD re-trim.

``train``/``serve`` (and their config dataclasses) resolve lazily so
that importing the driver surface alone does not pull in the training
loop or the serving stack.
"""
from .driver import (ALGORITHMS, DriverConfig, MGDDriver, ProbeParallelState,
                     as_analog_config, as_mgd_config, driver, make_epoch,
                     register_driver, replace_step, state_step)

_LAZY = {
    # offline loop
    "train": ("repro.training.train_loop", "train_mgd"),
    "train_mgd": ("repro.training.train_loop", "train_mgd"),
    "TrainLoopConfig": ("repro.training.train_loop", "TrainLoopConfig"),
    "TrainResult": ("repro.training.train_loop", "TrainResult"),
    # online serving tier
    "serve": ("repro.serving.online", "serve"),
    "OnlineService": ("repro.serving.online", "OnlineService"),
    "ServiceConfig": ("repro.serving.online", "ServiceConfig"),
    "TrimConfig": ("repro.serving.online", "TrimConfig"),
}

__all__ = [
    "ALGORITHMS", "DriverConfig", "MGDDriver", "ProbeParallelState",
    "as_analog_config", "as_mgd_config", "driver", "make_epoch",
    "register_driver", "replace_step", "state_step",
] + sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib

        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
