"""Public driver API — ``repro.driver()`` and the uniform MGD contract."""
from .driver import (ALGORITHMS, DriverConfig, MGDDriver, ProbeParallelState,
                     as_analog_config, as_mgd_config, driver, make_epoch,
                     register_driver, replace_step, state_step)

__all__ = [
    "ALGORITHMS", "DriverConfig", "MGDDriver", "ProbeParallelState",
    "as_analog_config", "as_mgd_config", "driver", "make_epoch",
    "register_driver", "replace_step", "state_step",
]
