"""First-order baseline optimizers (the paper's comparison axis)."""
from .sgd import sgd_init, sgd_step
