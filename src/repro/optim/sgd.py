"""Plain SGD (+ optional momentum) — the backprop baseline optimizer.

The paper compares MGD against backprop + SGD without momentum (§3.6); we
keep the baseline exactly that simple, with momentum available for the
beyond-paper comparisons.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0):
    if momentum:
        return {"m": jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)}
    return {}


def sgd_step(params, grads, state, *, eta: float, momentum: float = 0.0):
    if momentum:
        m = jax.tree_util.tree_map(
            lambda mi, gi: momentum * mi + gi.astype(jnp.float32),
            state["m"], grads)
        new_params = jax.tree_util.tree_map(
            lambda p, mi: (p.astype(jnp.float32) - eta * mi).astype(p.dtype),
            params, m)
        return new_params, {"m": m}
    new_params = jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32)
                      - eta * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return new_params, state
