"""Shared neural-net building blocks (pure functional JAX, dict pytrees).

Conventions:
* params are nested dicts of jnp arrays; every function takes (params, x).
* compute dtype follows the input; normalization statistics in f32.
* init functions take an explicit PRNG key and an ArchConfig-ish scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perturbations as pert
from repro.kernels import ops as kops


def dense_init(key, d_in, d_out, *, bias=False, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def groupnorm_heads(p, x, n_heads, eps=1e-5):
    """GroupNorm with one group per head over the flattened head dim
    (RWKV6's ln_x).  x: [..., H*D]."""
    *lead, hd = x.shape
    d = hd // n_heads
    xf = x.astype(jnp.float32).reshape(*lead, n_heads, d)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, hd)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def embedding_init(key, vocab, d, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
                      ).astype(dtype)}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def glu_mlp_init(key, d, d_ff, dtype=jnp.float32):
    """Gated (SwiGLU) MLP — the LM-family feedforward."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, d_ff, dtype=dtype),
        "up": dense_init(k2, d, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d, dtype=dtype),
    }


def glu_mlp(p, x):
    h = jax.nn.silu(dense(p["gate"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], h * dense(p["up"], x))


# --- perturbable primitives (MGD fused probe path) --------------------------
#
# ``pdense`` is the perturbable counterpart of ``dense``: instead of adding a
# materialized θ̃ to W in HBM, the weight matmul is routed through the Pallas
# perturbed-matmul kernels, which regenerate the Rademacher signs in VMEM
# next to the MXU — a probe forward reads W once, the same bytes as
# inference.  An antithetic central pair (signs == (+1, −1)) uses the
# single-pass pair kernel, reading W once per *pair*.  Non-matrix leaves
# (biases, norm scales) fall back to a materialized θ̃ — they are O(d), not
# O(d²), so the HBM cost is negligible.
#
# All perturbable ops take/return a TUPLE of activation streams, one per
# probe sign (1 for a forward probe, 2 for a central pair), plus the leaf-id
# subtree (``repro.core.utils.leaf_id_tree``) that anchors every leaf to the
# global hash the host generator uses.  ``layer`` (traced, from a
# stacked-layer scan) selects the row-major slice of stacked leaves via a
# seed shift — see perturbations.shifted_leaf_seed.


def _stream_offset(layer, nelem):
    """Element offset of layer ``layer``'s slice in a stacked leaf (traced
    uint32; wraparound matches the generator's uint32 iota)."""
    return (jnp.asarray(layer, jnp.uint32)
            * jnp.uint32(int(nelem) & 0xFFFFFFFF))


def pleaf(leaf, leaf_id, probe, *, layer=None):
    """Per-stream perturbed values of a non-matmul leaf (or its layer
    slice): tuple of leaf + sign_i·θ̃, float order identical to the
    materializing optimizer path."""
    offset = 0 if layer is None else _stream_offset(layer, leaf.size)
    theta = probe.leaf_theta(leaf.shape, leaf.dtype, leaf_id, offset=offset)
    return tuple(pert.apply_signed(leaf, theta, s) for s in probe.ctx.signs)


def pdense(p, xs, ids, probe, *, layer=None):
    """Perturbable dense: xs (tuple of per-sign streams) @ (W ± θ̃) + (b ± θ̃_b).

    W's perturbation is generated in-kernel (never materialized); the bias
    falls back to a materialized θ̃.  ``ids`` is the leaf-id subtree aligned
    with ``p``; ``layer`` the stacked-bank slice index (or None).
    """
    ctx = probe.ctx
    w = p["w"]
    lseed = probe.lseed(ids["w"])
    if layer is not None:
        lseed = pert.shifted_leaf_seed(
            lseed, _stream_offset(layer, w.shape[-2] * w.shape[-1]))
    if ctx.is_pair:
        ys = kops.perturbed_matmul_pair(
            xs[0], xs[1], w, lseed, dtheta=ctx.dtheta, impl=ctx.impl)
    else:
        ys = tuple(
            kops.perturbed_matmul(
                x, w, lseed, dtheta=ctx.dtheta, sign=s, impl=ctx.impl)
            for x, s in zip(xs, ctx.signs))
    if "b" in p:
        bs = pleaf(p["b"], ids["b"], probe, layer=layer)
        ys = tuple(y + b for y, b in zip(ys, bs))
    return tuple(ys)


def prmsnorm(p, xs, ids, probe, *, layer=None, eps=1e-5):
    """Per-stream rmsnorm with the scale leaf perturbed (materialized)."""
    scales = pleaf(p["scale"], ids["scale"], probe, layer=layer)
    return tuple(rmsnorm({"scale": sc}, x, eps)
                 for sc, x in zip(scales, xs))


# --- convolutions for the paper-scale CNNs ---------------------------------


def conv2d_init(key, kh, kw, c_in, c_out, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(kh * kw * c_in)
    return {
        "w": (jax.random.normal(key, (kh, kw, c_in, c_out), jnp.float32)
              * scale).astype(dtype),
        "b": jnp.zeros((c_out,), dtype),
    }


def conv2d(p, x, *, stride=1, padding="SAME"):
    """x: [B, H, W, C] NHWC."""
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def maxpool2(x):
    """2×2 max-pool, stride 2. x: [B, H, W, C]."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
