"""Shared neural-net building blocks (pure functional JAX, dict pytrees).

Conventions:
* params are nested dicts of jnp arrays; every function takes (params, x).
* compute dtype follows the input; normalization statistics in f32.
* init functions take an explicit PRNG key and an ArchConfig-ish scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in, d_out, *, bias=False, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def groupnorm_heads(p, x, n_heads, eps=1e-5):
    """GroupNorm with one group per head over the flattened head dim
    (RWKV6's ln_x).  x: [..., H*D]."""
    *lead, hd = x.shape
    d = hd // n_heads
    xf = x.astype(jnp.float32).reshape(*lead, n_heads, d)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, hd)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def embedding_init(key, vocab, d, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
                      ).astype(dtype)}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def glu_mlp_init(key, d, d_ff, dtype=jnp.float32):
    """Gated (SwiGLU) MLP — the LM-family feedforward."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, d_ff, dtype=dtype),
        "up": dense_init(k2, d, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d, dtype=dtype),
    }


def glu_mlp(p, x):
    h = jax.nn.silu(dense(p["gate"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], h * dense(p["up"], x))


# --- convolutions for the paper-scale CNNs ---------------------------------


def conv2d_init(key, kh, kw, c_in, c_out, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(kh * kw * c_in)
    return {
        "w": (jax.random.normal(key, (kh, kw, c_in, c_out), jnp.float32)
              * scale).astype(dtype),
        "b": jnp.zeros((c_out,), dtype),
    }


def conv2d(p, x, *, stride=1, padding="SAME"):
    """x: [B, H, W, C] NHWC."""
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def maxpool2(x):
    """2×2 max-pool, stride 2. x: [B, H, W, C]."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
