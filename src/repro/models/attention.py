"""Blockwise (flash-style) causal attention in pure JAX.

Grouped-query attention is computed natively in grouped layout — KV heads
are never materialized at Q-head multiplicity, so GQA's KV memory saving is
real, not cosmetic.

Two exact implementations:

* ``masked``   — scan over Q blocks × all KV blocks with causal masking.
  Simple; wastes ~2× FLOPs on fully-masked upper-triangle blocks.
* ``balanced`` — pairs Q block i with Q block n−1−i so every scan step does
  a constant (n+1) KV-block visits with no masked-block waste.  ~2× fewer
  attention FLOPs at long sequence; bit-compatible with ``masked`` (tested).

Both use online-softmax accumulation in f32, O(S·block) memory.
``decode_attention`` handles the single-token KV-cache path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30  # large-negative instead of -inf: keeps masked softmax NaN-free


def _block_scores(qb, kb, scale):
    """qb: [B, bq, KVH, G, D], kb: [B, bk, KVH, D] → [B, KVH, G, bq, bk] f32."""
    return jnp.einsum(
        "bqhgd,bkhd->bhgqk",
        qb.astype(jnp.float32), kb.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale


def _block_values(p, vb):
    """p: [B, KVH, G, bq, bk] f32, vb: [B, bk, KVH, D] → [B, bq, KVH, G, D]."""
    return jnp.einsum(
        "bhgqk,bkhd->bqhgd", p, vb.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _online_update(carry, qb, kb, vb, mask, scale):
    """One online-softmax accumulation step (all f32)."""
    m, l, acc = carry
    s = _block_scores(qb, kb, scale)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * _to_bqhgd(corr)[..., None] + _block_values(p, vb)
    return m_new, l_new, acc_new


def _to_bqhgd(x):
    """[B, KVH, G, bq] → [B, bq, KVH, G] (align stats with value layout)."""
    return jnp.transpose(x, (0, 3, 1, 2))


def _finish(m, l, acc, dtype):
    out = acc / _to_bqhgd(l)[..., None]
    return out.astype(dtype)


def chunked_causal_attention(
    q: jnp.ndarray,   # [B, S, H, D]
    k: jnp.ndarray,   # [B, S, KVH, D]
    v: jnp.ndarray,   # [B, S, KVH, D]
    *,
    q_block: int = 512,
    kv_block: int = 512,
    impl: str = "masked",
) -> jnp.ndarray:
    """Exact causal attention, O(S·block) memory.  Returns [B, S, H, Dv].

    V's head dim may differ from Q/K's (MLA uses 192/128).
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    scale = 1.0 / np.sqrt(d)
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    if s % q_block or s % kv_block:
        # end-padding is exact under the causal mask: padded keys sit at
        # positions after every real query; padded query rows are dropped.
        blk = max(q_block, kv_block)
        pad = blk - s % blk
        padded = [jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
                  for x in (q, k, v)]
        out = chunked_causal_attention(
            *padded, q_block=q_block, kv_block=kv_block, impl=impl)
        return out[:, :s]
    qg = q.reshape(b, s, kvh, g, d)

    if impl == "balanced":
        return _balanced(qg, k, v, q_block, scale).reshape(b, s, h, dv)
    assert impl == "masked", impl
    nq, nk = s // q_block, s // kv_block

    qs = qg.reshape(b, nq, q_block, kvh, g, d)
    ks = k.reshape(b, nk, kv_block, kvh, d)
    vs = v.reshape(b, nk, kv_block, kvh, dv)

    def per_q_block(_, iq):
        qb = qs[:, iq]
        qpos = iq * q_block + jnp.arange(q_block)

        def inner(carry, jk):
            j, kb, vb = jk
            kpos = j * kv_block + jnp.arange(kv_block)
            mask = kpos[None, :] <= qpos[:, None]            # [bq, bk]
            mask = mask[None, None, None]                    # [1,1,1,bq,bk]
            return _online_update(carry, qb, kb, vb, mask, scale), None

        init = (
            jnp.full((b, kvh, g, q_block), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, q_block), jnp.float32),
            jnp.zeros((b, q_block, kvh, g, dv), jnp.float32),
        )
        xs = (jnp.arange(nk), jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0))
        (m, l, acc), _ = jax.lax.scan(inner, init, xs)
        return None, _finish(m, l, acc, q.dtype)

    _, outs = jax.lax.scan(per_q_block, None, jnp.arange(nq))
    # outs: [nq, B, bq, KVH, G, Dv] → [B, S, H, Dv]
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dv)


def _balanced(qg, k, v, blk, scale):
    """Load-balanced exact causal attention (q_block == kv_block == blk).

    Q block i pairs with Q block n−1−i; each pair visits exactly n+1 KV
    blocks, so there are no masked-out block matmuls and the total block
    count is n(n+1)/2 + n/2 ≈ half of the masked implementation's n².
    """
    b, s, kvh, g, d = qg.shape
    dv = v.shape[-1]
    n = s // blk
    assert n % 2 == 0, f"balanced impl needs an even number of blocks, got {n}"
    ks = jnp.moveaxis(k.reshape(b, n, blk, kvh, d), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, n, blk, kvh, dv), 1, 0)
    qs = jnp.moveaxis(qg.reshape(b, n, blk, kvh, g, d), 1, 0)

    def per_pair(_, p):
        i_lo, i_hi = p, n - 1 - p
        q_lo, q_hi = qs[i_lo], qs[i_hi]

        def inner(carry, t):
            (m, l, acc) = carry
            use_lo = t <= p
            iq = jnp.where(use_lo, i_lo, i_hi)
            j = jnp.where(use_lo, t, t - (p + 1))
            qb = jnp.where(use_lo, q_lo, q_hi)
            kb = jax.lax.dynamic_index_in_dim(ks, j, 0, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vs, j, 0, keepdims=False)
            qpos = iq * blk + jnp.arange(blk)
            kpos = j * blk + jnp.arange(blk)
            mask = (kpos[None, :] <= qpos[:, None])[None, None, None]
            half = jnp.where(use_lo, 0, 1)
            sel = lambda c: jax.lax.dynamic_index_in_dim(c, half, 0, keepdims=False)
            upd = _online_update(
                (sel(m), sel(l), sel(acc)), qb, kb, vb, mask, scale)
            put = lambda c, u: jax.lax.dynamic_update_index_in_dim(
                c, u, half, 0)
            return (put(m, upd[0]), put(l, upd[1]), put(acc, upd[2])), None

        init = (
            jnp.full((2, b, kvh, g, blk), NEG_INF, jnp.float32),
            jnp.zeros((2, b, kvh, g, blk), jnp.float32),
            jnp.zeros((2, b, blk, kvh, g, dv), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(inner, init, jnp.arange(n + 1))
        out = jax.vmap(lambda mm, ll, aa: _finish(mm, ll, aa, qg.dtype))(m, l, acc)
        return None, out   # [2, B, blk, KVH, G, D]

    _, outs = jax.lax.scan(per_pair, None, jnp.arange(n // 2))
    # outs: [n/2, 2, B, blk, kvh, g, d]; pair p wrote blocks (p, n-1-p)
    order = np.empty((n,), np.int32)
    for p in range(n // 2):
        order[p] = p * 2          # position of block p in flattened outs
        order[n - 1 - p] = p * 2 + 1
    flat = outs.reshape(n, b, blk, kvh, g, dv)
    flat = jnp.take(flat, jnp.asarray(order), axis=0)
    return jnp.moveaxis(flat, 0, 1).reshape(b, s, kvh, g, dv)


def decode_attention(
    q1: jnp.ndarray,       # [B, 1, H, D] — the new token's query
    k_cache: jnp.ndarray,  # [B, S_max, KVH, D]
    v_cache: jnp.ndarray,  # [B, S_max, KVH, D]
    length,                # int32 — valid cache length (new token included)
) -> jnp.ndarray:
    """Single-token attention against the cache.  Returns [B, 1, H, Dv]."""
    b, _, h, d = q1.shape
    kvh = k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = h // kvh
    scale = 1.0 / np.sqrt(d)
    qg = q1.reshape(b, kvh, g, d)
    s = jnp.einsum("bhgd,bshd->bhgs",
                   qg.astype(jnp.float32), k_cache.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])
    s = jnp.where(pos[None, None, None, :] < length, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dv).astype(q1.dtype)
