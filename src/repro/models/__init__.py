"""Model zoo substrate: pure-functional JAX decoders for the 10 assigned
architectures plus the paper's own MLP/CNN networks."""
from .config import ArchConfig
from .transformer import (init_cache, make_transformer_probe_fn, model_decode,
                          model_forward, model_forward_perturbed, model_init,
                          model_loss, model_prefill, model_probe_costs,
                          supports_fused_probe)

__all__ = [
    "ArchConfig", "model_init", "model_forward", "model_loss",
    "model_prefill", "model_decode", "init_cache",
    "model_forward_perturbed", "model_probe_costs",
    "make_transformer_probe_fn", "supports_fused_probe",
]
