"""Model zoo substrate: pure-functional JAX decoders for the 10 assigned
architectures plus the paper's own MLP/CNN networks."""
from .config import ArchConfig
from .transformer import (init_cache, model_decode, model_forward, model_init,
                          model_loss, model_prefill)

__all__ = [
    "ArchConfig", "model_init", "model_forward", "model_loss",
    "model_prefill", "model_decode", "init_cache",
]
