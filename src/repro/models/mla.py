"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and KV are low-rank compressed; only the compressed latent c_kv
(kv_lora_rank) plus the shared decoupled-RoPE key k_rope are cached.  Two
execution forms:

* expand form (train / prefill): decompress K/V per position and run
  standard causal attention — matmul-friendly at full sequence length.
* absorbed form (decode): fold W_UK into the query and W_UV into the
  output so attention runs directly against the compressed cache —
  per-step FLOPs O(H·(r + d_rope)) per cached token instead of
  O(H·(d_nope + d_rope)), and cache bytes per token are
  (kv_lora_rank + d_rope) instead of 2·H·d_head (~ 18× smaller for V3).

Equivalence of the two forms is asserted in tests/test_models.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .attention import chunked_causal_attention, NEG_INF
from .layers import dense, dense_init, rmsnorm, rmsnorm_init
from .rope import apply_rope


def mla_init(key, cfg, dtype):
    """cfg needs: d_model, n_heads, q_lora_rank, kv_lora_rank,
    qk_nope_head_dim, qk_rope_head_dim, v_head_dim."""
    ks = jax.random.split(key, 8)
    h = cfg.n_heads
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p = {
        "wkv_a": dense_init(ks[0], cfg.d_model,
                            cfg.kv_lora_rank + cfg.qk_rope_head_dim,
                            dtype=dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wkv_b": dense_init(ks[1], cfg.kv_lora_rank,
                            h * (cfg.qk_nope_head_dim + cfg.v_head_dim),
                            dtype=dtype),
        "wo": dense_init(ks[2], h * cfg.v_head_dim, cfg.d_model, dtype=dtype),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[3], cfg.d_model, cfg.q_lora_rank, dtype=dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[4], cfg.q_lora_rank, h * qd, dtype=dtype)
    else:
        p["wq"] = dense_init(ks[3], cfg.d_model, h * qd, dtype=dtype)
    return p


def _queries(p, x, cfg):
    b, s, _ = x.shape
    h = cfg.n_heads
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x)))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(b, s, h, qd)
    return jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)  # nope, rope


def _kv_latent(p, x, cfg, positions):
    """Returns (c_kv [B,S,r] normalized, k_rope [B,S,1,dr] rotated)."""
    ckv_full = dense(p["wkv_a"], x)
    c_kv, k_rope = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_attention(p, x, positions, cfg, *, q_block=512, kv_block=512,
                  impl="masked"):
    """Expand-form causal MLA over a full sequence.

    Returns (y [B,S,d_model], cache = (c_kv, k_rope squeezed)).
    """
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q_nope, q_rope = _queries(p, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = _kv_latent(p, x, cfg, positions)

    kv = dense(p["wkv_b"], c_kv).reshape(b, s, h, dn + dv)
    k_nope, v = jnp.split(kv, [dn], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
    attn = chunked_causal_attention(
        q, k, v, q_block=q_block, kv_block=kv_block, impl=impl)
    y = dense(p["wo"], attn.reshape(b, s, h * dv))
    return y, (c_kv, k_rope[:, :, 0, :])


def _absorb_weights(p, cfg):
    """Split wkv_b into per-head W_UK [r,H,dn] and W_UV [r,H,dv]."""
    h = cfg.n_heads
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    wkv_b = p["wkv_b"]["w"].reshape(cfg.kv_lora_rank, h, dn + dv)
    return wkv_b[..., :dn], wkv_b[..., dn:]


def mla_decode(p, x1, cache, length, cfg):
    """Absorbed-form single-token decode.

    x1: [B, 1, d_model]; cache = (c_kv [B,Smax,r], k_rope [B,Smax,dr]),
    already containing this token's entries at position length−1.
    """
    b = x1.shape[0]
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    c_cache, r_cache = cache
    pos = jnp.full((b, 1), length - 1, jnp.int32)

    q_nope, q_rope = _queries(p, x1, cfg)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)        # [B,1,H,dr]
    w_uk, w_uv = _absorb_weights(p, cfg)

    # fold W_UK into the query: q_eff [B,H,r]
    q_eff = jnp.einsum("bhd,rhd->bhr",
                       q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_eff,
                   c_cache.astype(jnp.float32))
        + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                     r_cache.astype(jnp.float32))
    ) / np.sqrt(dn + dr)
    idx = jnp.arange(c_cache.shape[1])
    scores = jnp.where(idx[None, None, :] < length, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, c_cache.astype(jnp.float32))
    attn = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
    y = dense(p["wo"], attn.reshape(b, 1, -1).astype(x1.dtype))
    return y


def mla_cache_update(p, x1, cache, length, cfg):
    """Compute this token's (c_kv, k_rope) and write them at length−1."""
    b = x1.shape[0]
    pos = jnp.full((b, 1), length - 1, jnp.int32)
    c_kv, k_rope = _kv_latent(p, x1, cfg, pos)
    c_cache, r_cache = cache
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        c_cache, c_kv.astype(c_cache.dtype), length - 1, 1)
    r_cache = jax.lax.dynamic_update_slice_in_dim(
        r_cache, k_rope[:, :, 0, :].astype(r_cache.dtype), length - 1, 1)
    return c_cache, r_cache
