"""Mixture-of-Experts with grouped one-hot dispatch (mesh-TF/GSPMD style).

Tokens are split into groups of ``group_size``; each group routes its tokens
to top-k experts under a per-group capacity C = ceil(group·k/E·cf).  The
dispatch/combine tensors are [G, Sg, E, C] — with small groups their FLOP
cost is ~S_g/(6·d_ff) of the expert compute (≈1% at Sg=256), and GSPMD
shards them cleanly: experts → "model"/"expert" axis (EP), groups → batch
axes (DP), with XLA inserting the token all-to-alls.

Top-k normalization follows DeepSeek-V3 (probs renormalized over the
selected experts); an optional shared expert runs densely on every token.
Router z-loss / aux balance losses are NOT plumbed to the optimizer —
under MGD the router is trained by the same scalar feedback as everything
else, which is a genuine simplification the framework records in DESIGN.md.
Tokens overflowing capacity are dropped (combine weight 0), standard for
capacity-based MoE.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from .layers import dense, dense_init, glu_mlp, glu_mlp_init


def moe_init(key, cfg, dtype):
    """cfg needs: d_model, d_ff (expert inner), n_experts, n_shared_experts."""
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = 1.0 / math.sqrt(d)

    def bank(k, d_in, d_out):
        return (jax.random.normal(k, (e, d_in, d_out), jnp.float32)
                * scale).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, e, dtype=jnp.float32),  # f32 routing
        "gate": bank(ks[1], d, f),
        "up": bank(ks[2], d, f),
        "down": bank(ks[3], f, d),
    }
    if cfg.n_shared_experts:
        p["shared"] = glu_mlp_init(ks[4], d, f * cfg.n_shared_experts, dtype)
    return p


def capacity(group_size: int, top_k: int, n_experts: int,
             factor: float = 1.25, multiple: int = 4) -> int:
    c = math.ceil(group_size * top_k / n_experts * factor)
    return max(multiple, ((c + multiple - 1) // multiple) * multiple)


def moe_apply(p, x, cfg, *, group_size: int = 256,
              capacity_factor: float = 1.25):
    """x: [B, S, d] → [B, S, d]."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    t = b * s
    gs = min(group_size, t)
    assert t % gs == 0, (t, gs)
    g = t // gs
    c = capacity(gs, k, e, capacity_factor)

    xg = x.reshape(g, gs, d)
    logits = dense(p["router"], xg.astype(jnp.float32))      # [G,Sg,E] f32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # [G,Sg,K]
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)   # [G,Sg,K,E]
    # position of each (token,k) routing within its expert, in (s,k) order
    flat = onehot.reshape(g, gs * k, e)
    pos = (jnp.cumsum(flat, axis=1) - 1.0) * flat               # [G,Sg*K,E]
    pos = pos.reshape(g, gs, k, e)
    keep = (pos < c) & (onehot > 0)
    slot = jax.nn.one_hot(jnp.sum(pos * onehot, -1), c, dtype=jnp.float32)
    # combine[g,s,e,c] = Σ_k gate·onehot·keep·slot
    combine = jnp.einsum("gske,gskc->gsec",
                         onehot * keep * gate_vals[..., None], slot)
    dispatch = (combine > 0).astype(x.dtype)

    # expert tensors: E → EP axis, groups → DP axes (keeps the dispatch
    # working set sharded both ways; the token all-to-all happens here)
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, x.reshape(g, gs, d))
    expert_in = shard(expert_in, "expert", "batch", None, None)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, p["gate"])
                    .astype(jnp.float32)).astype(x.dtype)
    h = h * jnp.einsum("egcd,edf->egcf", expert_in, p["up"])
    h = shard(h, "expert", "batch", None, None)
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["down"])
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), expert_out)
    y = y.reshape(b, s, d)

    if "shared" in p:
        y = y + glu_mlp(p["shared"], x)
    return y


def moe_apply_dense_ref(p, x, cfg):
    """O(E·T) dense reference — every expert sees every token; used as the
    dispatch-correctness oracle in tests (no capacity drops)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    logits = dense(p["router"], x.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    dense_w = jnp.sum(
        jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)
        * gate_vals[..., None], axis=-2)                       # [B,S,E]

    def one_expert(i):
        h = jax.nn.silu((x @ p["gate"][i]).astype(jnp.float32)).astype(x.dtype)
        h = h * (x @ p["up"][i])
        return h @ p["down"][i]

    outs = jax.lax.map(one_expert, jnp.arange(e))              # [E,B,S,d]
    y = jnp.einsum("bse,ebsd->bsd", dense_w.astype(x.dtype), outs)
    if "shared" in p:
        y = y + glu_mlp(p["shared"], x)
    return y
