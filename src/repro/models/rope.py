"""Rotary position embeddings — standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (multimodal RoPE, arXiv:2409.12191) splits the rotary half-dim into
three sections (temporal, height, width) and rotates each section with its
own position id.  For pure text all three ids are equal, which reduces
M-RoPE exactly to 1-D RoPE — tested in tests/test_models.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape [d_head//2] (f32)."""
    half = d_head // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate x: [..., S, H, D] by per-token positions [..., S] (int32)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                         # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                   # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: tuple) -> jnp.ndarray:
    """Qwen2-VL M-RoPE.  x: [B, S, H, D]; positions3: [B, S, 3] (t, h, w).

    ``sections`` partitions the half-dim (sum(sections) == D//2); section i
    rotates with positions3[..., i].
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(d, theta)                          # [D/2]
    # build per-frequency position ids by section
    sec_id = np.concatenate([
        np.full((s,), i, np.int32) for i, s in enumerate(sections)
    ])                                                  # [D/2]
    pos = jnp.take(positions3, jnp.asarray(sec_id), axis=-1)   # [B, S, D/2]
    ang = pos.astype(jnp.float32) * inv                 # [B, S, D/2]
    cos = jnp.cos(ang)[..., None, :]                    # [B, S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
