"""ArchConfig — one frozen dataclass describing every supported family.

Families:
    dense   — GQA decoder transformer (mistral-nemo, qwen3, granite, qwen2)
    moe     — dense attention (or MLA) + mixture-of-experts MLP
    ssm     — RWKV-6 (attention-free)
    hybrid  — Mamba-2 backbone + shared attention block (zamba2)
    vlm     — dense backbone + M-RoPE + stubbed patch-embedding frontend
    audio   — dense backbone over EnCodec codebook tokens (stub frontend)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0             # 0 → = n_heads
    d_head: int = 0                 # 0 → d_model // n_heads
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    mrope_sections: Optional[Tuple[int, ...]] = None   # qwen2-vl
    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    n_shared_experts: int = 0
    moe_group_size: int = 256
    # capacity factor: tokens over C = group·k/E·cf are dropped.  NOTE:
    # capacity competition makes MoE outputs depend on group composition,
    # so prefill-vs-decode parity is only exact with cf high enough to
    # never drop (tests use cf ≥ E/k).
    moe_capacity_factor: float = 1.25
    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0             # zamba2: shared attn after every k mamba
    # audio
    n_codebooks: int = 0            # musicgen EnCodec codebooks
    # numerics / execution
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    attn_q_block: int = 512
    attn_kv_block: int = 512
    attn_impl: str = "masked"       # masked | balanced
    la_chunk: int = 32              # linear-attention chunk length
    fsdp: bool = False              # shard weights on the DP axis too
    seq_parallel: bool = False      # Megatron-SP residual sharding
    scan_layers: bool = True
    # embedding tying
    tie_embeddings: bool = False

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def jdtype(self):
        import jax.numpy as jnp
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                "float16": jnp.float16}[self.dtype]

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)
