"""The paper's own experiment networks (§3).

* ``mlp``        — sigmoidal feedforward nets: 2-2-1 (XOR), n-n-1 (parity),
  49-4-4 (NIST7x7).  Supports per-neuron activation defects (§3.5, Fig. 10).
* ``cnn``        — the Fashion-MNIST 2-conv and CIFAR-10 3-conv nets of
  Table 2 (3×3 convs + 2×2 max-pools + linear head, no softmax; MSE cost on
  one-hot targets, exactly as the paper specifies).

The paper's CNN layer widths are given but the exact head wiring is
ambiguous ("converted the 256 outputs"); we pool CIFAR to 2×2×64 = 256 and
Fashion-MNIST to 7×7×32, and record our parameter counts in EXPERIMENTS.md
next to the paper's (26154 / 14378).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.cost import mse
from repro.core.noise import ActivationDefects, defective_sigmoid
from repro.core.utils import leaf_id_tree
from .layers import conv2d, conv2d_init, dense, dense_init, maxpool2, pdense


# --- fully-connected sigmoid nets ------------------------------------------


def mlp_init(key, sizes: Sequence[int]):
    """sizes e.g. (2, 2, 1) — weights N(0,1)/sqrt(fan_in), biases zero."""
    ks = jax.random.split(key, len(sizes) - 1)
    return [dense_init(k, a, b, bias=True, dtype=jnp.float32)
            for k, a, b in zip(ks, sizes[:-1], sizes[1:])]


def mlp_apply(params, x, defects: Optional[Sequence[ActivationDefects]] = None):
    """Sigmoid MLP; ``defects[i]`` (optional) deforms layer i's activations."""
    for i, p in enumerate(params):
        x = dense(p, x)
        if defects is not None and defects[i] is not None:
            x = defective_sigmoid(x, defects[i])
        else:
            x = jax.nn.sigmoid(x)
    return x


def mlp_apply_perturbed(params, x, probe,
                        defects: Optional[Sequence[ActivationDefects]] = None):
    """``mlp_apply`` under perturbation θ̃(probe) — the fused probe path.

    Weight matmuls go through the Pallas perturbed-matmul kernels (θ̃ never
    materialized; the antithetic pair shares one read of each W); biases get
    a materialized θ̃.  Returns a tuple of per-sign outputs, one per entry of
    ``probe.ctx.signs`` — bit-identical (f32) to running ``mlp_apply`` on
    the materialized θ ± θ̃.
    """
    ids = leaf_id_tree(params)
    xs = tuple(x for _ in probe.ctx.signs)
    for i, (p, pid) in enumerate(zip(params, ids)):
        xs = pdense(p, xs, pid, probe)
        if defects is not None and defects[i] is not None:
            xs = tuple(defective_sigmoid(h, defects[i]) for h in xs)
        else:
            xs = tuple(jax.nn.sigmoid(h) for h in xs)
    return xs


def linear_apply(params, x):
    """Affine chain with NO activation — the jax twin of
    ``hardware.devices.LinearLaneChip``'s forward.  Same layer pytree
    shape as ``mlp_init`` output ([{"w": ..., "b": ...}, ...]); with
    dyadic parameters and {0,1} inputs every product and partial sum is
    exact in f32, so this matches the numpy chip bit-for-bit regardless
    of dot-product association."""
    h = jnp.asarray(x, jnp.float32)
    for p in params:
        h = h @ p["w"]
        if "b" in p:
            h = h + p["b"]
    return h


def make_mlp_probe_fn(defects: Optional[Sequence[ActivationDefects]] = None):
    """probe_fn(params, batch, probe) → [n_signs] MSE costs, for
    ``MGDConfig(fused=True)`` (see core.mgd.build_mgd_step)."""

    def probe_fn(params, batch, probe):
        outs = mlp_apply_perturbed(params, batch["x"], probe, defects)
        return jnp.stack([mse(o, batch["y"]) for o in outs])

    return probe_fn


# --- the paper's CNNs -------------------------------------------------------


def cnn_init(key, *, in_hw, in_ch, channels, n_classes, head_pool):
    """channels e.g. (16, 32) fmnist / (16, 32, 64) cifar."""
    ks = jax.random.split(key, len(channels) + 1)
    convs = []
    c = in_ch
    hw = in_hw
    for k, co in zip(ks, channels):
        convs.append(conv2d_init(k, 3, 3, c, co))
        c = co
        hw //= 2
    while hw > head_pool:  # extra pools to reach the paper's head width
        hw //= 2
    feat = hw * hw * c
    return {"convs": convs,
            "fc": dense_init(ks[-1], feat, n_classes, bias=True)}


def cnn_apply(params, x, *, head_pool):
    """x: [B,H,W,C] → class scores [B,n_classes] (no softmax, per paper)."""
    for p in params["convs"]:
        x = jax.nn.relu(conv2d(p, x))
        x = maxpool2(x)
    while x.shape[1] > head_pool:
        x = maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    return dense(params["fc"], x)


def fashion_cnn_init(key):
    return cnn_init(key, in_hw=28, in_ch=1, channels=(16, 32),
                    n_classes=10, head_pool=7)


def fashion_cnn_apply(params, x):
    return cnn_apply(params, x, head_pool=7)


def cifar_cnn_init(key):
    return cnn_init(key, in_hw=32, in_ch=3, channels=(16, 32, 64),
                    n_classes=10, head_pool=2)


def cifar_cnn_apply(params, x):
    return cnn_apply(params, x, head_pool=2)
