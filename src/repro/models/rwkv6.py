"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free linear RNN with
data-dependent per-channel decay.

Faithful pieces: token-shift lerp mixing, the w-LoRA data-dependent decay
w_t = exp(−exp(w0 + tanh(x_w A) B)), the u (time_faaaa) bonus, per-head
GroupNorm (ln_x), SiLU(g) output gating, squared-ReLU channel mix.
Simplification (noted in DESIGN.md): the first-order token-shift lerp uses
static μ (RWKV-6's ddlerp adds a second LoRA on the μ themselves).

State per layer: (x_prev_att [B,d], x_prev_ffn [B,d], wkv [B,H,dk,dv]) —
O(1) in sequence length, which is why rwkv6 runs the long_500k shape.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import (dense, dense_init, groupnorm_heads, layernorm,
                     layernorm_init)
from .linear_attention import chunked_vector_decay, step_vector_decay

W_LORA_DIM = 64


def rwkv6_block_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 12)

    def mu(k):
        return jax.random.uniform(k, (d,), jnp.float32).astype(dtype)

    att = {
        "mu_r": mu(ks[0]), "mu_k": mu(ks[1]), "mu_v": mu(ks[2]),
        "mu_g": mu(ks[3]), "mu_w": mu(ks[4]),
        "wr": dense_init(ks[5], d, d, dtype=dtype),
        "wk": dense_init(ks[6], d, d, dtype=dtype),
        "wv": dense_init(ks[7], d, d, dtype=dtype),
        "wg": dense_init(ks[8], d, d, dtype=dtype),
        "wo": dense_init(ks[9], d, d, dtype=dtype),
        "w0": jnp.zeros((d,), jnp.float32),
        "w_lora_a": (jax.random.normal(ks[10], (d, W_LORA_DIM), jnp.float32)
                     * 0.01).astype(dtype),
        "w_lora_b": (jax.random.normal(ks[11], (W_LORA_DIM, d), jnp.float32)
                     * 0.01).astype(dtype),
        "u": jnp.zeros((h, dh), jnp.float32),
        "ln_x": layernorm_init(d, jnp.float32),
    }
    kf = jax.random.split(ks[0], 4)
    ffn = {
        "mu_k": mu(kf[0]), "mu_r": mu(kf[1]),
        "wk": dense_init(kf[2], d, cfg.d_ff, dtype=dtype),
        "wv": dense_init(kf[3], cfg.d_ff, d, dtype=dtype),
        "wr": dense_init(kf[0], d, d, dtype=dtype),
    }
    return {"ln1": layernorm_init(d, dtype), "ln2": layernorm_init(d, dtype),
            "att": att, "ffn": ffn}


def rwkv6_state_init(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    return {
        "att_x": jnp.zeros((batch, d), dtype),
        "ffn_x": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, dh, dh), jnp.float32),
    }


def _shift(x, x_prev):
    """Token shift: out[t] = x[t−1]; position 0 sees x_prev."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _log_decay(att, xw):
    """log w = −exp(w0 + tanh(xw·A)·B) ∈ (−inf, 0)."""
    lora = jnp.tanh(xw @ att["w_lora_a"]) @ att["w_lora_b"]
    return -jnp.exp(att["w0"].astype(jnp.float32)
                    + lora.astype(jnp.float32))


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def rwkv6_time_mix(att, x, state, cfg, *, chunk=32):
    """x: [B,S,d] → (y, new_state).  state = (x_prev [B,d], wkv)."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    x_prev, wkv = state
    xs = _shift(x, x_prev.astype(x.dtype))
    r = dense(att["wr"], _mix(x, xs, att["mu_r"])).reshape(b, s, h, dh)
    k = dense(att["wk"], _mix(x, xs, att["mu_k"])).reshape(b, s, h, dh)
    v = dense(att["wv"], _mix(x, xs, att["mu_v"])).reshape(b, s, h, dh)
    g = dense(att["wg"], _mix(x, xs, att["mu_g"]))
    log_w = _log_decay(att, _mix(x, xs, att["mu_w"])).reshape(b, s, h, dh)

    y, wkv = chunked_vector_decay(r, k, v, log_w, att["u"], s0=wkv,
                                  chunk=chunk)
    y = groupnorm_heads(att["ln_x"], y.reshape(b, s, d), h)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    return dense(att["wo"], y), (x[:, -1, :], wkv)


def rwkv6_channel_mix(ffn, x, x_prev):
    xs = _shift(x, x_prev.astype(x.dtype))
    xk = _mix(x, xs, ffn["mu_k"])
    xr = _mix(x, xs, ffn["mu_r"])
    k = jnp.square(jax.nn.relu(dense(ffn["wk"], xk).astype(jnp.float32)))
    r = jax.nn.sigmoid(dense(ffn["wr"], xr).astype(jnp.float32))
    return (r * dense(ffn["wv"], k.astype(x.dtype)).astype(jnp.float32)
            ).astype(x.dtype), x[:, -1, :]


def rwkv6_block(p, x, state, cfg, *, chunk=32):
    """Full block: x [B,S,d] → (x', new_state dict)."""
    att_y, (att_x, wkv) = rwkv6_time_mix(
        p["att"], layernorm(p["ln1"], x), (state["att_x"], state["wkv"]),
        cfg, chunk=chunk)
    x = x + att_y
    ffn_y, ffn_x = rwkv6_channel_mix(
        p["ffn"], layernorm(p["ln2"], x), state["ffn_x"])
    x = x + ffn_y
    return x, {"att_x": att_x, "ffn_x": ffn_x, "wkv": wkv}


def rwkv6_block_step(p, x1, state, cfg):
    """Single-token decode: x1 [B,d] → (y [B,d], new_state)."""
    b, d = x1.shape
    h = cfg.n_heads
    dh = d // h
    att, ffn = p["att"], p["ffn"]

    xn = layernorm(p["ln1"], x1)
    xs = state["att_x"].astype(xn.dtype)
    mix = lambda mu: xn + (xs - xn) * mu.astype(xn.dtype)
    r = dense(att["wr"], mix(att["mu_r"])).reshape(b, h, dh)
    k = dense(att["wk"], mix(att["mu_k"])).reshape(b, h, dh)
    v = dense(att["wv"], mix(att["mu_v"])).reshape(b, h, dh)
    g = dense(att["wg"], mix(att["mu_g"]))
    log_w = _log_decay(att, mix(att["mu_w"])).reshape(b, h, dh)
    y, wkv = step_vector_decay(r, k, v, log_w, att["u"], state["wkv"])
    y = groupnorm_heads(att["ln_x"], y.reshape(b, d).astype(x1.dtype), h)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    x1 = x1 + dense(att["wo"], y)
    new_att_x = xn

    xn2 = layernorm(p["ln2"], x1)
    xs2 = state["ffn_x"].astype(xn2.dtype)
    xk = xn2 + (xs2 - xn2) * ffn["mu_k"].astype(xn2.dtype)
    xr = xn2 + (xs2 - xn2) * ffn["mu_r"].astype(xn2.dtype)
    kk = jnp.square(jax.nn.relu(dense(ffn["wk"], xk).astype(jnp.float32)))
    rr = jax.nn.sigmoid(dense(ffn["wr"], xr).astype(jnp.float32))
    x1 = x1 + (rr * dense(ffn["wv"], kk.astype(x1.dtype)).astype(jnp.float32)
               ).astype(x1.dtype)
    return x1, {"att_x": new_att_x, "ffn_x": xn2, "wkv": wkv}
