"""Decoder assembly for all 10 assigned architectures.

One set of entry points, family-dispatched:

    model_init(cfg, key)                  → params (stacked-layer pytree)
    model_forward(params, cfg, batch)     → logits          (train/prefill)
    model_loss(params, cfg, batch)        → scalar xent     (MGD's loss_fn)
    init_cache(cfg, batch, max_len)       → decode cache/state
    model_prefill(params, cfg, batch, max_len) → (logits, cache)
    model_decode(params, cfg, tokens, cache)   → (logits, cache)

Layers are stacked on a leading L dim and driven by ``lax.scan`` — one
layer's HLO regardless of depth (compile-time and GSPMD-friendliness at
88-layer scale).  Activation sharding uses logical axis names translated
against whatever mesh is active (repro.distributed.sharding).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import perturbations as pert
from repro.core.utils import leaf_id_tree, tree_add, tree_axpy
from repro.distributed.sharding import shard
from .attention import chunked_causal_attention, decode_attention
from .config import ArchConfig
from .layers import (dense, dense_init, embed, embedding_init, glu_mlp,
                     glu_mlp_init, pdense, pleaf, prmsnorm, rmsnorm,
                     rmsnorm_init)
from .mamba2 import (mamba2_block, mamba2_block_init, mamba2_block_step,
                     mamba2_state_init)
from .mla import (mla_attention, mla_cache_update, mla_decode, mla_init)
from .moe import moe_apply, moe_init
from .rope import apply_mrope, apply_rope
from .rwkv6 import (rwkv6_block, rwkv6_block_init, rwkv6_block_step,
                    rwkv6_state_init)

# ---------------------------------------------------------------------------
# GQA attention sub-layer
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ArchConfig, dtype):
    h, kvh, dh, d = cfg.n_heads, cfg.kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], d, kvh * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], d, kvh * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], h * dh, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, dtype)
        p["k_norm"] = rmsnorm_init(dh, dtype)
    return p


def _rope(cfg, x, positions):
    if cfg.mrope_sections is not None and positions.ndim == 3:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def _qkv(p, x, positions, cfg):
    b, s, d = x.shape
    h, kvh, dh = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, s, h, dh)
    k = dense(p["wk"], x).reshape(b, s, kvh, dh)
    v = dense(p["wv"], x).reshape(b, s, kvh, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    return q, k, v


def attn_apply(p, x, positions, cfg: ArchConfig):
    """Full-sequence causal attention.  Returns (y, (k, v) for caching)."""
    b, s, d = x.shape
    q, k, v = _qkv(p, x, positions, cfg)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)
    y = chunked_causal_attention(
        q, k, v, q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
        impl=cfg.attn_impl)
    y = dense(p["wo"], y.reshape(b, s, -1))
    return y, (k, v)


def attn_decode_step(p, x1, positions, kcache, vcache, length, cfg):
    """x1: [B,1,d].  Caches [B,Smax,KVH,dh]; entry written at length−1."""
    b = x1.shape[0]
    q, k, v = _qkv(p, x1, positions, cfg)
    kcache = jax.lax.dynamic_update_slice_in_dim(
        kcache, k.astype(kcache.dtype), length - 1, 1)
    vcache = jax.lax.dynamic_update_slice_in_dim(
        vcache, v.astype(vcache.dtype), length - 1, 1)
    y = decode_attention(q, kcache, vcache, length)
    y = dense(p["wo"], y.reshape(b, 1, -1))
    return y, kcache, vcache


# ---------------------------------------------------------------------------
# One decoder layer (dense / moe / mla variants)
# ---------------------------------------------------------------------------


def block_init(key, cfg: ArchConfig, dtype):
    ka, km = jax.random.split(key)
    p = {"ln1": rmsnorm_init(cfg.d_model, dtype),
         "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if cfg.use_mla:
        p["attn"] = mla_init(ka, cfg, dtype)
    else:
        p["attn"] = attn_init(ka, cfg, dtype)
    if cfg.n_experts:
        p["moe"] = moe_init(km, cfg, dtype)
    else:
        p["mlp"] = glu_mlp_init(km, cfg.d_model, cfg.d_ff, dtype)
    return p


def _mlp_part(p, x, cfg):
    if cfg.n_experts:
        y = moe_apply(p["moe"], x, cfg, group_size=cfg.moe_group_size,
                      capacity_factor=cfg.moe_capacity_factor)
    else:
        y = glu_mlp(p["mlp"], x)
    return y


def block_apply(p, x, positions, cfg: ArchConfig):
    """Pre-norm residual block.  Returns (x', kv-cache payload)."""
    seq_ax = "sp" if cfg.seq_parallel else None
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        att, cache = mla_attention(
            p["attn"], xn, positions, cfg,
            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
            impl=cfg.attn_impl)
    else:
        att, cache = attn_apply(p["attn"], xn, positions, cfg)
    x = x + att
    x = shard(x, "batch", seq_ax, None)
    y = _mlp_part(p, rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    x = x + y
    return shard(x, "batch", seq_ax, None), cache


def block_decode(p, x1, positions, layer_cache, length, cfg: ArchConfig):
    xn = rmsnorm(p["ln1"], x1, cfg.norm_eps)
    if cfg.use_mla:
        cache = mla_cache_update(p["attn"], xn, layer_cache, length, cfg)
        att = mla_decode(p["attn"], xn, cache, length, cfg)
    else:
        kc, vc = layer_cache
        att, kc, vc = attn_decode_step(
            p["attn"], xn, positions, kc, vc, length, cfg)
        cache = (kc, vc)
    x1 = x1 + att
    y = _mlp_part(p, rmsnorm(p["ln2"], x1, cfg.norm_eps), cfg)
    return x1 + y, cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    n_tables = max(cfg.n_codebooks, 1)
    p = {"tok": embedding_init(k1, cfg.vocab * n_tables, cfg.d_model, dtype),
         "ln_f": rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        head_out = cfg.vocab * n_tables
        p["head"] = dense_init(k2, cfg.d_model, head_out, dtype=dtype)
    return p


def _embed_tokens(p, cfg: ArchConfig, batch):
    """Tokens or precomputed (stub-frontend) embeddings → [B,S,d]."""
    if "embeds" in batch:
        x = batch["embeds"]
    elif cfg.n_codebooks:
        # musicgen: tokens [B, nq, S]; codebook i uses table slice i
        toks = batch["tokens"]
        b, nq, s = toks.shape
        offs = (jnp.arange(nq, dtype=toks.dtype) * cfg.vocab)[None, :, None]
        x = embed(p["tok"], toks + offs).sum(axis=1)
    else:
        x = embed(p["tok"], batch["tokens"])
    return shard(x, "batch", "sp" if cfg.seq_parallel else None, None)


def _logits(p, cfg: ArchConfig, x):
    if cfg.tie_embeddings:
        logits = x @ p["tok"]["table"].T
    else:
        logits = dense(p["head"], x)
    logits = shard(logits, "batch", None, "model")
    if cfg.n_codebooks:
        b, s, _ = logits.shape
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab)
    return logits


def _positions(cfg: ArchConfig, batch, s, b):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[..., None], (b, s, 3))
    return pos


# ---------------------------------------------------------------------------
# Model: init / forward / loss
# ---------------------------------------------------------------------------


def _layer_keys(key, n):
    return jax.random.split(key, n)


def model_init(cfg: ArchConfig, key):
    dtype = cfg.jdtype
    k_emb, k_layers, k_shared = jax.random.split(key, 3)
    params: Dict[str, Any] = {"embed": _embed_init(k_emb, cfg, dtype)}
    if cfg.family == "ssm":
        init_one = functools.partial(rwkv6_block_init, cfg=cfg, dtype=dtype)
        params["layers"] = jax.vmap(init_one)(_layer_keys(k_layers, cfg.n_layers))
    elif cfg.family == "hybrid":
        n_mamba, n_shared_calls = _hybrid_plan(cfg)
        init_one = functools.partial(mamba2_block_init, cfg=cfg, dtype=dtype)
        params["layers"] = jax.vmap(init_one)(_layer_keys(k_layers, n_mamba))
        params["shared_attn"] = block_init(k_shared, cfg, dtype)
    else:
        init_one = functools.partial(block_init, cfg=cfg, dtype=dtype)
        params["layers"] = jax.vmap(init_one)(_layer_keys(k_layers, cfg.n_layers))
    return params


def _hybrid_plan(cfg: ArchConfig):
    """zamba2: n_layers counts mamba blocks + shared-attn invocations.
    With attn_every = k: groups of (k mamba + 1 shared attn)."""
    k = cfg.attn_every
    group = k + 1
    n_groups = cfg.n_layers // group
    n_mamba = n_groups * k
    return n_mamba, n_groups


def model_forward(params, cfg: ArchConfig, batch, *, return_state=False,
                  state=None):
    """Full-sequence forward → logits [B,S,V].  For ssm/hybrid, optionally
    returns the recurrent state (prefill path)."""
    x = _embed_tokens(params["embed"], cfg, batch)
    b, s, _ = x.shape
    positions = _positions(cfg, batch, s, b)

    if cfg.family == "ssm":
        if state is None:
            state = jax.vmap(
                lambda _: rwkv6_state_init(cfg, b), axis_size=cfg.n_layers,
                out_axes=0)(jnp.arange(cfg.n_layers))

        def body(x, layer):
            lp, st = layer
            x, st = rwkv6_block(lp, x, st, cfg, chunk=cfg.la_chunk)
            return x, st

        x, new_state = jax.lax.scan(body, x, (params["layers"], state))
    elif cfg.family == "hybrid":
        n_mamba, n_groups = _hybrid_plan(cfg)
        k = cfg.attn_every
        if state is None:
            state = {
                "mamba": jax.vmap(
                    lambda _: mamba2_state_init(cfg, b), axis_size=n_mamba,
                    out_axes=0)(jnp.arange(n_mamba)),
                "attn_kv": None,
            }
        lp_grouped = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups, k, *a.shape[1:]), params["layers"])
        st_grouped = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups, k, *a.shape[1:]), state["mamba"])

        def body(x, layer):
            lps, sts = layer

            def inner(x, one):
                lp, st = one
                x, st = mamba2_block(lp, x, st, cfg, chunk=cfg.la_chunk)
                return x, st

            x, new_sts = jax.lax.scan(inner, x, (lps, sts))
            x, kv = block_apply(params["shared_attn"], x, positions, cfg)
            return x, (new_sts, kv)

        x, (new_m, kvs) = jax.lax.scan(body, x, (lp_grouped, st_grouped))
        new_state = {
            "mamba": jax.tree_util.tree_map(
                lambda a: a.reshape(n_mamba, *a.shape[2:]), new_m),
            "attn_kv": kvs,
        }
    else:
        def body(x, lp):
            x, kv = block_apply(lp, x, positions, cfg)
            return x, kv

        x, kvs = jax.lax.scan(body, x, params["layers"])
        new_state = kvs

    x = rmsnorm(params["embed"]["ln_f"], x, cfg.norm_eps)
    logits = _logits(params["embed"], cfg, x)
    if return_state:
        return logits, new_state
    return logits


def _loss_from_logits(logits, labels):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def model_loss(params, cfg: ArchConfig, batch):
    """Token-mean softmax cross-entropy — MGD's scalar cost."""
    return _loss_from_logits(model_forward(params, cfg, batch),
                             batch["labels"])


# ---------------------------------------------------------------------------
# Fused probe path (MGD): forward under θ ± θ̃ without materializing θ̃
# ---------------------------------------------------------------------------
#
# The GQA/MLP weight matmuls — the HBM-dominant leaves — route through the
# Pallas perturbed-matmul kernels (sign generation in VMEM; the antithetic
# central pair reads each W tile ONCE).  Norm scales, biases and the
# embedding table fall back to materialized θ̃ (O(d) or gather-bound).
# Stacked-layer banks are addressed through the per-layer seed shift, so the
# in-kernel sign pattern is bit-identical to the host generator's view of
# the stacked leaf.


def _pqkv(p, xs, positions, cfg, ids, probe, layer):
    b, s, _ = xs[0].shape
    h, kvh, dh = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    qs = tuple(q.reshape(b, s, h, dh)
               for q in pdense(p["wq"], xs, ids["wq"], probe, layer=layer))
    ks = tuple(k.reshape(b, s, kvh, dh)
               for k in pdense(p["wk"], xs, ids["wk"], probe, layer=layer))
    vs = tuple(v.reshape(b, s, kvh, dh)
               for v in pdense(p["wv"], xs, ids["wv"], probe, layer=layer))
    if cfg.qk_norm:
        qs = prmsnorm(p["q_norm"], qs, ids["q_norm"], probe, layer=layer,
                      eps=cfg.norm_eps)
        ks = prmsnorm(p["k_norm"], ks, ids["k_norm"], probe, layer=layer,
                      eps=cfg.norm_eps)
    qs = tuple(_rope(cfg, q, positions) for q in qs)
    ks = tuple(_rope(cfg, k, positions) for k in ks)
    return qs, ks, vs


def _pattn_apply(p, xs, positions, cfg: ArchConfig, ids, probe, layer):
    b, s, _ = xs[0].shape
    qs, ks, vs = _pqkv(p, xs, positions, cfg, ids, probe, layer)
    ys = []
    for q, k, v in zip(qs, ks, vs):
        q = shard(q, "batch", None, "model", None)
        k = shard(k, "batch", None, "model", None)
        v = shard(v, "batch", None, "model", None)
        y = chunked_causal_attention(
            q, k, v, q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
            impl=cfg.attn_impl)
        ys.append(y.reshape(b, s, -1))
    return pdense(p["wo"], tuple(ys), ids["wo"], probe, layer=layer)


def _pglu_mlp(p, xs, ids, probe, layer):
    gs = pdense(p["gate"], xs, ids["gate"], probe, layer=layer)
    us = pdense(p["up"], xs, ids["up"], probe, layer=layer)
    hs = tuple(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
               for g, u, x in zip(gs, us, xs))
    return pdense(p["down"], hs, ids["down"], probe, layer=layer)


def _pblock_apply(p, xs, positions, cfg: ArchConfig, ids, probe, layer):
    seq_ax = "sp" if cfg.seq_parallel else None
    xn = prmsnorm(p["ln1"], xs, ids["ln1"], probe, layer=layer,
                  eps=cfg.norm_eps)
    att = _pattn_apply(p["attn"], xn, positions, cfg, ids["attn"], probe,
                       layer)
    xs = tuple(x + a for x, a in zip(xs, att))
    xs = tuple(shard(x, "batch", seq_ax, None) for x in xs)
    ys = _pglu_mlp(
        p["mlp"],
        prmsnorm(p["ln2"], xs, ids["ln2"], probe, layer=layer,
                 eps=cfg.norm_eps),
        ids["mlp"], probe, layer)
    xs = tuple(x + y for x, y in zip(xs, ys))
    return tuple(shard(x, "batch", seq_ax, None) for x in xs)


def supports_fused_probe(cfg: ArchConfig) -> bool:
    """Dense GQA decoders (incl. vlm/audio stub frontends) have the fully
    fused probe path; MoE/MLA/SSM/hybrid fall back to materializing."""
    return (cfg.family in ("dense", "vlm", "audio")
            and not cfg.use_mla and not cfg.n_experts)


def model_forward_perturbed(params, cfg: ArchConfig, batch, probe):
    """Per-sign perturbed logits, θ̃ fused into the weight matmuls.

    Returns a tuple of logits arrays, one per ``probe.ctx.signs`` entry.
    """
    assert supports_fused_probe(cfg), cfg.family
    ids = leaf_id_tree(params)
    emb, eids = params["embed"], ids["embed"]
    tables = pleaf(emb["tok"]["table"], eids["tok"]["table"], probe)
    if "embeds" in batch:
        xs = tuple(batch["embeds"] for _ in probe.ctx.signs)
    elif cfg.n_codebooks:
        toks = batch["tokens"]
        _, nq, _ = toks.shape
        offs = (jnp.arange(nq, dtype=toks.dtype) * cfg.vocab)[None, :, None]
        xs = tuple(jnp.take(t, toks + offs, axis=0).sum(axis=1)
                   for t in tables)
    else:
        xs = tuple(jnp.take(t, batch["tokens"], axis=0) for t in tables)
    xs = tuple(shard(x, "batch", "sp" if cfg.seq_parallel else None, None)
               for x in xs)
    b, s, _ = xs[0].shape
    positions = _positions(cfg, batch, s, b)

    def body(carry, layer_in):
        lp, l = layer_in
        out = _pblock_apply(lp, carry, positions, cfg, ids["layers"], probe,
                            l)
        return out, None

    xs, _ = jax.lax.scan(
        body, xs, (params["layers"], jnp.arange(cfg.n_layers)))
    xs = prmsnorm(emb["ln_f"], xs, eids["ln_f"], probe, eps=cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = tuple(x @ t.T for x, t in zip(xs, tables))
    else:
        logits = pdense(emb["head"], xs, eids["head"], probe)
    logits = tuple(shard(l, "batch", None, "model") for l in logits)
    if cfg.n_codebooks:
        logits = tuple(l.reshape(b, s, cfg.n_codebooks, cfg.vocab)
                       for l in logits)
    return logits


def model_probe_costs(params, cfg: ArchConfig, batch, probe):
    """probe_fn for ``MGDConfig(fused=True)``: [n_signs] xent costs.

    Fused for dense GQA decoders; other families materialize θ̃ per sign
    with the exact float order of the unfused optimizer path.
    """
    if supports_fused_probe(cfg):
        logits = model_forward_perturbed(params, cfg, batch, probe)
        return jnp.stack(
            [_loss_from_logits(l, batch["labels"]) for l in logits])
    theta = pert.generate(
        params, ptype="rademacher", step=probe.step, seed=probe.seed,
        dtheta=probe.ctx.dtheta, tau_p=probe.ctx.tau_p)
    costs = []
    for s in probe.ctx.signs:
        p_s = tree_add(params, theta) if s == 1.0 else tree_axpy(
            s, theta, params)
        costs.append(model_loss(p_s, cfg, batch))
    return jnp.stack(costs)


def make_transformer_probe_fn(cfg: ArchConfig):
    """Bind ``cfg`` → probe_fn(params, batch, probe) for build_mgd_step."""

    def probe_fn(params, batch, probe):
        return model_probe_costs(params, cfg, batch, probe)

    return probe_fn


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int):
    dtype = cfg.jdtype
    if cfg.family == "ssm":
        st = jax.vmap(lambda _: rwkv6_state_init(cfg, batch_size),
                      axis_size=cfg.n_layers, out_axes=0)(
            jnp.arange(cfg.n_layers))
        return {"state": st, "length": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        n_mamba, n_groups = _hybrid_plan(cfg)
        st = jax.vmap(lambda _: mamba2_state_init(cfg, batch_size),
                      axis_size=n_mamba, out_axes=0)(jnp.arange(n_mamba))
        kvh, dh = cfg.kv_heads, cfg.head_dim
        kv = jnp.zeros((n_groups, batch_size, max_len, kvh, dh), dtype)
        kv = shard(kv, None, "batch", "kvseq", None, None)
        return {"state": st, "k": kv, "v": kv,
                "length": jnp.zeros((), jnp.int32)}
    if cfg.use_mla:
        c = jnp.zeros((cfg.n_layers, batch_size, max_len, cfg.kv_lora_rank),
                      dtype)
        r = jnp.zeros((cfg.n_layers, batch_size, max_len,
                       cfg.qk_rope_head_dim), dtype)
        return {"c_kv": shard(c, None, "batch", "kvseq", None),
                "k_rope": shard(r, None, "batch", "kvseq", None),
                "length": jnp.zeros((), jnp.int32)}
    kvh, dh = cfg.kv_heads, cfg.head_dim
    kv = jnp.zeros((cfg.n_layers, batch_size, max_len, kvh, dh), dtype)
    kv = shard(kv, None, "batch", "kvseq", None, None)
    return {"k": kv, "v": kv, "length": jnp.zeros((), jnp.int32)}


def model_prefill(params, cfg: ArchConfig, batch, max_len: int):
    """Run the prompt; returns (full-seq logits, ready-to-decode cache)."""
    b = (batch["tokens"].shape[0] if "tokens" in batch
         else batch["embeds"].shape[0])
    s = (batch["tokens"].shape[-1] if "tokens" in batch
         else batch["embeds"].shape[1])
    logits, st = model_forward(params, cfg, batch, return_state=True)
    length = jnp.asarray(s, jnp.int32)
    if cfg.family == "ssm":
        return logits, {"state": st, "length": length}
    if cfg.family == "hybrid":
        cache = init_cache(cfg, b, max_len)
        kvs = st["attn_kv"]  # ([G,B,S,kvh,dh], [G,B,S,kvh,dh])
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], kvs[0].astype(cache["k"].dtype), 0, 2)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], kvs[1].astype(cache["v"].dtype), 0, 2)
        return logits, {"state": st["mamba"], "k": k, "v": v,
                        "length": length}
    cache = init_cache(cfg, b, max_len)
    if cfg.use_mla:
        c = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], st[0].astype(cache["c_kv"].dtype), 0, 2)
        r = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], st[1].astype(cache["k_rope"].dtype), 0, 2)
        return logits, {"c_kv": c, "k_rope": r, "length": length}
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], st[0].astype(cache["k"].dtype), 0, 2)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], st[1].astype(cache["v"].dtype), 0, 2)
    return logits, {"k": k, "v": v, "length": length}


def model_decode(params, cfg: ArchConfig, tokens, cache, embeds=None):
    """One decode step.  tokens: [B] int32 (or embeds [B,1,d] for stub
    frontends).  Returns (logits [B,V...], new cache)."""
    if embeds is not None:
        x1 = embeds
    elif cfg.n_codebooks:
        offs = (jnp.arange(cfg.n_codebooks, dtype=tokens.dtype)
                * cfg.vocab)[None, :]
        x1 = embed(params["embed"]["tok"], tokens + offs).sum(axis=1)[:, None, :]
    else:
        x1 = embed(params["embed"]["tok"], tokens)[:, None, :]
    b = x1.shape[0]
    length = cache["length"] + 1
    pos = jnp.full((b, 1), length - 1, jnp.int32)
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[..., None], (b, 1, 3))

    if cfg.family == "ssm":
        def body(x, layer):
            lp, st = layer
            y, st = rwkv6_block_step(lp, x[:, 0, :], st, cfg)
            return y[:, None, :], st

        x1, new_state = jax.lax.scan(body, x1, (params["layers"],
                                                cache["state"]))
        new_cache = {"state": new_state, "length": length}
    elif cfg.family == "hybrid":
        n_mamba, n_groups = _hybrid_plan(cfg)
        k = cfg.attn_every
        lp_g = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups, k, *a.shape[1:]), params["layers"])
        st_g = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups, k, *a.shape[1:]), cache["state"])

        def body(x, layer):
            lps, sts, kc, vc = layer

            def inner(x, one):
                lp, st = one
                y, st = mamba2_block_step(lp, x[:, 0, :], st, cfg)
                return y[:, None, :], st

            x, new_sts = jax.lax.scan(inner, x, (lps, sts))
            x, (kc, vc) = block_decode(
                params["shared_attn"], x, pos, (kc, vc), length, cfg)
            return x, (new_sts, kc, vc)

        x1, (new_m, kc, vc) = jax.lax.scan(
            body, x1, (lp_g, st_g, cache["k"], cache["v"]))
        new_cache = {
            "state": jax.tree_util.tree_map(
                lambda a: a.reshape(n_mamba, *a.shape[2:]), new_m),
            "k": kc, "v": vc, "length": length,
        }
    elif cfg.use_mla:
        def body(x, layer):
            lp, cc, rr = layer
            x, (cc, rr) = block_decode(lp, x, pos, (cc, rr), length, cfg)
            return x, (cc, rr)

        x1, (c, r) = jax.lax.scan(
            body, x1, (params["layers"], cache["c_kv"], cache["k_rope"]))
        new_cache = {"c_kv": c, "k_rope": r, "length": length}
    else:
        def body(x, layer):
            lp, kc, vc = layer
            x, (kc, vc) = block_decode(lp, x, pos, (kc, vc), length, cfg)
            return x, (kc, vc)

        x1, (kc, vc) = jax.lax.scan(
            body, x1, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": kc, "v": vc, "length": length}

    x1 = rmsnorm(params["embed"]["ln_f"], x1, cfg.norm_eps)
    logits = _logits(params["embed"], cfg, x1)[:, 0]
    return logits, new_cache
