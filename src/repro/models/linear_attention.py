"""Chunked linear attention with decaying state — the shared recurrence
behind RWKV-6 (per-channel data-dependent decay) and Mamba-2 SSD (per-head
scalar decay).

Recurrence (per head; state S ∈ R^{dk×dv}):

    S_t = diag(w_t)·S_{t−1} + k_tᵀ v_t
    y_t = q_t·S_{t−1} + (q_t ⊙ u ⊙ k_t)·v_t          (u-bonus: RWKV only)

Chunked evaluation processes blocks of L tokens with matmuls:
  * cross-chunk:  y⁺_t = (q_t ⊙ exp(A_{t−1})) @ S_in,   A = cumsum(log w)
  * state update: S_out = diag(exp(A_L))·S_in + Σ_s (exp(A_L−A_s) ⊙ k_s)ᵀ v_s
  * intra-chunk:  scores[t,s] = Σ_c q_tc·k_sc·exp(A_{t−1,c} − A_{s,c}),  s<t

Numerical stability: every exp() argument here is ≤ 0 — A is a cumsum of
log-decays (negative) and the intra-chunk pairwise differences are masked to
the causal region *before* exponentiation, where A_{t−1} ≤ A_s.  This makes
the chunked form unconditionally overflow-free, unlike the common
q·exp(A) / k·exp(−A) factorization (the per-factor exp(−A_s) overflows under
strong decay).  The cost is the [L,L,dk] pairwise tensor, so L stays small
(default 32); the recurrence is <2% of layer FLOPs at LM scale, projections
dominate.

All functions are vmapped over [B, H] leading dims.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


def _pad_t(x, pad):
    """Right-pad the time axis (axis 1) with zeros."""
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths)


def chunked_vector_decay(
    q,          # [B, S, H, dk]
    k,          # [B, S, H, dk]
    v,          # [B, S, H, dv]
    log_w,      # [B, S, H, dk]  log-decay per channel (≤ 0)
    u=None,     # [H, dk] bonus (RWKV time_faaaa) or None
    s0=None,    # [B, H, dk, dv] initial state
    chunk: int = 32,
):
    """Returns (y [B,S,H,dv], s_final [B,H,dk,dv]).  f32 internally."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        # right-pad to a chunk multiple: log_w = 0 (decay 1) and k = 0 keep
        # the carried state exact through the padding; pad outputs dropped.
        pad = chunk - s % chunk
        y, s_fin = chunked_vector_decay(
            _pad_t(q, pad), _pad_t(k, pad), _pad_t(v, pad),
            _pad_t(log_w, pad), u, s0=s0, chunk=chunk)
        return y[:, :s], s_fin
    n = s // chunk
    f32 = jnp.float32

    def to_chunks(x):  # [B,S,H,*] → [n, B, H, L, *]
        return jnp.moveaxis(
            x.reshape(b, n, chunk, h, -1), (1, 3), (0, 2)).astype(f32)

    qc, kc, vc, wc = to_chunks(q), to_chunks(k), to_chunks(v), to_chunks(log_w)
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), f32)
    else:
        s0 = s0.astype(f32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strict lower

    def per_chunk(state, xs):
        qb, kb, vb, wb = xs                   # [B,H,L,*]
        a = jnp.cumsum(wb, axis=2)            # A_t (inclusive)        [B,H,L,dk]
        a_prev = a - wb                       # A_{t−1}
        # cross-chunk
        y_cross = jnp.einsum("bhlc,bhcv->bhlv", qb * jnp.exp(a_prev), state)
        # intra-chunk: pairwise decay differences, masked before exp
        diff = a_prev[:, :, :, None, :] - a[:, :, None, :, :]  # [B,H,t,s,c]
        diff = jnp.where(tri[None, None, :, :, None], diff, -jnp.inf)
        scores = jnp.einsum("bhtc,bhsc,bhtsc->bhts", qb, kb, jnp.exp(diff))
        if u is not None:
            diag = jnp.einsum("bhlc,hc,bhlc->bhl", qb, u.astype(f32), kb)
            scores = scores + diag[..., None] * jnp.eye(chunk, dtype=f32)
        y_intra = jnp.einsum("bhts,bhsv->bhtv", scores, vb)
        # state update (all exp args ≤ 0)
        a_last = a[:, :, -1:, :]                                # [B,H,1,dk]
        k_hat = kb * jnp.exp(a_last - a)
        state = (jnp.exp(a_last[:, :, 0, :, None]) * state
                 + jnp.einsum("bhlc,bhlv->bhcv", k_hat, vb))
        return state, y_cross + y_intra

    s_final, ys = jax.lax.scan(per_chunk, s0, (qc, kc, vc, wc))
    y = jnp.moveaxis(ys, (0, 2), (1, 3)).reshape(b, s, h, dv)
    return y.astype(q.dtype), s_final


def chunked_scalar_decay(
    q,          # [B, S, H, dk]   (Mamba-2: C)
    k,          # [B, S, H, dk]   (Mamba-2: B)
    v,          # [B, S, H, dv]   (Mamba-2: Δ·x)
    log_a,      # [B, S, H]       log-decay per head (≤ 0)
    s0=None,    # [B, H, dk, dv]
    chunk: int = 64,
):
    """Scalar-decay variant: decay matrices are [L,L] per head, scores are a
    plain matmul — cheaper than the per-channel pairwise tensor."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        pad = chunk - s % chunk
        y, s_fin = chunked_scalar_decay(
            _pad_t(q, pad), _pad_t(k, pad), _pad_t(v, pad),
            _pad_t(log_a, pad), s0=s0, chunk=chunk)
        return y[:, :s], s_fin
    n = s // chunk
    f32 = jnp.float32

    def to_chunks(x):
        return jnp.moveaxis(
            x.reshape(b, n, chunk, h, -1), (1, 3), (0, 2)).astype(f32)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    ac = jnp.moveaxis(log_a.reshape(b, n, chunk, h), (1, 3), (0, 2)).astype(f32)
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), f32)
    else:
        s0 = s0.astype(f32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))  # include diagonal (SSD)

    def per_chunk(state, xs):
        # SSD semantics: y_t reads the *new* state h_t = a_t·h_{t−1} + k_t v_t,
        # so every decay exponent uses the INCLUSIVE cumsum A_t:
        #   cross:  exp(A_t)·h_in ;  intra (s ≤ t): exp(A_t − A_s)  (=1 at s=t)
        qb, kb, vb, ab = xs                    # ab: [B,H,L]
        a = jnp.cumsum(ab, axis=2)             # A_t inclusive
        y_cross = jnp.einsum(
            "bhlc,bhcv->bhlv", qb * jnp.exp(a)[..., None], state)
        diff = a[:, :, :, None] - a[:, :, None, :]            # [B,H,t,s]
        diff = jnp.where(tri[None, None], diff, -jnp.inf)
        scores = jnp.einsum("bhtc,bhsc->bhts", qb, kb) * jnp.exp(diff)
        y_intra = jnp.einsum("bhts,bhsv->bhtv", scores, vb)
        a_last = a[:, :, -1]                                   # [B,H]
        k_hat = kb * jnp.exp(a_last[:, :, None] - a)[..., None]
        state = (jnp.exp(a_last)[:, :, None, None] * state
                 + jnp.einsum("bhlc,bhlv->bhcv", k_hat, vb))
        return state, y_cross + y_intra

    s_final, ys = jax.lax.scan(per_chunk, s0, (qc, kc, vc, ac))
    y = jnp.moveaxis(ys, (0, 2), (1, 3)).reshape(b, s, h, dv)
    return y.astype(q.dtype), s_final


# --- single-token recurrent steps (decode) ---------------------------------


def step_vector_decay(q1, k1, v1, log_w1, u, state):
    """One token.  q1/k1/log_w1: [B,H,dk], v1: [B,H,dv], state [B,H,dk,dv].
    RWKV-6 order: y uses S_{t−1} plus the u-bonus for the current token."""
    f32 = jnp.float32
    q1, k1, v1 = q1.astype(f32), k1.astype(f32), v1.astype(f32)
    y = jnp.einsum("bhc,bhcv->bhv", q1, state)
    if u is not None:
        bonus = jnp.einsum("bhc,hc,bhc->bh", q1, u.astype(f32), k1)
        y = y + bonus[..., None] * v1
    state = (jnp.exp(log_w1.astype(f32))[..., None] * state
             + k1[..., None] * v1[..., None, :])
    return y, state


def step_scalar_decay(q1, k1, v1, log_a1, state):
    """One token, Mamba-2 SSD semantics: state updates first (decay applies
    to the previous state), y reads the NEW state.
    log_a1: [B,H]."""
    f32 = jnp.float32
    q1, k1, v1 = q1.astype(f32), k1.astype(f32), v1.astype(f32)
    state = (jnp.exp(log_a1.astype(f32))[..., None, None] * state
             + k1[..., None] * v1[..., None, :])
    y = jnp.einsum("bhc,bhcv->bhv", q1, state)
    return y, state
