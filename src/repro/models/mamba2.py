"""Mamba-2 block (SSD — state-space duality), used by the zamba2 hybrid.

Structure per block: in_proj → (z, xBC, dt); causal depthwise conv over xBC;
SSD recurrence with per-head scalar decay a_t = exp(−Δ_t·exp(A_log)); skip
D·x; gated RMSNorm (y·silu(z)); out_proj.  n_groups = 1 (B/C shared across
heads).  State per layer: conv tail [B, K−1, conv_dim] + SSD state
[B, H, N, P] — O(1) in sequence length (zamba2 runs long_500k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense, dense_init, rmsnorm, rmsnorm_init
from .linear_attention import chunked_scalar_decay, step_scalar_decay

CONV_K = 4


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    head_p = cfg.ssm_head_dim
    n_heads = d_inner // head_p
    n_state = cfg.ssm_state
    conv_dim = d_inner + 2 * n_state
    return d_inner, head_p, n_heads, n_state, conv_dim


def mamba2_block_init(key, cfg, dtype):
    d = cfg.d_model
    d_inner, head_p, n_heads, n_state, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "norm_in": rmsnorm_init(d, dtype),
        "in_proj": dense_init(
            ks[0], d, 2 * d_inner + 2 * n_state + n_heads, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, conv_dim), jnp.float32)
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),        # A = −exp(a_log)
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_gate": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(ks[2], d_inner, d, dtype=dtype),
    }


def mamba2_state_init(cfg, batch, dtype=jnp.float32):
    d_inner, head_p, n_heads, n_state, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, n_heads, n_state, head_p), jnp.float32),
    }


def _causal_conv(x, w, b, tail):
    """Depthwise causal conv1d.  x: [B,S,C]; tail: [B,K−1,C] history.
    Returns (y [B,S,C], new_tail)."""
    kk, c = w.shape
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    # grouped 1-D conv: kernel [K, I/groups=1, O=C], groups = C (depthwise)
    y = jax.lax.conv_general_dilated(
        xp, w.astype(x.dtype)[:, None, :], (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    return y + b.astype(x.dtype), xp[:, -(kk - 1):, :]


def _split_proj(p, x, cfg):
    d_inner, head_p, n_heads, n_state, conv_dim = _dims(cfg)
    zxbcdt = dense(p["in_proj"], x)
    return jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)


def mamba2_block(p, x, state, cfg, *, chunk=64):
    """x: [B,S,d] → (x + mixer(x), new_state)."""
    b, s, d = x.shape
    d_inner, head_p, n_heads, n_state, conv_dim = _dims(cfg)
    xn = rmsnorm(p["norm_in"], x)
    z, xbc, dt = _split_proj(p, xn, cfg)
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], state["conv"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    x_ssm, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n_state], axis=-1)
    x_ssm = x_ssm.reshape(b, s, n_heads, head_p)
    bmat = jnp.broadcast_to(bmat[:, :, None, :], (b, s, n_heads, n_state))
    cmat = jnp.broadcast_to(cmat[:, :, None, :], (b, s, n_heads, n_state))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B,S,H]
    log_a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt      # ≤ 0
    v = x_ssm.astype(jnp.float32) * dt[..., None]
    y, ssd = chunked_scalar_decay(cmat, bmat, v.astype(x.dtype), log_a,
                                  s0=state["ssd"], chunk=chunk)
    y = (y.astype(jnp.float32)
         + p["d_skip"].astype(jnp.float32)[None, None, :, None]
         * x_ssm.astype(jnp.float32))
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm_gate"],
                y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = dense(p["out_proj"], y)
    return x + out, {"conv": conv_tail, "ssd": ssd}


def mamba2_block_step(p, x1, state, cfg):
    """Single-token decode.  x1: [B,d]."""
    b, d = x1.shape
    d_inner, head_p, n_heads, n_state, conv_dim = _dims(cfg)
    xn = rmsnorm(p["norm_in"], x1)
    z, xbc, dt = _split_proj(p, xn[:, None, :], cfg)
    z, xbc, dt = z[:, 0], xbc[:, 0], dt[:, 0]
    # conv over (tail ++ this token)
    window = jnp.concatenate(
        [state["conv"].astype(xbc.dtype), xbc[:, None, :]], axis=1)
    y_conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(xbc.dtype))
    xbc = jax.nn.silu((y_conv + p["conv_b"].astype(xbc.dtype))
                      .astype(jnp.float32)).astype(x1.dtype)
    x_ssm, bvec, cvec = jnp.split(xbc, [d_inner, d_inner + n_state], axis=-1)
    x_ssm = x_ssm.reshape(b, n_heads, head_p)
    bvec = jnp.broadcast_to(bvec[:, None, :], (b, n_heads, n_state))
    cvec = jnp.broadcast_to(cvec[:, None, :], (b, n_heads, n_state))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    log_a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt      # [B,H]
    v = x_ssm.astype(jnp.float32) * dt[..., None]
    y, ssd = step_scalar_decay(cvec, bvec, v.astype(x1.dtype), log_a,
                               state["ssd"])
    y = (y + p["d_skip"].astype(jnp.float32)[None, :, None]
         * x_ssm.astype(jnp.float32))
    y = y.reshape(b, d_inner).astype(x1.dtype)
    y = rmsnorm(p["norm_gate"],
                y * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype))
    out = dense(p["out_proj"], y)
    return x1 + out, {"conv": window[:, 1:, :], "ssd": ssd}
