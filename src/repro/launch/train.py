"""Training entry point: ``python -m repro.launch.train --arch qwen3-14b
--smoke --steps 200``.

Trains an assigned architecture with MGD (or the backprop baseline) on the
synthetic LM stream.  ``--smoke`` selects the reduced config (CPU-runnable);
the full configs are exercised via the dry-run (launch/dryrun.py).
Checkpoints are atomic and resumable (--ckpt-dir); a killed run restarted
with the same flags reproduces the exact trajectory.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.core import MGDConfig
from repro.data.pipeline import lm_sampler
from repro.models import model_init, model_loss
from repro.training.train_loop import (TrainLoopConfig, train_backprop,
                                       train_mgd)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--algo", default="mgd", choices=["mgd", "backprop"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--eta", type=float, default=None)
    ap.add_argument("--dtheta", type=float, default=1e-2)
    ap.add_argument("--tau-theta", type=int, default=1)
    ap.add_argument("--tau-x", type=int, default=1)
    ap.add_argument("--mode", default="central",
                    choices=["forward", "central"])
    ap.add_argument("--probes", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=20)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = model_init(cfg, jax.random.PRNGKey(args.seed))
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name} ({'smoke' if args.smoke else 'full'}): "
          f"{n/1e6:.2f}M params, algo={args.algo}")

    sample_fn = lm_sampler(args.batch, args.seq, cfg.vocab, seed=args.seed)
    loss_fn = lambda p, b: model_loss(p, cfg, b)      # noqa: E731

    if args.algo == "mgd":
        eta = args.eta if args.eta is not None else 1e-2
        mgd_cfg = MGDConfig(
            ptype="rademacher", dtheta=args.dtheta, eta=eta,
            tau_theta=args.tau_theta, tau_x=args.tau_x, mode=args.mode,
            probes=args.probes, seed=args.seed)
        res = train_mgd(loss_fn, params, mgd_cfg, sample_fn, args.steps,
                        loop=TrainLoopConfig(
                            chunk=args.chunk, checkpoint_dir=args.ckpt_dir,
                            checkpoint_every=args.ckpt_every))
    else:
        eta = args.eta if args.eta is not None else 0.3
        res = train_backprop(loss_fn, params, sample_fn, args.steps,
                             eta=eta, chunk=args.chunk)
    first = res.history[0][1]["cost"]
    last = res.history[-1][1]["cost"]
    print(f"[train] done: cost {first:.4f} → {last:.4f} "
          f"over {res.steps_done} steps")


if __name__ == "__main__":
    main()
