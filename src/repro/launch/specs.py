"""Sharding rules + abstract input specs for every (arch × shape) cell.

``PARAM_RULES`` is the single ordered rule table translating parameter-tree
paths to logical axis names (right-aligned; see distributed/sharding).
``input_specs`` builds ShapeDtypeStruct stand-ins for the dry-run — weak-
type-correct, shardable, zero allocation.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.distributed import sharding as shd
from repro.models import ArchConfig, init_cache, model_init


# Ordered: first match wins.  "fsdp" resolves to nothing unless cfg.fsdp.
def param_rules(cfg: ArchConfig):
    fsdp = "fsdp" if cfg.fsdp else None
    rules = [
        # embeddings / head
        (r"embed/tok/table$", ("model", None)),          # vocab-sharded
        (r"embed/head/w$", (fsdp, "model")),
        # MoE
        (r"moe/router/w$", (None, "expert")),
        (r"moe/shared/(gate|up)/w$", (fsdp, "model")),
        (r"moe/shared/down/w$", ("model", fsdp)),
        (r"moe/(gate|up)$", ("expert", fsdp, None)),     # [E, d, f] banks
        (r"moe/down$", ("expert", None, fsdp)),          # [E, f, d]
        # dense MLP
        (r"mlp/(gate|up)/w$", (fsdp, "model")),
        (r"mlp/down/w$", ("model", fsdp)),
        # rwkv6 channel-mix (before the generic wk/wv rules)
        (r"ffn/wk/w$", (fsdp, "model")),
        (r"ffn/wv/w$", ("model", fsdp)),
        (r"ffn/wr/w$", (fsdp, "model")),
        # attention / rwkv time-mix / MLA projections
        (r"(wq|wk|wv|wg|wr|wq_b|wkv_b)/w$", (fsdp, "model")),
        (r"(wq_a|wkv_a|in_proj)/w$", (fsdp, "model")),
        (r"(wo|out_proj)/w$", ("model", fsdp)),
        (r"(wq|wk|wv|in_proj)/b$", ("model",)),
        # rwkv decay LoRA / bonus
        (r"w_lora_a$", (fsdp, None)),
        (r"w_lora_b$", (None, "model")),
        (r"att/u$", ("model", None)),
        (r"att/w0$", ("model",)),
        # mamba2 scalars / conv
        (r"(a_log|d_skip|dt_bias)$", ("model",)),
        (r"conv_w$", (None, "model")),
        (r"conv_b$", ("model",)),
        (r"norm_gate/scale$", ("model",)),
    ]
    return [(pat, names) for pat, names in rules]


def abstract_params(cfg: ArchConfig):
    """Parameter ShapeDtypeStructs without allocating anything."""
    return jax.eval_shape(
        functools.partial(model_init, cfg), jax.random.PRNGKey(0))


def param_shardings(cfg: ArchConfig, mesh):
    specs = shd.param_specs(abstract_params(cfg), param_rules(cfg), mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# Input specs per (arch × shape)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    if cfg.family in ("vlm", "audio"):
        batch = {"embeds": _sds((b, s, cfg.d_model), cfg.jdtype)}
        if cfg.n_codebooks:
            batch["labels"] = _sds((b, s, cfg.n_codebooks), jnp.int32)
        else:
            batch["labels"] = _sds((b, s), jnp.int32)
        if cfg.mrope_sections:
            batch["positions"] = _sds((b, s, 3), jnp.int32)
        return batch
    return {"tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32)}


def prefill_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    batch = train_input_specs(cfg, shape)
    batch.pop("labels", None)
    return batch


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh=None):
    """(token inputs, abstract cache at the shape's seq_len)."""
    b, s = shape.global_batch, shape.seq_len
    with shd.use_mesh(mesh):
        cache = jax.eval_shape(
            functools.partial(init_cache, cfg, b, s))
    if cfg.family in ("vlm", "audio"):
        tok = {"embeds": _sds((b, 1, cfg.d_model), cfg.jdtype)}
    elif cfg.n_codebooks:
        tok = {"tokens": _sds((b, cfg.n_codebooks), jnp.int32)}
    else:
        tok = {"tokens": _sds((b,), jnp.int32)}
    return tok, cache


def batch_shardings(batch_specs, mesh):
    """NamedShardings for a train/prefill batch: leading dim → "batch"."""

    def one(x):
        return NamedSharding(mesh, shd.logical_spec(x.shape, ["batch"], mesh))

    return jax.tree_util.tree_map(one, batch_specs)


def cache_shardings(cfg: ArchConfig, cache_specs, mesh):
    """NamedShardings for a decode cache.

    KV caches: [L, B, S, KVH, D] → (None, batch, seq, model, None); SSM
    states: [L, B, H, ...] → (None, batch, model, ...); scalars replicated.
    The logical translator drops non-dividing/duplicate axes (B=1 long-
    context → sequence-sharded cache).
    """

    def one(path, x):
        names = ["batch"]
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        if pstr.endswith(("wkv", "ssd")) and x.ndim >= 4:
            spec = shd.logical_spec(x.shape, [None, "batch", "model"], mesh)
        elif pstr.endswith(("k", "v", "c_kv", "k_rope")) and x.ndim >= 3:
            spec = shd.logical_spec(
                x.shape, [None, "batch", "kvseq"], mesh)
        elif x.ndim >= 2:
            spec = shd.logical_spec(x.shape, [None, "batch"], mesh)
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_specs)
