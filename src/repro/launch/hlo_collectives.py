"""Collective-byte accounting from compiled (SPMD-partitioned) HLO text.

``compiled.as_text()`` is the per-device program after GSPMD partitioning —
the ground truth for what crosses the interconnect.  Two subtleties:

1. Collectives inside a while body (layer scan) appear ONCE in the text but
   execute trip-count times.  We parse each ``while`` instruction's
   ``condition=`` computation, extract its loop-bound constant, and
   propagate multipliers down nested loops.
2. Bytes-on-the-wire per chip per collective, ring algorithms on n shards:
       all-gather:        out_bytes · (n−1)/n        (recv side)
       reduce-scatter:    in_bytes  · (n−1)/n
       all-reduce:        2 · bytes · (n−1)/n        (RS + AG)
       all-to-all:        bytes · (n−1)/n
       collective-permute: bytes
   We conservatively use the (n−1)/n ≈ 1 limit and report
   Σ type_multiplier · shape_bytes, with per-op detail kept for §Perf.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8,
}

COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^=]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

TYPE_MULT = {
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-reduce": 2.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _computations(text: str) -> Dict[str, str]:
    """Split the module text into named computation bodies.

    Computation headers start at column 0 and end with "{" (instruction
    lines are indented); the name is the first %-token.  Tuple-typed
    headers contain ``/*index=N*/`` comments and nested parens, so no
    fancier parsing is reliable.
    """
    comps = {}
    cur_name, cur_lines = None, []
    for line in text.splitlines():
        if (line and not line[0].isspace() and line.rstrip().endswith("{")
                and ("%" in line or line.startswith("ENTRY"))):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            m = re.search(r"%([\w\.\-]+)", line)
            cur_name = m.group(1) if m else line.split()[0]
            if line.startswith("ENTRY"):
                cur_name = "ENTRY " + cur_name
            cur_lines = []
        elif line.strip() == "}":
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
                cur_lines = []
        elif cur_name is not None:
            cur_lines.append(line)
    return comps


_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(cond_text: str):
    """Loop bound from the condition computation (compare against const)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else None


def collective_bytes(hlo_text: str, default_trip: int = 1) -> Dict:
    """Returns {"total_bytes", "by_type", "ops": [...]}.

    Bytes are per-device wire bytes per step, loop-multiplied.  Collectives
    in loops whose bound can't be parsed get ``default_trip`` and are
    flagged.
    """
    comps = _computations(hlo_text)
    # multiplier per computation, starting from entry (= main)
    entry = None
    for name in comps:
        if name.startswith("ENTRY"):
            entry = name
            break
    if entry is None:
        for name in comps:
            if "main" in name:
                entry = name
                break
    if entry is None and comps:
        entry = next(iter(comps))

    # typed call edges: (caller, callee, factor); while bodies carry their
    # parsed trip count, everything else ×1
    edges = []
    for name, body_text in comps.items():
        for m in _WHILE_RE.finditer(body_text):
            cond, body = m.group(1), m.group(2)
            tc = _trip_count(comps.get(cond, ""))
            edges.append((name, body, float(tc if tc is not None
                                            else default_trip)))
            edges.append((name, cond, 1.0))
        for call in re.finditer(
                r"(?:calls|to_apply|called_computations|branch_computations"
                r"|true_computation|false_computation)="
                r"(\{[^}]*\}|%?[\w\.\-]+)", body_text):
            blob = call.group(1)
            for nm in re.findall(r"%?([\w\.\-]+)", blob):
                if nm in comps and nm != name:
                    edges.append((name, nm, 1.0))

    # relax the DAG: propagate multipliers from entry until fixed point
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    for _ in range(len(comps)):
        changed = False
        acc = defaultdict(float)
        acc[entry] = 1.0
        for caller, callee, factor in edges:
            if mult.get(caller, 0.0):
                acc[callee] += mult[caller] * factor
        for k, v in acc.items():
            if abs(mult.get(k, 0.0) - v) > 1e-9:
                changed = True
        if not changed:
            break
        new = defaultdict(float, acc)
        new[entry] = 1.0
        mult = new

    by_type = defaultdict(float)
    ops: List[dict] = []
    total = 0.0
    for name, body_text in comps.items():
        m_factor = mult.get(name, 0.0)
        if m_factor == 0.0:
            continue
        for cm in COLLECTIVE_RE.finditer(body_text):
            type_str, op = cm.group(1), cm.group(2)
            raw = _shape_bytes(type_str)
            wire = raw * TYPE_MULT[op] * m_factor
            by_type[op] += wire
            total += wire
            ops.append({"op": op, "bytes": raw, "mult": m_factor,
                        "comp": name})
    return {"total_bytes": total, "by_type": dict(by_type), "ops": ops}
