"""Scan-aware cost analysis on jaxprs.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE regardless
of trip count (verified empirically — a scan of length 8 reports the same
flops as length 1), which silently undercounts every scanned layer stack,
attention block loop, and SSM chunk scan.  This walker computes costs on
the CLOSED JAXPR instead, where ``scan`` carries an explicit ``length`` to
multiply by, recursing through scan/while/cond/pjit/remat.

Accounting (global, logical — pre-partitioning):
* flops: dot_general = 2·batch·M·N·K; conv = 2·spatial·window·Cin·Cout·B.
  Elementwise/reduction ops are ignored (≪ matmul terms at LM scale).
* bytes: for every counted op, operand + result bytes (a streaming
  roofline estimate of HBM traffic: weights read once per use, activations
  read+written around each matmul).  Fusion can beat this; gathers/norms
  add to it — treat as a ±2× estimate and say so in §Roofline.
* while: body cost × (statically inferrable trip count if the loop was a
  ``fori``; else 1 and a warning flag).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np

DTYPE_BYTES = {
    "float32": 4, "float64": 8, "bfloat16": 2, "float16": 2,
    "int32": 4, "int64": 8, "int8": 1, "uint8": 1, "uint32": 4,
    "int16": 2, "uint16": 2, "bool": 1, "complex64": 8,
}


def _nbytes(aval) -> int:
    try:
        size = math.prod(aval.shape)
        return size * DTYPE_BYTES.get(str(aval.dtype), 4)
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(lhs.shape[i] for i in range(len(lhs.shape))
                  if i not in set(lc) | set(lb))
    n = math.prod(rhs.shape[i] for i in range(len(rhs.shape))
                  if i not in set(rc) | set(rb))
    return 2 * batch * m * n * contract


def _conv_flops(eqn) -> int:
    # kernel is HWIO-ish: [spatial..., I/groups, O]; every output element
    # contracts spatial × I/groups inputs.
    rhs = eqn.invars[1].aval
    out = eqn.outvars[0].aval
    return 2 * math.prod(out.shape) * math.prod(rhs.shape[:-1])


def jaxpr_cost(closed_jaxpr) -> Dict[str, Any]:
    """Returns {"flops": int, "bytes": int, "unknown_while": int}."""
    return _walk(closed_jaxpr.jaxpr)


def _walk(jaxpr) -> Dict[str, Any]:
    total = {"flops": 0, "bytes": 0, "unknown_while": 0}
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total["flops"] += _dot_flops(eqn)
            total["bytes"] += sum(_nbytes(v.aval) for v in eqn.invars)
            total["bytes"] += sum(_nbytes(v.aval) for v in eqn.outvars)
        elif prim == "conv_general_dilated":
            total["flops"] += _conv_flops(eqn)
            total["bytes"] += sum(_nbytes(v.aval) for v in eqn.invars)
            total["bytes"] += sum(_nbytes(v.aval) for v in eqn.outvars)
        elif prim in ("gather", "take", "dynamic_slice",
                      "dynamic_update_slice", "scatter", "scatter-add",
                      "scatter_add"):
            # cache updates / embedding lookups: result traffic only
            total["bytes"] += sum(_nbytes(v.aval) for v in eqn.outvars)
        elif prim == "scan":
            inner = _walk(eqn.params["jaxpr"].jaxpr)
            n = eqn.params["length"]
            for k in ("flops", "bytes"):
                total[k] += n * inner[k]
            total["unknown_while"] += inner["unknown_while"]
        elif prim == "while":
            inner = _walk(eqn.params["body_jaxpr"].jaxpr)
            n = _fori_trip_count(eqn)
            if n is None:
                n = 1
                total["unknown_while"] += 1
            for k in ("flops", "bytes"):
                total[k] += n * inner[k]
        elif prim == "cond":
            branches = [_walk(b.jaxpr) for b in eqn.params["branches"]]
            # conservative: the most expensive branch
            total["flops"] += max(b["flops"] for b in branches)
            total["bytes"] += max(b["bytes"] for b in branches)
            total["unknown_while"] += sum(b["unknown_while"] for b in branches)
        elif prim in ("pjit", "closed_call", "core_call", "remat_call",
                      "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "checkpoint", "remat"):
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                inner = _walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
                for k in total:
                    total[k] += inner[k]
        elif prim == "custom_jvp_call_jaxpr":
            inner = _walk(eqn.params["fun_jaxpr"].jaxpr)
            for k in total:
                total[k] += inner[k]
    return total


def _fori_trip_count(eqn):
    """fori_loop-shaped while: carry[0] is the counter, cond is i < C with
    both bounds constant-folded into the carry init.  Not recoverable from
    the jaxpr alone in general — return None (callers avoid bare whiles on
    dry-run paths; every loop we emit is a scan)."""
    return None


def abstract_cost(fn, *args, **kwargs) -> Dict[str, Any]:
    """Cost of ``fn(*args)`` traced abstractly (ShapeDtypeStructs ok)."""
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return jaxpr_cost(jaxpr)
