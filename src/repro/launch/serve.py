"""Serving entry point: ``python -m repro.launch.serve --arch rwkv6-7b
--smoke --batch 4 --max-new 32``.

Two modes:

* **Batch generation** (default) — prefill a batch of synthetic prompts
  and decode with the KV/SSM cache: the serve_step lowered by the decode
  dry-run cells, executed for real at smoke scale.
* **Online serving** (``--online-trim``) — stand up an
  :class:`repro.OnlineService` over the model's next-token head: live
  requests are batched into fixed decode slots, labeled feedback flows
  into the replay buffer, and a background MGD trimmer re-trims the
  weights through a (optionally drifting) plant, publishing fenced
  snapshot-consistent parameter swaps while traffic keeps flowing:

      python -m repro.launch.serve --arch qwen3-14b --smoke --online-trim
      python -m repro.launch.serve --arch qwen3-14b --smoke --online-trim \\
          --drift 0.002 --requests 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import model_forward, model_init, model_loss
from repro.serving import greedy_generate


def _serve_online(args, cfg, params):
    from repro.api import DriverConfig
    from repro.hardware import DriftingPlant, IdealPlant
    from repro.serving import ServiceConfig, TrimConfig
    from repro.serving import serve as make_service

    S = args.prompt_len

    def predict_fn(p, batch):
        # next-token logits for a fixed-length window — the decode slot
        return model_forward(p, cfg, {"tokens": batch["tokens"]})[:, -1, :]

    def loss_fn(p, batch):
        return model_loss(p, cfg, batch)

    plant = IdealPlant(loss_fn)
    if args.drift > 0:
        plant = DriftingPlant(plant, mode="walk", drift_rate=args.drift,
                              seed=args.seed + 41)

    trim = TrimConfig(
        DriverConfig(dtheta=args.dtheta, eta=args.eta, probes=args.probes,
                     mode="central", seed=args.seed),
        loss_fn, plant=plant)
    svc_cfg = ServiceConfig(slots=args.batch, batch_window_s=0.002,
                            replay_capacity=1024, trim_batch=args.batch,
                            min_fill=2 * args.batch,
                            publish_every=10, seed=args.seed)

    # a small synthetic "corpus": next token is deterministic given the
    # window, so re-trim measurably drives the served cost down
    corpus = np.asarray(jax.random.randint(
        jax.random.PRNGKey(args.seed + 1), (8, S + 1), 0, cfg.vocab))

    def corpus_cost(p):
        return float(np.mean([
            loss_fn(p, {"tokens": jnp.asarray(corpus[j:j + 1, :S]),
                        "labels": jnp.asarray(corpus[j:j + 1, 1:])})
            for j in range(len(corpus))]))

    # context entry starts the dispatcher AND the background trainer
    # thread — traffic and MGD re-trim genuinely overlap here
    with make_service(svc_cfg, predict_fn, params, trim=trim,
                      start=False) as svc:
        c0 = corpus_cost(svc.snapshot().params)
        t0 = time.time()
        rounds = max(args.requests // args.batch, 1)
        for r in range(rounds):
            futs = []
            for i in range(args.batch):
                j = (r * args.batch + i) % len(corpus)
                futs.append(svc.submit(
                    {"tokens": corpus[j, :S]},
                    feedback={"labels": corpus[j, 1:]}))
            for f in futs:
                f.result(60)
        deadline = time.time() + 120
        while (svc.stats()["trim_global_step"] < args.trim_steps
               and time.time() < deadline):
            time.sleep(0.02)
        svc.fence()
        svc.publish()
        stats = svc.stats()
        c1 = corpus_cost(svc.snapshot().params)
        dt = time.time() - t0
        print(f"[serve] {cfg.name}: online mode — {stats['served']} "
              f"requests, {stats['trim_global_step']} trim steps, "
              f"{stats['version']} param swaps in {dt:.1f}s")
        print(f"[serve]   latency p50={stats['latency_p50_ms']:.2f}ms "
              f"p99={stats['latency_p99_ms']:.2f}ms  "
              f"qps={stats['served'] / dt:.1f}")
        print(f"[serve]   served cost {c0:.4f} -> {c1:.4f} "
              f"({'improved' if c1 < c0 else 'no improvement'}"
              f"{', drifting plant' if args.drift > 0 else ''})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--online-trim", action="store_true",
                    help="serve through OnlineService with background "
                         "MGD re-trim from request feedback")
    ap.add_argument("--requests", type=int, default=64,
                    help="[online] total requests to serve")
    ap.add_argument("--trim-steps", type=int, default=200,
                    help="[online] total MGD trim steps")
    ap.add_argument("--drift", type=float, default=0.0,
                    help="[online] per-step drift walk std on the plant")
    ap.add_argument("--eta", type=float, default=2e-3)
    ap.add_argument("--dtheta", type=float, default=1e-3)
    ap.add_argument("--probes", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit(f"{args.arch}: stub-frontend arch — serve via "
                         "examples/serve_lm.py with embeddings")
    params = model_init(cfg, jax.random.PRNGKey(args.seed))

    if args.online_trim:
        _serve_online(args, cfg, params)
        return

    prompts = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1),
        (args.batch, args.prompt_len), 0, cfg.vocab)

    t0 = time.time()
    out = greedy_generate(params, cfg, prompts, args.max_new,
                          temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print("[serve] sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
