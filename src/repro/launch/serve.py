"""Serving entry point: ``python -m repro.launch.serve --arch rwkv6-7b
--smoke --batch 4 --max-new 32``.

Prefills a batch of synthetic prompts and decodes with the KV/SSM cache —
the serve_step lowered by the decode dry-run cells, executed for real at
smoke scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import model_init
from repro.serving import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit(f"{args.arch}: stub-frontend arch — serve via "
                         "examples/serve_lm.py with embeddings")
    params = model_init(cfg, jax.random.PRNGKey(args.seed))
    prompts = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1),
        (args.batch, args.prompt_len), 0, cfg.vocab)

    t0 = time.time()
    out = greedy_generate(params, cfg, prompts, args.max_new,
                          temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print("[serve] sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
