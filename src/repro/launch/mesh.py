"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required because the
dry-run overrides the host platform device count before first jax use.

Topology (TPU v5e-class pods):
    single-pod:  (16, 16)      axes ("data", "model")        — 256 chips
    multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

The "pod" axis is outer data parallelism by default; MGD re-purposes it as
the probe axis (core/probe_parallel.py) or a pipeline axis
(distributed/pipeline.py).
"""
from __future__ import annotations

import jax

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1-D "data" mesh (CPU tests/examples)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
