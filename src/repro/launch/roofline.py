"""Roofline analysis over dry-run artifacts.

Hardware model (TPU v5e-class, per chip):
    peak bf16 compute: 197 TFLOP/s
    HBM bandwidth:     819 GB/s
    ICI link bw:       ~50 GB/s  (per-link; scalar broadcast rides this)

Terms per (arch × shape × mesh) cell, per MGD step (or serve step):
    compute    = global_FLOPs / (chips × peak)
    memory     = global_bytes / (chips × HBM_bw)
    collective = per-device wire bytes / link_bw
                 (per-device HLO × chips / (chips × link_bw) — identical)

FLOPs/bytes are the scan-aware jaxpr costs (launch/jaxpr_cost.py) — XLA's
cost_analysis counts loop bodies once and is reported alongside for
reference only.  Bytes are a streaming estimate (dot/conv operands +
results): fusion can beat it, gathers can exceed it; treat as ±2×.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
from typing import Dict, List

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # bytes/s / chip
LINK_BW = 50e9              # bytes/s / link


def load_artifacts(art_dir: str) -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def roofline_terms(rec: dict) -> dict:
    chips = rec["chips"]
    t_compute = rec["jaxpr_flops"] / (chips * PEAK_FLOPS)
    t_memory = rec["jaxpr_bytes"] / (chips * HBM_BW)
    t_coll = rec["collective_bytes_per_device"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = rec["model_flops"]
    return {
        **terms,
        "dominant": dominant,
        "step_time_bound": bound,
        "model_flops": useful,
        "flops_ratio": useful / max(rec["jaxpr_flops"], 1),
        # achievable fraction of compute roofline if perfectly overlapped
        "roofline_fraction": t_compute / max(bound, 1e-30),
        "mfu_bound": useful / max(bound, 1e-30) / (chips * PEAK_FLOPS),
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(records: List[dict], *, multi_pod=False, tag="") -> str:
    rows = []
    hdr = ("| arch | shape | chips | compute | memory | collective | "
           "dominant | roofline frac | MFU bound | MODEL/HLO flops |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for r in records:
        if r["multi_pod"] != multi_pod or r.get("tag", "") != tag:
            continue
        t = roofline_terms(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} "
            f"| {fmt_s(t['compute'])} | {fmt_s(t['memory'])} "
            f"| {fmt_s(t['collective'])} | {t['dominant']} "
            f"| {t['roofline_fraction']*100:.1f}% "
            f"| {t['mfu_bound']*100:.2f}% "
            f"| {t['flops_ratio']*100:.1f}% |")
    return "\n".join(rows)


def memory_table(records: List[dict], *, multi_pod=False) -> str:
    rows = ["| arch | shape | args GiB/dev | temp GiB/dev | fits 16G? |",
            "|---|---|---|---|---|"]
    for r in records:
        if r["multi_pod"] != multi_pod or r.get("tag", ""):
            continue
        m = r["memory"]
        total = (m["argument_bytes"] + m["temp_bytes"]
                 + m["output_bytes"]) / 2**30
        args = m["argument_bytes"] / 2**30
        temp = m["temp_bytes"] / 2**30
        rows.append(f"| {r['arch']} | {r['shape']} | {args:.2f} "
                    f"| {temp:.2f} | {'YES' if total < 16 else 'NO'} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load_artifacts(args.artifacts)
    print(table(recs, multi_pod=args.multi_pod, tag=args.tag))
    print()
    print(memory_table(recs, multi_pod=args.multi_pod))


if __name__ == "__main__":
    main()
