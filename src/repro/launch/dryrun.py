"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the host-device override before ANY other import — jax locks the
device count on first init.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import functools         # noqa: E402
import json              # noqa: E402
import math              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.api import driver  # noqa: E402
from repro.configs import ARCH_IDS, SHAPES, get_config, runnable_cells  # noqa: E402
from repro.core import MGDConfig, mgd_init  # noqa: E402
from repro.core.mgd import MGDState  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import specs  # noqa: E402
from repro.launch.hlo_collectives import collective_bytes  # noqa: E402
from repro.launch.jaxpr_cost import jaxpr_cost  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import init_cache, model_decode, model_loss, model_prefill  # noqa: E402


def default_mgd_config(mode: str = "forward") -> MGDConfig:
    """Paper-faithful baseline: Algorithm 1, τ_p = τ_θ = τ_x = 1
    (C₀ refresh + perturbed forward = 2 forwards/step)."""
    return MGDConfig(ptype="rademacher", dtheta=1e-3, eta=1e-2,
                     tau_p=1, tau_theta=1, tau_x=1, mode=mode)


def count_params(aparams) -> int:
    return sum(int(math.prod(x.shape))
               for x in jax.tree_util.tree_leaves(aparams))


def active_params(cfg, aparams) -> int:
    n = count_params(aparams)
    if cfg.n_experts:
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_moe_layers = cfg.n_layers
        n -= n_moe_layers * (cfg.n_experts - cfg.n_experts_active) * per_expert
    return n


def model_flops(cfg, shape, kind: str, n_forwards: int) -> float:
    """Analytic useful FLOPs per step (the roofline's MODEL_FLOPS)."""
    aparams = specs.abstract_params(cfg)
    n_active = active_params(cfg, aparams)
    n_embed = cfg.vocab * max(cfg.n_codebooks, 1) * cfg.d_model
    n_mm = n_active - n_embed          # embedding lookup is a gather
    b, s = shape.global_batch, shape.seq_len
    if kind == "train" or kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_mm * tokens
        if cfg.family not in ("ssm",):
            # causal attention: 2 matmuls × 2 flops × S²/2 × heads·dh (+GQA)
            attn_layers = (cfg.n_layers if cfg.family != "hybrid"
                           else cfg.n_layers // (cfg.attn_every + 1))
            d_attn = cfg.n_heads * cfg.head_dim
            if cfg.use_mla:
                d_attn = cfg.n_heads * (cfg.qk_nope_head_dim
                                        + cfg.qk_rope_head_dim
                                        + cfg.v_head_dim) / 2
            flops += attn_layers * b * s * s * d_attn * 2.0  # ≈2·2·S²/2·d
    else:  # decode: one token per sequence
        tokens = b
        flops = 2.0 * n_mm * tokens
        if cfg.family not in ("ssm",):
            attn_layers = (cfg.n_layers if cfg.family != "hybrid"
                           else cfg.n_layers // (cfg.attn_every + 1))
            if cfg.use_mla:
                # absorbed decode: scores+values vs the r-dim latent cache
                d_attn = cfg.n_heads * (cfg.kv_lora_rank
                                        + cfg.qk_rope_head_dim)
            else:
                d_attn = cfg.n_heads * cfg.head_dim
            flops += attn_layers * b * s * d_attn * 2.0 * 2.0
    return flops * n_forwards


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------


def build_train(cfg, shape, mesh, mgd_mode="forward"):
    mgd_cfg = default_mgd_config(mgd_mode)
    loss_fn = lambda p, b: model_loss(p, cfg, b)          # noqa: E731
    step_fn = driver("discrete", mgd_cfg, loss_fn).step
    aparams = specs.abstract_params(cfg)
    astate = jax.eval_shape(functools.partial(mgd_init, cfg=mgd_cfg), aparams)
    abatch = specs.train_input_specs(cfg, shape)
    p_shard = specs.param_shardings(cfg, mesh)
    rep = NamedSharding(mesh, P())
    g_shard = None if astate.g is None else jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s.spec), p_shard)
    st_shard = MGDState(step=rep, c0=rep, g=g_shard, replay_c=None, m=None,
                        metric_cost=rep)
    b_shard = specs.batch_shardings(abatch, mesh)
    n_forwards = 2
    return (step_fn, (aparams, astate, abatch),
            (p_shard, st_shard, b_shard), n_forwards)


def build_prefill(cfg, shape, mesh):
    abatch = specs.prefill_input_specs(cfg, shape)
    fn = functools.partial(model_prefill, cfg=cfg, max_len=shape.seq_len)
    aparams = specs.abstract_params(cfg)
    p_shard = specs.param_shardings(cfg, mesh)
    b_shard = specs.batch_shardings(abatch, mesh)

    def prefill_fn(params, batch):
        return fn(params, batch=batch)

    return prefill_fn, (aparams, abatch), (p_shard, b_shard), 1


def build_decode(cfg, shape, mesh):
    """serve_step: ONE new token against a seq_len-deep cache."""
    tok, acache = specs.decode_input_specs(cfg, shape, mesh)
    aparams = specs.abstract_params(cfg)
    p_shard = specs.param_shardings(cfg, mesh)
    c_shard = specs.cache_shardings(cfg, acache, mesh)
    t_shard = specs.batch_shardings(tok, mesh)

    if "embeds" in tok:
        def serve_step(params, tok_in, cache):
            return model_decode(params, cfg, None, cache,
                                embeds=tok_in["embeds"])
    else:
        def serve_step(params, tok_in, cache):
            return model_decode(params, cfg, tok_in["tokens"], cache)

    return serve_step, (aparams, tok, acache), (p_shard, t_shard, c_shard), 1


def build_cell(cfg, shape, mesh, mgd_mode="forward"):
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, mgd_mode)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "artifacts/dryrun", mgd_mode: str = "forward",
             cfg_overrides=None, tag: str = "", pure_dp: bool = False,
             rule_set=None, verbose=True) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.shape.values())
    t0 = time.time()
    result = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "multi_pod": multi_pod, "chips": chips, "tag": tag,
        "mgd_mode": mgd_mode if shape.kind == "train" else None,
        "overrides": {k: str(v) for k, v in (cfg_overrides or {}).items()},
        "pure_dp": pure_dp,
    }
    if rule_set:
        rules = shd.RULE_SETS[rule_set]
    else:
        rules = shd.PURE_DP_RULES if pure_dp else None
    with shd.use_mesh(mesh, rules):
        fn, args, shardings, n_fwd = build_cell(cfg, shape, mesh, mgd_mode)
        # scan-aware logical cost from the jaxpr (global, all chips)
        jx = jax.make_jaxpr(fn)(*args)
        jcost = jaxpr_cost(jx)
        t_trace = time.time() - t0
        # donate params (+ optimizer state / cache): the production step
        # updates in place, so the dry-run must account buffers that way
        # too.  Donation needs matching out_shardings on the updated
        # outputs, so pin them.
        donate = ((0, 1) if shape.kind == "train"
                  else (2,) if shape.kind == "decode" else ())
        out_shardings = None
        if shape.kind == "train":
            rep = NamedSharding(mesh, P())
            metrics_shard = {"cost": rep, "c_tilde": rep, "updated": rep}
            out_shardings = (shardings[0], shardings[1], metrics_shard)
        elif shape.kind == "decode":
            out_shardings = (None, shardings[2])
        lowered = jax.jit(fn, in_shardings=shardings,
                          out_shardings=out_shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0 - t_trace
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_trace - t_lower
        mem = compiled.memory_analysis()
        xca = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text(), default_trip=1)

    result.update({
        "params": count_params(args[0]),
        "params_active": active_params(cfg, args[0]),
        "jaxpr_flops": jcost["flops"],
        "jaxpr_bytes": jcost["bytes"],
        "unknown_while": jcost["unknown_while"],
        "model_flops": model_flops(cfg, shape, shape.kind, n_fwd),
        "xla_flops_per_device": xca.get("flops"),
        "xla_bytes_per_device": xca.get("bytes accessed"),
        "collective_bytes_per_device": coll["total_bytes"],
        "collective_by_type": coll["by_type"],
        "n_collectives": len(coll["ops"]),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "seconds": {"trace": round(t_trace, 2), "lower": round(t_lower, 2),
                    "compile": round(t_compile, 2)},
    })
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "multipod" if multi_pod else "singlepod"
        tag_s = f"_{tag}" if tag else ""
        path = os.path.join(out_dir,
                            f"{arch}_{shape_name}_{suffix}{tag_s}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × "
              f"{'2x16x16' if multi_pod else '16x16'}: "
              f"compile {result['seconds']['compile']}s, "
              f"args {mem.argument_size_in_bytes/2**30:.2f} GiB/dev, "
              f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB/dev, "
              f"coll {coll['total_bytes']/2**20:.1f} MiB/dev/step")
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mgd-mode", default="forward",
                    choices=["forward", "central"])
    ap.add_argument("--out", default="artifacts/dryrun")
    # hillclimb variants
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--pure-dp", action="store_true")
    ap.add_argument("--rules", default=None,
                    choices=[None, "pure_dp", "dp_fsdp", "moe_ep"])
    ap.add_argument("--attn", default=None, choices=[None, "balanced"])
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    overrides = {}
    if args.seq_parallel:
        overrides["seq_parallel"] = True
    if args.attn:
        overrides["attn_impl"] = args.attn
    if args.moe_group:
        overrides["moe_group_size"] = args.moe_group

    cells = [(a, s) for a, s, ok in runnable_cells() if ok]
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                         mgd_mode=args.mgd_mode, cfg_overrides=overrides,
                         tag=args.tag, pure_dp=args.pure_dp,
                         rule_set=args.rules)
            except Exception as e:   # noqa: BLE001
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] FAIL {arch} × {shape} mp={mp}: {e}")
                traceback.print_exc(limit=5)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} cells compiled clean")


if __name__ == "__main__":
    main()
