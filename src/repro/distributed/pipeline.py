"""Pipeline parallelism over the "pod" axis (GPipe-style, shard_map).

MGD's default use of the pod axis is data/probe parallelism (the scalar
feedback makes that nearly free), but very deep models may still want
pipeline stages.  This wrapper runs S stages over the "pod" mesh axis with
M microbatches using collective_permute between neighbours — forward-only
(MGD has no backward pass, so the classic GPipe bubble halves: fill is
S−1 microbatch-steps, no drain for gradients).

The schedule is the standard loop of (M + S − 1) ticks; device s computes
microbatch m = t − s when 0 ≤ t − s < M, then permutes its activation ring
one step toward stage s+1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn, stage_params, x, *, mesh, axis="pod",
                     microbatches=None):
    """Run ``stage_fn(params_s, x)`` as a pipeline over ``axis``.

    stage_params: pytree stacked on a leading stage dim == mesh.shape[axis].
    x: [B, ...] global batch, split into ``microbatches`` chunks (default =
    number of stages).  Returns the final-stage outputs re-assembled.
    """
    n_stages = mesh.shape[axis]
    m = microbatches or n_stages
    b = x.shape[0]
    assert b % m == 0, (b, m)
    mb = b // m

    def run(params_local, x_local):
        # params_local: [1, ...] this stage's slice; x_local: [B/m? ...]
        params_s = jax.tree_util.tree_map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        # x_local holds this stage's shard of the microbatch queue:
        # stage 0 owns the real inputs; others start with zeros.
        queue = x_local  # [m_local_chunks, mb, ...] — here m chunks on stage0
        total = m + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(t, carry):
            buf, out = carry
            # current microbatch for this stage: m_idx = t - s
            m_idx = t - s
            active = (m_idx >= 0) & (m_idx < m)
            cur = buf  # [mb, ...] activation arriving from the left
            y = stage_fn(params_s, cur)
            y = jnp.where(active, y, cur)
            # last stage writes outputs
            write_idx = jnp.clip(m_idx, 0, m - 1)
            is_last = s == n_stages - 1
            out = jax.lax.cond(
                active & is_last,
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, y[None], write_idx, 0),
                lambda o: o, out)
            # rotate activations toward the next stage
            nxt = jax.lax.ppermute(y, axis, perm)
            # stage 0 injects the next microbatch from its local queue
            inject_idx = jnp.clip(t + 1, 0, m - 1)
            inj = jax.lax.dynamic_index_in_dim(queue, inject_idx, 0,
                                               keepdims=False)
            buf = jnp.where(s == 0, inj, nxt)
            return buf, out

        first = jax.lax.dynamic_index_in_dim(queue, 0, 0, keepdims=False)
        buf = jnp.where(s == 0, first, jnp.zeros_like(first))
        out0 = jnp.zeros((m,) + first.shape, first.dtype)
        _, outs = jax.lax.fori_loop(0, total, tick, (buf, out0))
        return outs[None]  # [1, m, mb, ...] — stacked over stages outside

    from .compat import shard_map
    shard = shard_map(
        run, mesh=mesh,
        in_specs=(P(axis), P()),   # params sharded by stage; x replicated
        out_specs=P(axis),         # per-stage outputs; last stage is real
    )
    xq = x.reshape(m, mb, *x.shape[1:])
    outs = shard(stage_params, xq)          # [n_stages, m, mb, ...]
    return outs[-1].reshape(b, *x.shape[1:])
