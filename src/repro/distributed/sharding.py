"""Logical-axis sharding: one vocabulary, any mesh.

Models annotate activations with *logical* axis names ("batch", "seq",
"model", "expert", "fsdp"); this module translates them to whatever mesh is
active — (16,16) ("data","model") single-pod, (2,16,16) ("pod","data",
"model") multi-pod, or no mesh at all (CPU tests → no-op).  Translation
drops axes the mesh doesn't have and axes that don't divide the dimension,
so the same model code lowers everywhere.

Logical vocabulary:
    batch  → ("pod", "data")   data parallelism (outer "pod" included)
    seq    → ("data",)         sequence parallelism (long-context KV/state)
    model  → ("model",)        tensor parallelism
    expert → ("model",)        expert parallelism (MoE banks)
    fsdp   → ("data",)         parameter sharding on the DP axis (ZeRO-3
                               style; MGD has no optimizer state to shard —
                               this shards the weights themselves)
    pod    → ("pod",)          explicit pod axis (probe parallelism)
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "seq": ("data",),
    "model": ("model",),
    "expert": ("model",),
    "fsdp": ("data",),
    "pod": ("pod",),
    # sequence parallelism: residual-stream seq dim sharded over the TP
    # axis between blocks (Megatron-SP) — GSPMD turns the per-layer
    # all-reduces into reduce-scatter + all-gather (½ the wire bytes) and
    # norms/elementwise run on 1/TP of the tokens.
    "sp": ("model",),
    # decode KV/latent caches: sequence dim sharded over every axis the
    # batch dim didn't consume (the spec builder dedups used axes) — B=128
    # decode gets seq→model, B=1 long-context gets seq→data×model.
    "kvseq": ("data", "model"),
}

# pure data parallelism: for models too small to feed a 16-wide TP axis,
# spend the "model" axis on batch too.  MGD makes this unusually cheap:
# no gradient all-reduce, no optimizer state — the only sync is the
# scalar cost psum.
PURE_DP_RULES = {
    **LOGICAL_RULES,
    "batch": ("pod", "data", "model"),
    "model": (),
    "expert": (),
    "fsdp": (),
    "sp": (),
}

# FSDP-only: every device computes the full model on its batch shard;
# weights are sharded across ALL axes and all-gathered per layer.
# Forward-only MGD never reduce-scatters gradients, so the per-layer wire
# cost is ONE weight all-gather — cheaper than Megatron-TP's two
# activation all-reduces whenever tokens/device·d > params/layer.
DP_FSDP_RULES = {
    **LOGICAL_RULES,
    "batch": ("pod", "data", "model"),
    "model": (),
    "expert": (),
    "sp": (),
    "fsdp": ("pod", "data", "model"),
}

# MoE-EP: experts keep expert parallelism over "model"; the dense parts
# (MLA projections, router, embeddings) drop tensor parallelism and run
# FSDP-style over "data" instead — their per-layer weight all-gather is
# far cheaper than the activation all-reduces TP needs at d_model 7168.
MOE_EP_RULES = {
    **LOGICAL_RULES,
    "model": (),
    "sp": (),
    "expert": ("model",),
    "fsdp": ("data", "model"),
}

RULE_SETS = {"default": LOGICAL_RULES, "pure_dp": PURE_DP_RULES,
             "dp_fsdp": DP_FSDP_RULES, "moe_ep": MOE_EP_RULES}

_ACTIVE_MESH: Optional[Mesh] = None
_ACTIVE_RULES: dict = LOGICAL_RULES


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh (+ optional logical-rule table) during tracing."""
    global _ACTIVE_MESH, _ACTIVE_RULES
    prev, prev_rules = _ACTIVE_MESH, _ACTIVE_RULES
    _ACTIVE_MESH = mesh
    _ACTIVE_RULES = rules or LOGICAL_RULES
    try:
        yield mesh
    finally:
        _ACTIVE_MESH = prev
        _ACTIVE_RULES = prev_rules


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def _translate(name, dim_size, mesh, rules=None) -> Optional[tuple]:
    """Logical name → tuple of mesh axes (or None = replicated)."""
    if name is None:
        return None
    rules = rules or _ACTIVE_RULES
    axes = tuple(a for a in rules.get(name, ())
                 if a in mesh.axis_names)
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if dim_size is not None and dim_size % total != 0:
        # try dropping trailing axes until it divides (e.g. kv-heads smaller
        # than the model axis → replicate)
        while axes:
            axes = axes[:-1]
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            if axes and dim_size % total == 0:
                return axes
        return None
    return axes


def logical_spec(shape, names, mesh=None, *, align="left") -> P:
    """Build a PartitionSpec for ``shape`` from logical ``names``.

    ``align="right"`` pads names on the left (stacked-layer leading dims).
    A mesh axis is used at most once per spec — later dims that would reuse
    an axis are replicated (e.g. a [B, S, ...] cache asking for "batch" and
    "seq" on a mesh where both map to "data" shards only the batch dim).
    """
    mesh = mesh or _ACTIVE_MESH
    if mesh is None:
        return P()
    names = list(names)
    if len(names) < len(shape):
        pad = [None] * (len(shape) - len(names))
        names = (pad + names) if align == "right" else (names + pad)
    entries = []
    used = set()
    for dim, name in zip(shape, names):
        axes = _translate(name, dim, mesh)
        if axes is not None:
            axes = tuple(a for a in axes if a not in used)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            if not axes or dim % total != 0:
                axes = None
        if axes is None:
            entries.append(None)
        elif len(axes) == 1:
            used.add(axes[0])
            entries.append(axes[0])
        else:
            used.update(axes)
            entries.append(axes)
    return P(*entries)


def shard(x, *names):
    """Activation sharding constraint in logical names; no-op without mesh."""
    if _ACTIVE_MESH is None:
        return x
    spec = logical_spec(x.shape, names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACTIVE_MESH, spec))


# ---------------------------------------------------------------------------
# Parameter shardings from path-pattern rules
# ---------------------------------------------------------------------------


def param_specs(params_shape, rules, mesh=None):
    """Map a params shape-pytree to a PartitionSpec pytree.

    ``rules`` is an ordered list of (regex, logical-names) — first match on
    the '/'-joined tree path wins; unmatched leaves are replicated.  Names
    are RIGHT-aligned to the leaf shape, so one rule covers both a stacked
    [L, d, f] bank and an unstacked [d, f] matrix.
    """
    mesh = mesh or _ACTIVE_MESH

    def one(path, leaf):
        pstr = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for pat, names in rules:
            if re.search(pat, pstr):
                return logical_spec(leaf.shape, names, mesh, align="right")
        return P()

    return jax.tree_util.tree_map_with_path(one, params_shape)


def named_shardings(params_shape, rules, mesh):
    specs = param_specs(params_shape, rules, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
