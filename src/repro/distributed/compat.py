"""Version-tolerant wrappers for the jax distribution APIs we use.

The codebase targets the current jax API (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.make_mesh(..., axis_types=...)``);
older installs (≤ 0.4.x) expose the same semantics under
``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)`` and
a ``make_mesh`` without ``axis_types``.  Everything funnels through here so
the call sites stay on the modern spelling.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis_types when the API has them."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(AxisType.Auto,) * len(axis_shapes))
    except (ImportError, AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, manual_axes=None):
    """shard_map that is manual over ``manual_axes`` (None = all mesh axes)
    and automatic elsewhere, with replication checking disabled."""
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": False}
        if manual_axes is not None:
            kw["axis_names"] = frozenset(manual_axes)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    # Old jax: partial-auto shard_map lowers to a PartitionId instruction
    # the 0.4.x SPMD partitioner rejects, so run fully manual — specs
    # already describe every mesh axis (unmentioned axes = replicated);
    # only the *automatic re-sharding* of the inner computation is lost,
    # not correctness.
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
