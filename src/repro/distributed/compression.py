"""Gradient compression for the backprop baseline's all-reduce.

int8 stochastic quantization with error feedback (residual carried between
steps) — the standard distributed-optimization trick for shrinking the
O(P) gradient all-reduce that backprop needs at pod scale.

MGD needs none of this: its entire feedback channel is ONE scalar per step
(the cost psum), which is the quantitative point the benchmark harness
makes when it compares collective bytes (EXPERIMENTS.md §Roofline).  This
module exists so the baseline is a fair, production-grade strawman.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_init(params):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)


def quantize_int8(g, residual, key):
    """g + residual → (int8 codes, scale, new residual).  Stochastic
    rounding keeps the quantizer unbiased."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    scaled = gf / scale
    noise = jax.random.uniform(key, gf.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_gradients(grads, residuals, seed_step):
    """Tree-wise int8+EF round trip (the all-reduce would move the int8
    payload; XLA inserts it when this feeds a psum under pjit)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = jax.tree_util.tree_leaves(residuals)
    out_g, out_r = [], []
    for i, (g, r) in enumerate(zip(leaves, res_leaves)):
        key = jax.random.fold_in(jax.random.PRNGKey(17 + i), seed_step)
        q, scale, nr = quantize_int8(g, r, key)
        out_g.append(dequantize_int8(q, scale).astype(g.dtype))
        out_r.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_r))
