"""Distribution substrate: sharding rules, pipeline stages, compression."""
