"""Drift/aging study: MGD's online re-trim vs scheduled recalibration.

The paper's central hardware claim is that continuous zero-order
feedback can hold a network at its operating point as the device
misbehaves; the follow-up scaling study (Oripov et al. 2025) makes
TIME-VARYING device parameters the open deployment question.  This
benchmark makes that quantitative on a ``hardware.DriftingPlant`` whose
stored weights random-walk (or decay toward rest) after every write:

* Train a reference network drift-free → θ* and its accuracy A₀.
* For each drift rate σ_d, run three mitigation strategies from θ*
  through the SAME ``train_mgd`` loop for a fixed window:
    - ``none``   — no mitigation: η = 0, the device just ages.
    - ``recal``  — scheduled recalibration: η = 0 plus the train loop's
      ``recal_every`` hook (periodic full rewrite from the trainer's
      shadow θ*), the lab-bench mitigation.
    - ``mgd``    — continuous MGD re-trim: the optimizer keeps probing
      the aging device and pushes downhill from wherever it actually is.
* Record tail accuracy per (rate, strategy), the drift rate at which
  each strategy collapses (loses half its above-chance margin), the
  fraction of drift-free accuracy MGD holds at the rate where
  no-mitigation collapses (the headline number, gated in CI by
  ``benchmarks/check_regression.py``), and a Table-3-style wall-clock
  projection of what each strategy costs per step on HW1-like latencies.

The re-trim driver runs the strongest feedback the discrete algorithm
offers (probe averaging, ``probes=4``, large η): the aging device is a
NON-stationary target, so the correction rate — not asymptotic variance
— is what sets the steady state, and the wall-clock rows price the 4×
probe reads honestly.

A decay-mode trio (weights relaxing toward 0 with time constant τ_d)
rides along: pure relaxation is the aging mode recalibration handles
best, so it is the fair comparison point for the OU walk rows.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.api import DriverConfig
from repro.core.cost import mse
from repro.data import tasks
from repro.data.pipeline import generator_sampler
from repro.hardware import DriftingPlant, IdealPlant, PlantMeta
from repro.models.simple import mlp_apply, mlp_init
from repro.training.train_loop import classification_accuracy, train_mgd

SIZES = (49, 4, 4)
CHANCE = 0.25                          # 4-way nist7x7 classification
RATES = (0.003, 0.01, 0.03, 0.08)      # σ_d sweep (per-step walk std)
SMOKE_RATES = (0.01, 0.08)
DECAY_TAU = 400.0                      # decay-mode relaxation constant
STRATEGIES = ("none", "recal", "mgd")
COLLAPSE_FRAC = 0.5   # collapsed ⇔ above-chance margin falls below ½·(A₀−chance)
RECAL_EVERY = 100
ETA_REF = 0.4                          # drift-free reference training
ETA_RETRIM = 1.6                       # re-trim: strong feedback ...
PROBES_RETRIM = 4                      # ... with 4-probe averaging


def _loss(params, batch):
    return mse(mlp_apply(params, batch["x"]), batch["y"])


def _eval_batch():
    x, y = tasks.nist7x7_batch(jax.random.PRNGKey(99), 512)
    return x, y


def _accuracy(params, xe, ye):
    return float(classification_accuracy(mlp_apply, params, xe, ye))


def _reference(seed, steps):
    """Drift-free MGD training → (θ*, A₀)."""
    params = mlp_init(jax.random.PRNGKey(seed), SIZES)
    cfg = DriverConfig(dtheta=2e-2, eta=ETA_REF, mode="central", seed=seed)
    res = train_mgd(_loss, params, cfg,
                    generator_sampler(tasks.nist7x7_batch, 8, seed=11),
                    steps, chunk=max(steps // 4, 1), log=None)
    xe, ye = _eval_batch()
    return res.params, _accuracy(res.params, xe, ye)


def _strategy_run(strategy, theta_star, plant, seed, steps):
    """One mitigation window from θ* on ``plant``; returns tail accuracy
    (mean of the last 3 evals — recalibration phase averages out)."""
    xe, ye = _eval_batch()
    mgd = strategy == "mgd"
    cfg = DriverConfig(dtheta=2e-2, eta=ETA_RETRIM if mgd else 0.0,
                       probes=PROBES_RETRIM if mgd else 1,
                       mode="central", seed=seed)
    eval_every = max(steps // 8, 1)
    res = train_mgd(
        _loss, theta_star, cfg,
        generator_sampler(tasks.nist7x7_batch, 8, seed=11), steps,
        plant=plant, chunk=eval_every,
        eval_fn=lambda p: {"acc": _accuracy(p, xe, ye)},
        eval_every=eval_every, log=None,
        recal_every=RECAL_EVERY if strategy == "recal" else 0,
        recal_params=theta_star)
    accs = [rec["acc"] for _, rec in res.history if "acc" in rec]
    return float(np.mean(accs[-3:]))


def _wallclock_rows(steps):
    """Projected seconds per drift window on an HW1-style device (1 ms
    cost read, 1 ms full-array write): what each mitigation strategy
    COSTS, Table-3 style."""
    hw = PlantMeta(name="HW1-drift", read_latency_s=1e-3,
                   write_latency_s=1e-3)
    per_step = {
        "none": 0.0,                                    # device idles
        "recal": hw.step_latency_s(0, 1) / RECAL_EVERY,  # amortized rewrite
        # one central pair per probe, plus the update write
        "mgd": hw.step_latency_s(2 * PROBES_RETRIM, 1),
    }
    return [{
        "bench": "drift_aging",
        "name": f"projected_{strategy}_s_per_{steps}steps",
        "value": steps * s,
        "detail": "HW1-style 1 ms read/write; recal amortizes one full "
                  f"rewrite per {RECAL_EVERY} steps",
    } for strategy, s in per_step.items()]


def run(seed: int = 0, smoke: bool = False):
    rates = SMOKE_RATES if smoke else RATES
    ref_steps = 2000
    window = 1000

    theta_star, a0 = _reference(seed, ref_steps)
    collapse_acc = CHANCE + COLLAPSE_FRAC * (a0 - CHANCE)
    rows = [{
        "bench": "drift_aging", "name": "driftfree_accuracy", "value": a0,
        "detail": f"reference MGD training, {ref_steps} steps, nist7x7",
    }]

    tail = {}
    for rate in rates:
        for strategy in STRATEGIES:
            plant = DriftingPlant(IdealPlant(_loss), mode="walk",
                                  drift_rate=rate, seed=seed + 41)
            acc = _strategy_run(strategy, theta_star, plant, seed, window)
            tail[(strategy, rate)] = acc
            rows.append({
                "bench": "drift_aging",
                "name": f"acc_{strategy}_rate{rate:g}",
                "value": acc,
                "detail": f"tail accuracy after {window} drift steps; "
                          f"OU walk sigma_d={rate:g}/step",
            })

    collapse = {}
    for strategy in STRATEGIES:
        collapsed = [r for r in rates
                     if tail[(strategy, r)] < collapse_acc]
        collapse[strategy] = min(collapsed) if collapsed else -1.0
        rows.append({
            "bench": "drift_aging",
            "name": f"collapse_rate_{strategy}",
            "value": collapse[strategy],
            "detail": f"first swept sigma_d losing half the above-chance "
                      f"margin (tail acc < {collapse_acc:.3f}; -1: never "
                      f"in sweep)",
        })

    # headline: the fraction of drift-free accuracy continuous MGD holds
    # at the drift rate where the unmitigated device has collapsed
    if collapse["none"] > 0:
        hold = tail[("mgd", collapse["none"])] / a0
        detail = (f"MGD tail acc / A0 at sigma_d={collapse['none']:g} "
                  f"(where no-mitigation collapsed)")
    else:
        hold, detail = -1.0, "no-mitigation never collapsed in this sweep"
    rows.append({
        "bench": "drift_aging", "name": "retrim_hold_frac",
        "value": hold, "detail": detail,
    })

    # decay mode: relaxation toward rest — recalibration's best case
    # (full grid only: the CI smoke gate covers the walk rows)
    if not smoke:
        for strategy in STRATEGIES:
            plant = DriftingPlant(IdealPlant(_loss), mode="decay",
                                  drift_tau=DECAY_TAU, rest=0.0,
                                  seed=seed + 41)
            acc = _strategy_run(strategy, theta_star, plant, seed, window)
            rows.append({
                "bench": "drift_aging",
                "name": f"acc_{strategy}_decay_tau{DECAY_TAU:g}",
                "value": acc,
                "detail": f"tail accuracy, weights relaxing toward 0 with "
                          f"tau_d={DECAY_TAU:g} write events",
            })

    rows += _wallclock_rows(window)
    return rows
