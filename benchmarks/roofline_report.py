"""Roofline + collective-traffic summary over the dry-run artifacts —
this repo's quantitative version of the paper's §5 broadcast argument.

Headline number: MGD's gradient-path collective is ONE scalar psum per
step; backprop's is an O(P) gradient all-reduce.  The table compares the
measured per-device wire bytes of the full MGD step (dominated by plain
tensor-parallel activation collectives that inference would also pay)
against the hypothetical backprop gradient all-reduce (2·P/chips bytes)."""
from __future__ import annotations

import glob
import json
import os

from repro.launch.roofline import LINK_BW, roofline_terms

ART = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def run():
    rows = []
    paths = sorted(glob.glob(os.path.join(ART, "*_singlepod.json")))
    if not paths:
        return [{"bench": "roofline", "name": "artifacts_missing",
                 "value": -1,
                 "detail": "run python -m repro.launch.dryrun first"}]
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag"):
            continue
        t = roofline_terms(rec)
        rows.append({
            "bench": "roofline",
            "name": f"{rec['arch']}_{rec['shape']}_dominant",
            "value": round(t["roofline_fraction"], 4),
            "detail": (f"{t['dominant']}-bound; compute {t['compute']:.3g}s "
                       f"memory {t['memory']:.3g}s coll "
                       f"{t['collective']:.3g}s; MODEL/HLO "
                       f"{t['flops_ratio']*100:.0f}%"),
        })
        if rec["kind"] == "train":
            # MGD vs backprop feedback-channel bytes
            p = rec["params"]
            bp_allreduce = 2.0 * p * 2 / rec["chips"]   # bf16 ring AR
            mgd_scalar = 4.0                            # one f32 psum
            rows.append({
                "bench": "roofline",
                "name": f"{rec['arch']}_gradpath_bytes_ratio",
                "value": bp_allreduce / mgd_scalar,
                "detail": (f"backprop grad-AR {bp_allreduce/2**20:.1f} "
                           f"MiB/dev vs MGD scalar 4 B "
                           f"(={bp_allreduce/LINK_BW*1e3:.2f} ms/step "
                           "of pure gradient traffic eliminated)"),
            })
    return rows
