"""Paper Fig. 6: effect of τ_θ on XOR training time at fixed batch size.

(a) fixed η: batch-1 training slows with τ_θ, batch-4 barely changes;
(b) the max-η sweep is approximated with a coarse grid per τ_θ.
"""
from __future__ import annotations

from repro.core import MGDConfig

from .common import median, time_to_solve_xor

N_SEEDS = 3
TAUS = (1, 4, 16)


def run():
    rows = []
    # (a) fixed low eta, batch 1 (tau_x = tau_theta) vs batch 4
    for batch in (1, 4):
        for tau in TAUS:
            tau_x = tau if batch == 1 else max(1, tau // 4)
            cfg = MGDConfig(dtheta=1e-2, eta=0.5, tau_theta=tau,
                            tau_x=tau_x)
            times = [time_to_solve_xor(cfg, s, max_steps=80000,
                                       chunk=4000)
                     for s in range(N_SEEDS)]
            solved = [t for t in times if t is not None]
            rows.append({
                "bench": "fig6", "name": f"batch{batch}_tau{tau}_steps",
                "value": median(solved) if solved else -1,
                "detail": f"{len(solved)}/{N_SEEDS} solved, fixed eta=0.5",
            })
    # (b) max-eta per tau (coarse grid)
    for tau in TAUS:
        best = None
        for eta in (8.0, 4.0, 2.0, 1.0, 0.5):
            cfg = MGDConfig(dtheta=1e-2, eta=eta, tau_theta=tau, tau_x=tau)
            times = [time_to_solve_xor(cfg, s, max_steps=40000, chunk=2000)
                     for s in range(N_SEEDS)]
            solved = [t for t in times if t is not None]
            if len(solved) * 2 > N_SEEDS:       # >50% convergence
                best = (eta, median(solved))
                break
        rows.append({
            "bench": "fig6", "name": f"max_eta_tau{tau}",
            "value": best[0] if best else -1,
            "detail": f"min median steps {best[1] if best else 'n/a'}; "
                      "paper: max-eta falls as tau_theta grows",
        })
    return rows
