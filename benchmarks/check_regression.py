"""Bench-regression gate: fresh benchmark runs vs committed baselines.

CI's ``bench-smoke`` job used to check only that the benchmarks *run*;
the recorded numbers in ``artifacts/bench/*.json`` could rot silently.
This checker turns them into a gate:

    python -m benchmarks.check_regression \
        --fresh artifacts/bench-fresh --baseline artifacts/bench

compares every fresh metric that has a tolerance entry below against the
committed baseline of the same (bench, name) and exits non-zero when any
lands outside its band.  Metrics without an entry — wall-clock steps/s,
machine-dependent timings — are reported informationally and never gate.
Per-file, a gate that matches NO fresh metric at all is itself an error:
renamed metrics must update the tolerance table, not silently un-gate.

Directions: most checks are two-sided (a benchmark that suddenly doubles
its variance is as suspicious as one that halves it); accuracy-style
metrics gate only the drop (``direction="min"`` — improvements pass).

``--self-test`` verifies the gate end-to-end without running a single
benchmark: the baseline compared against itself must pass, and a
baseline with one gated metric perturbed beyond tolerance must fail.
CI runs it next to the real gate so a broken checker cannot pass green.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import math
import os
import sys
import tempfile

# (bench, metric-name glob, tolerance).  ``rel`` is a fraction of the
# baseline magnitude, ``abs`` an absolute band; the allowance is their
# max.  ``direction``: "both" (default) | "min" (gate drops only) |
# "max" (gate rises only).  First match wins.
TOLERANCES = [
    # drift_aging — counter-keyed drift + fixed seeds: deterministic on a
    # given jax; bands absorb cross-version RNG/codegen differences.
    ("drift_aging", "retrim_hold_frac", dict(abs=0.04, direction="min")),
    ("drift_aging", "driftfree_accuracy", dict(abs=0.10, direction="min")),
    ("drift_aging", "acc_mgd_*", dict(abs=0.10, direction="min")),
    ("drift_aging", "projected_*", dict(rel=0.01)),
    # fault_tolerance — accuracy hold fractions under injected faults;
    # min-direction (a policy that holds MORE accuracy is fine), and the
    # two exact invariants (bit-exact retry transparency + resume) gate
    # at zero tolerance
    ("fault_tolerance", "fault_free_accuracy", dict(abs=0.10, direction="min")),
    ("fault_tolerance", "hold_frac_retry_transient", dict(abs=0.0)),
    ("fault_tolerance", "hold_frac_*", dict(abs=0.05, direction="min")),
    ("fault_tolerance", "resume_bitexact", dict(abs=0.0)),
    # online_serving — end-to-end serving tier: accuracy is measured from
    # the service's responses, so batching/swap/alive-mask paths are all
    # inside the gate.  Latency/QPS rows are machine-dependent and stay
    # ungated.
    ("online_serving", "driftfree_accuracy", dict(abs=0.10, direction="min")),
    ("online_serving", "served_acc_online_trim_*",
     dict(abs=0.10, direction="min")),
    ("online_serving", "serve_trim_hold_frac",
     dict(abs=0.04, direction="min")),
    ("online_serving", "no_trim_collapsed", dict(abs=0.0)),
    ("online_serving", "torn_swaps", dict(abs=0.0)),
    ("online_serving", "resume_bitexact", dict(abs=0.0)),
    # farm_scaling — the 1/k law and farm convergence
    ("farm_scaling", "ghat_variance_*", dict(rel=0.75)),
    ("farm_scaling", "variance_ratio_*", dict(rel=0.5)),
    ("farm_scaling", "nist7x7_k*_accuracy", dict(abs=0.15, direction="min")),
    ("farm_scaling", "projected_*", dict(rel=0.01)),
    # farm backends — process farms must stay flat in k (max: a RISING
    # step-time ratio is the regression), keep their pipeline utilization
    # (min), and keep beating the GIL-serialized thread farm (min).
    # steps_per_s_* rows stay informational (machine-dependent).
    ("farm_scaling", "wallclock_flat_*", dict(rel=0.30, direction="max")),
    ("farm_scaling", "pipeline_utilization_*",
     dict(abs=0.15, direction="min")),
    ("farm_scaling", "thread_over_process_*",
     dict(rel=0.50, direction="min")),
    # scaling_laws — the acceptance laws: the mesh ≡ farm bit-equality
    # row gates at zero, the 1/k variance ratios and per-k variances in
    # the same bands as farm_scaling, accuracy gates the drop only, and
    # the pure-arithmetic N counts / projections gate tight
    ("scaling_laws", "mesh_farm_bitmatch_f32", dict(abs=0.0)),
    ("scaling_laws", "mesh_ghat_variance_*", dict(rel=0.75)),
    ("scaling_laws", "mesh_variance_ratio_replicated_*", dict(rel=0.5)),
    ("scaling_laws", "ghat_variance_N*", dict(rel=0.75)),
    ("scaling_laws", "xor_accuracy_k*", dict(abs=0.25, direction="min")),
    ("scaling_laws", "xor_cost_k*", dict(rel=0.5, direction="max")),
    ("scaling_laws", "params_*", dict(rel=0.001)),
    ("scaling_laws", "projected_probe_budget_*", dict(rel=0.01)),
    ("scaling_laws", "projected_step_s_*", dict(rel=0.01)),
    # fused_probe — only the arithmetic W-read identities gate; the
    # steps/s rows are machine-dependent and stay informational
    ("fused_probe", "*_wread_ratio", dict(rel=0.001)),
    # full-suite extras (nightly / local full runs)
    ("hardware_plants", "nist7x7_*_accuracy", dict(abs=0.10, direction="min")),
    ("hardware_plants", "*_projected_s", dict(rel=0.01)),
    ("table3_hardware", "*_seconds", dict(rel=0.01)),
]


def spec_for(bench: str, name: str):
    for b, pattern, spec in TOLERANCES:
        if b == bench and fnmatch.fnmatch(name, pattern):
            return spec
    return None


def _band(spec, base):
    allow = max(spec.get("abs", 0.0), spec.get("rel", 0.0) * abs(base))
    direction = spec.get("direction", "both")
    lo = base - allow if direction in ("both", "min") else -math.inf
    hi = base + allow if direction in ("both", "max") else math.inf
    return lo, hi


def _rows(path):
    with open(path) as f:
        return json.load(f)["rows"]


def compare_file(bench: str, fresh_rows, baseline_rows):
    """Check one benchmark's fresh rows against its baseline rows.
    Returns (violations, checked, findings) where findings are printable
    (status, name, message) triples."""
    base = {r["name"]: float(r["value"]) for r in baseline_rows}
    findings, checked, violations = [], 0, 0
    for row in fresh_rows:
        name, value = row["name"], float(row["value"])
        spec = spec_for(bench, name)
        if spec is None:
            findings.append(("info", name, f"{value:.6g} (ungated)"))
            continue
        if name not in base:
            findings.append(("warn", name,
                             f"{value:.6g} — no committed baseline "
                             f"(new metric? commit a refreshed artifact)"))
            continue
        checked += 1
        lo, hi = _band(spec, base[name])
        if lo <= value <= hi:
            findings.append(("ok", name,
                             f"{value:.6g} in [{lo:.6g}, {hi:.6g}]"))
        else:
            violations += 1
            findings.append(("FAIL", name,
                             f"{value:.6g} outside [{lo:.6g}, {hi:.6g}] "
                             f"(baseline {base[name]:.6g})"))
    gated_in_baseline = sum(1 for n in base if spec_for(bench, n))
    if checked == 0 and gated_in_baseline:
        violations += 1
        findings.append((
            "FAIL", "<gate>",
            f"no fresh metric matched any of the {gated_in_baseline} gated "
            f"baseline metrics — renamed metrics must update "
            f"check_regression.TOLERANCES"))
    return violations, checked, findings


def compare_dirs(fresh_dir: str, baseline_dir: str, verbose=True) -> int:
    """Compare every benchmark JSON present in BOTH dirs; returns the
    violation count (0 = gate passes)."""
    fresh_files = sorted(f for f in os.listdir(fresh_dir)
                         if f.endswith(".json"))
    if not fresh_files:
        print(f"check_regression: no fresh artifacts in {fresh_dir}",
              file=sys.stderr)
        return 1
    total_violations = total_checked = 0
    for fname in fresh_files:
        bench = fname[:-len(".json")]
        baseline_path = os.path.join(baseline_dir, fname)
        if not os.path.exists(baseline_path):
            if verbose:
                print(f"-- {bench}: no committed baseline, skipped")
            continue
        violations, checked, findings = compare_file(
            bench, _rows(os.path.join(fresh_dir, fname)),
            _rows(baseline_path))
        total_violations += violations
        total_checked += checked
        if verbose:
            print(f"-- {bench}: {checked} gated, {violations} regressed")
            for status, name, msg in findings:
                if status != "info" or os.environ.get("CHECK_REGRESSION_V"):
                    print(f"   [{status:4s}] {name}: {msg}")
    print(f"check_regression: {total_checked} metrics gated, "
          f"{total_violations} regressed")
    return total_violations


def self_test(baseline_dir: str) -> int:
    """Prove the gate can fail: baseline-vs-itself passes, and a copy
    with one gated metric pushed beyond tolerance fails.  Returns 0 only
    when both behave."""
    if compare_dirs(baseline_dir, baseline_dir, verbose=False):
        print("self-test FAILED: baseline does not pass against itself",
              file=sys.stderr)
        return 1
    # find a gated metric to perturb
    for fname in sorted(os.listdir(baseline_dir)):
        if not fname.endswith(".json"):
            continue
        bench = fname[:-len(".json")]
        with open(os.path.join(baseline_dir, fname)) as f:
            payload = json.load(f)
        for row in payload["rows"]:
            spec = spec_for(bench, row["name"])
            if spec is None:
                continue
            base = float(row["value"])
            lo, hi = _band(spec, base)
            bad = (lo - max(1.0, abs(base)) if math.isfinite(lo)
                   else hi + max(1.0, abs(base)))
            with tempfile.TemporaryDirectory() as tmp:
                perturbed = dict(payload)
                perturbed["rows"] = [
                    dict(r, value=bad) if r["name"] == row["name"] else r
                    for r in payload["rows"]]
                with open(os.path.join(tmp, fname), "w") as f:
                    json.dump(perturbed, f)
                if not compare_dirs(tmp, baseline_dir, verbose=False):
                    print(f"self-test FAILED: perturbing {bench}:"
                          f"{row['name']} to {bad:.6g} was not caught",
                          file=sys.stderr)
                    return 1
            print(f"self-test OK: identity passes; perturbed {bench}:"
                  f"{row['name']} ({base:.6g} -> {bad:.6g}) fails as it "
                  f"should")
            return 0
    print("self-test FAILED: no gated metric found in baseline dir",
          file=sys.stderr)
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default="artifacts/bench-fresh",
                    help="directory with the fresh benchmark JSONs")
    ap.add_argument("--baseline", default="artifacts/bench",
                    help="directory with the committed baseline JSONs")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate fails on a perturbed baseline "
                         "(no benchmarks are run)")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test(args.baseline)
    return 1 if compare_dirs(args.fresh, args.baseline) else 0


if __name__ == "__main__":
    raise SystemExit(main())
