"""Paper Table 3: projected wall-clock training time on hardware.

MGD's iteration count (from Table 2 budgets) × hardware time constants.
One MGD iteration = one perturbation epoch ≈ max(τ_p, τ_x) plus the
parameter-update amortized over τ_θ; the paper's rows use τ_p as the
per-step clock, which we follow.  The backprop column reports this repo's
measured CPU step time for the same nets, scaled as an honest stand-in for
the paper's GPU numbers (clearly labelled).
"""
from __future__ import annotations

import time

import jax

from repro.core import mse
from repro.data import tasks
from repro.data.pipeline import dataset_sampler, generator_sampler
from repro.hardware import PlantMeta
from repro.models.simple import (fashion_cnn_apply, fashion_cnn_init,
                                 mlp_apply, mlp_init)
from repro.training.train_loop import train_backprop

# the paper's three hardware rows as plant metadata: the per-step clock is
# the cost readout (τ_p); persistent writes are amortized over τ_θ and the
# paper's rows fold them into τ_p, so write latency is 0 here.
HW = {
    "HW1_chip_in_loop": PlantMeta(name="HW1", read_latency_s=1e-3,
                                  external=True),          # τ_p = 1 ms
    "HW2_memcompute": PlantMeta(name="HW2", read_latency_s=10e-9),
    "HW3_superconducting": PlantMeta(name="HW3", read_latency_s=200e-12),
}
# write-capable variants of the fast rows: every persistent write paid at
# the readout clock (τ_w = τ_p — conservative; real memcompute writes are
# slower, superconducting loop writes faster).  These price the CENTRAL
# pair explicitly (2 reads + 1 write per step) and its fused upgrade:
# differential probe line (the antithetic pair in ONE conversion) + the
# double-buffered farm schedule (write overlaps read → max, not sum).
HW_WRITE = {
    "HW2_memcompute": PlantMeta(name="HW2w", read_latency_s=10e-9,
                                write_latency_s=10e-9),
    "HW3_superconducting": PlantMeta(name="HW3w", read_latency_s=200e-12,
                                     write_latency_s=200e-12),
}
STEPS = {"2bit_parity": 1e4, "fashion_mnist": 1e6, "cifar10": 1e7}
PAPER = {  # (HW1, HW2, HW3, backprop) from the paper's Table 3
    "2bit_parity": ("20 s", "200 us", "4 us", "70 ms CPU"),
    "fashion_mnist": ("33 min", "20 ms", "400 us", "54 s GPU"),
    "cifar10": ("5.6 h", "200 ms", "4 ms", "480 s GPU"),
}


def run():
    rows = []
    for task, steps in STEPS.items():
        for hw, meta in HW.items():
            rows.append({
                "bench": "table3", "name": f"{task}_{hw}_seconds",
                "value": steps * meta.step_latency_s(reads_per_step=1,
                                                     writes_per_step=0),
                "detail": f"paper: {PAPER[task]}",
            })
    # explicit-write projections: central pair priced honestly (2 reads +
    # 1 write), then the fused path (differential pair + pipelined write)
    # — the projected payoff of ChipFarm(pipeline=True) on hardware whose
    # writes are NOT free
    for task, steps in STEPS.items():
        for hw, meta in HW_WRITE.items():
            central = meta.step_latency_s(reads_per_step=2,
                                          writes_per_step=1)
            fused = meta.step_latency_s(reads_per_step=2, writes_per_step=1,
                                        differential=True, pipelined=True)
            rows.append({
                "bench": "table3", "name": f"{task}_{hw}_central_seconds",
                "value": steps * central,
                "detail": "2 reads + 1 write per step, tau_w = tau_p",
            })
            rows.append({
                "bench": "table3", "name": f"{task}_{hw}_fused_seconds",
                "value": steps * fused,
                "detail": "differential pair (1 read) + pipelined write "
                          f"-> max(tau_r, tau_w); {central / fused:.1f}x "
                          "over central",
            })
    # measured backprop step time on THIS machine (CPU stand-in)
    x, y = tasks.xor_dataset()
    loss = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])   # noqa: E731
    params = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
    t0 = time.time()
    train_backprop(loss, params, dataset_sampler(x, y, 4), 2000, eta=2.0,
                   chunk=1000, log=None)
    per_step = (time.time() - t0) / 2000
    rows.append({"bench": "table3", "name": "2bit_parity_backprop_cpu_s",
                 "value": per_step * 1e4,
                 "detail": f"measured {per_step*1e6:.1f} us/step here; "
                           "paper CPU 70 ms total"})
    floss = lambda p, b: mse(fashion_cnn_apply(p, b["x"]), b["y"])  # noqa
    fparams = fashion_cnn_init(jax.random.PRNGKey(0))
    sample = generator_sampler(tasks.fashion_batch, 256, seed=3)
    t0 = time.time()
    train_backprop(floss, fparams, sample, 40, eta=1.0, chunk=20, log=None)
    per_step = (time.time() - t0) / 40
    rows.append({"bench": "table3", "name": "fashion_backprop_cpu_s_1e6",
                 "value": per_step * 1e6,
                 "detail": f"measured {per_step*1e3:.1f} ms/step (batch "
                           "256, CPU); paper GPU 54 s — MGD on HW2/HW3 "
                           "projects orders of magnitude faster"})
    return rows
