"""Shared benchmark helpers: driver-based MGD training with early stopping.

Every benchmark constructs its algorithm through ``repro.driver`` — the
one registry call — so the same helper drives discrete, analog, and
probe-parallel configs against any hardware plant.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.api import driver as build_driver, make_epoch
from repro.core import MGDConfig, mse
from repro.data import tasks
from repro.data.pipeline import dataset_sampler
from repro.models.simple import mlp_apply, mlp_init


def train_until(loss_fn, params, cfg, sample_fn, *,
                max_steps: int, threshold_fn: Callable,
                chunk: int = 2000, plant=None, algorithm: str = "discrete"):
    """Run an MGD driver in jitted chunks until threshold_fn(params) or
    budget.  ``plant`` optionally trains against an explicit hardware
    device; ``cfg`` is a DriverConfig or the algorithm's legacy config.

    Returns (params, steps_used, solved).
    """
    drv = build_driver(algorithm, cfg, loss_fn, plant=plant)
    run = make_epoch(drv, chunk, sample_fn)
    state = drv.init(params)
    steps = 0
    while steps < max_steps:
        params, state, _ = run(params, state)
        steps += chunk
        if threshold_fn(params):
            return params, steps, True
    return params, steps, False


def xor_mse(params):
    x, y = tasks.xor_dataset()
    return float(mse(mlp_apply(params, x), y))


def xor_setup(seed: int):
    x, y = tasks.xor_dataset()
    params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])  # noqa: E731
    return params, loss_fn, dataset_sampler(x, y, 1)


def time_to_solve_xor(cfg: MGDConfig, seed: int, max_steps=60000,
                      chunk=2000, plant=None):
    params, loss_fn, sample_fn = xor_setup(seed)
    _, steps, solved = train_until(
        loss_fn, params, cfg, sample_fn, max_steps=max_steps,
        threshold_fn=lambda p: xor_mse(p) < 0.04, chunk=chunk, plant=plant)
    return steps if solved else None


def median(vals):
    vals = sorted(vals)
    return vals[len(vals) // 2] if vals else None
