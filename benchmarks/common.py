"""Shared benchmark helpers: scan-driven MGD training with early stopping."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import MGDConfig, make_mgd_epoch, mgd_init, mse
from repro.data import tasks
from repro.data.pipeline import dataset_sampler
from repro.models.simple import mlp_apply, mlp_init


def train_until(loss_fn, params, cfg: MGDConfig, sample_fn, *,
                max_steps: int, threshold_fn: Callable,
                chunk: int = 2000, plant=None):
    """Run MGD in jitted chunks until threshold_fn(params) or budget.
    ``plant`` optionally trains against an explicit hardware device.

    Returns (params, steps_used, solved).
    """
    run = make_mgd_epoch(loss_fn, cfg, chunk, sample_fn, plant=plant)
    state = mgd_init(params, cfg)
    steps = 0
    while steps < max_steps:
        params, state, _ = run(params, state)
        steps += chunk
        if threshold_fn(params):
            return params, steps, True
    return params, steps, False


def xor_mse(params):
    x, y = tasks.xor_dataset()
    return float(mse(mlp_apply(params, x), y))


def xor_setup(seed: int):
    x, y = tasks.xor_dataset()
    params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])  # noqa: E731
    return params, loss_fn, dataset_sampler(x, y, 1)


def time_to_solve_xor(cfg: MGDConfig, seed: int, max_steps=60000,
                      chunk=2000, plant=None):
    params, loss_fn, sample_fn = xor_setup(seed)
    _, steps, solved = train_until(
        loss_fn, params, cfg, sample_fn, max_steps=max_steps,
        threshold_fn=lambda p: xor_mse(p) < 0.04, chunk=chunk, plant=plant)
    return steps if solved else None


def median(vals):
    vals = sorted(vals)
    return vals[len(vals) // 2] if vals else None
