"""Fused probe execution path: steps/sec + modeled HBM weight traffic,
materialized vs fused, on the MLP and transformer configs.

The fused path's claim is a *memory-roofline* one: an MGD probe should cost
the same weight HBM reads as inference.  The materializing baseline pays,
per probe sign, a read of W to build θ+θ̃ plus a read of the materialized
θ+θ̃ inside the matmul (≈2× inference W-bytes; central mode doubles it to
≈4× per antithetic pair).  The fused kernels regenerate the signs in VMEM —
one read of W per probe (forward) and, with the pair kernel, one read per
probe *pair* (central).  Wall-clock steps/sec on a CPU interpret backend is
reported for completeness but measures the Pallas interpreter, not the TPU
kernel; the bytes model is the hardware-relevant number and feeds the
roofline report (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.api import DriverConfig, driver, make_epoch
from repro.core import mse
from repro.core.utils import tree_size
from repro.models.simple import make_mlp_probe_fn, mlp_apply, mlp_init

STEPS = 60          # measured steps per path (after one warm-up chunk)
CHUNK = 20


def _weight_bytes(params):
    """(matmul-weight bytes, other bytes) — ndim≥2 leaves ride the kernels."""
    wb = ob = 0
    for leaf in jax.tree_util.tree_leaves(params):
        n = leaf.size * leaf.dtype.itemsize
        if leaf.ndim >= 2:
            wb += n
        else:
            ob += n
    return wb, ob


def _modeled_reads(mode: str, fused: bool) -> float:
    """Weight HBM reads per probe step, in units of one inference pass.

    materialized probe: read W (θ+θ̃ build) + read θ+θ̃ (matmul) = 2×;
    fused probe: 1×; fused central pair shares the read → 1× per pair.
    """
    per_sign = 1.0 if fused else 2.0
    signs = 2 if mode == "central" else 1
    if fused and mode == "central":
        return 1.0                     # pair kernel: one pass over W
    return per_sign * signs


def _timed_run(run, params, state, steps):
    params, state, _ = run(params, state)          # warm-up + compile
    t0 = time.perf_counter()
    done = 0
    while done < steps:
        params, state, _ = run(params, state)
        done += CHUNK
    jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
    return done / (time.perf_counter() - t0)


def _bench_mlp(mode, fused):
    sizes = (64, 64, 10)
    key = jax.random.PRNGKey(0)
    params = mlp_init(key, sizes)
    x = jax.random.normal(jax.random.fold_in(key, 1), (32, sizes[0]))
    y = jax.nn.one_hot(
        jax.random.randint(jax.random.fold_in(key, 2), (32,), 0, sizes[-1]),
        sizes[-1])
    batch = {"x": x, "y": y}
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])  # noqa: E731
    cfg = DriverConfig(mode=mode, dtheta=1e-3, eta=1e-2, fused=fused,
                       kernel_impl=None if jax.default_backend() == "tpu"
                       else "interpret")
    mgd = driver("discrete", cfg, loss_fn,
                 probe_fn=make_mlp_probe_fn() if fused else None)
    run = make_epoch(mgd, CHUNK, lambda i: batch)
    sps = _timed_run(run, params, mgd.init(params), STEPS)
    return params, sps


def _bench_transformer(mode, fused):
    from repro.configs import get_smoke_config
    from repro.models import make_transformer_probe_fn, model_init, model_loss
    cfg_a = get_smoke_config("qwen3-14b").replace(dtype="float32")
    params = model_init(cfg_a, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg_a.vocab)
    batch = {"tokens": toks, "labels": toks}
    loss_fn = lambda p, b: model_loss(p, cfg_a, b)  # noqa: E731
    cfg = DriverConfig(mode=mode, dtheta=1e-3, eta=1e-2, fused=fused,
                       kernel_impl=None if jax.default_backend() == "tpu"
                       else "interpret")
    mgd = driver("discrete", cfg, loss_fn,
                 probe_fn=(make_transformer_probe_fn(cfg_a)
                           if fused else None))
    run = make_epoch(mgd, CHUNK, lambda i: batch)
    sps = _timed_run(run, params, mgd.init(params), STEPS)
    return params, sps


def run():
    rows = []
    for model_name, bench in (("mlp", _bench_mlp),
                              ("transformer", _bench_transformer)):
        for mode in ("forward", "central"):
            sps = {}
            params = None
            for fused in (False, True):
                params, sps[fused] = bench(mode, fused)
            wb, ob = _weight_bytes(params)
            for fused in (False, True):
                reads = _modeled_reads(mode, fused)
                rows.append({
                    "bench": "fused_probe",
                    "name": f"{model_name}_{mode}_"
                            f"{'fused' if fused else 'materialized'}",
                    "value": round(sps[fused], 3),
                    "detail": (f"steps/s ({jax.default_backend()}); modeled "
                               f"W-reads/probe-step {reads:.0f}x inference "
                               f"({reads * wb / 1e6:.2f} MB of "
                               f"{wb / 1e6:.2f} MB weights; "
                               f"{tree_size(params)} params)"),
                })
            rows.append({
                "bench": "fused_probe",
                "name": f"{model_name}_{mode}_wread_ratio",
                "value": _modeled_reads(mode, False) / _modeled_reads(
                    mode, True),
                "detail": "materialized/fused modeled W-read ratio "
                          "(central pair target: 4x -> 1x)",
            })
    return rows
