#!/usr/bin/env sh
# CI-style smoke: kernel correctness + driver-API parity + fused-probe path
# + bench configs, all on the CPU/interpret backend.  Run from the repo
# root:
#   sh benchmarks/smoke.sh
#
# Failure propagation is EXPLICIT: every step runs through `run`, which
# exits with the failing command's status immediately — not an artifact
# of `set -e` semantics, which differ across sh implementations (compound
# commands, command substitutions).  CI asserts the propagation with
# `sh benchmarks/smoke.sh --self-test-fail`, a deliberately broken
# benchmark selection that MUST exit non-zero.
#
# Artifacts land in artifacts/bench-fresh (override with SMOKE_OUT) —
# NEVER in artifacts/bench/, which holds the COMMITTED baselines that
# benchmarks/check_regression.py gates fresh runs against; refreshing a
# baseline is an explicit copy + git commit, not a smoke side effect.
set -u
cd "$(dirname "$0")/.."
export PYTHONPATH="src:tools${PYTHONPATH:+:$PYTHONPATH}"
OUT="${SMOKE_OUT:-artifacts/bench-fresh}"

run() {
    "$@" || {
        status=$?
        echo "smoke.sh: FAILED (exit $status): $*" >&2
        exit "$status"
    }
}

if [ "${1:-}" = "--self-test-fail" ]; then
    # deliberately broken step: an unknown --only selection exits 2;
    # reaching the echo below would mean failures do NOT propagate
    run python -m benchmarks.run --only no_such_benchmark
    echo "smoke.sh: self-test reached unreachable code — failure did not propagate" >&2
    exit 0
fi

# invariant lint first: cheapest gate, catches host-boundary/determinism
# violations before any benchmark spends minutes reproducing them
run python -m mgdlint src tests benchmarks
run python -m pytest -x -q tests/test_kernels.py tests/test_fused_probe.py \
    tests/test_driver_api.py
run python -m benchmarks.run --list
run python -m benchmarks.run --only fused_probe --seed 0 --out "$OUT"
# scaling laws: 1/k variance on a virtual 8-device mesh + the
# batch-sharded mesh == chip-farm bit-equality row (gated at zero)
run env XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m benchmarks.run --only scaling_laws --smoke --seed 0 --out "$OUT"
# chip farm: host-thread probe fan-out exercised on every PR
run python -m benchmarks.run --only farm_scaling --smoke --seed 0 --out "$OUT"
# farm backends: each backend's GIL-bound throughput sweep runs on its
# own, so a broken backend names itself in the failing command
run python -m benchmarks.farm_scaling --backend thread --smoke
run python -m benchmarks.farm_scaling --backend process --smoke
# drift/aging: MGD re-trim vs scheduled recal vs no mitigation
run python -m benchmarks.run --only drift_aging --smoke --seed 0 --out "$OUT"
# fault tolerance: hangs/crashes/garbage masked, retried, quarantined
run python -m benchmarks.run --only fault_tolerance --smoke --seed 0 --out "$OUT"
# online serving: live-traffic inference with background MGD re-trim —
# torn-swap + resume invariants gate at zero, drift accuracy gated
run python -m benchmarks.run --only online_serving --smoke --seed 0 --out "$OUT"
run python examples/chip_in_the_loop.py --chips 4 --steps 300 --eval-every 150
run python examples/chip_in_the_loop.py --drift 0.02 --steps 200 --eval-every 100
run python examples/chip_in_the_loop.py --chips 4 --fault-rate 0.1 --steps 200 --eval-every 100
echo "smoke OK"
