#!/usr/bin/env sh
# CI-style smoke: kernel correctness + driver-API parity + fused-probe path
# + one bench config, all on the CPU/interpret backend.  Run from the repo
# root:
#   sh benchmarks/smoke.sh
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q tests/test_kernels.py tests/test_fused_probe.py \
    tests/test_driver_api.py
python -m benchmarks.run --list
python -m benchmarks.run --only fused_probe --seed 0 --out artifacts/bench
# chip farm: host-thread probe fan-out exercised on every PR
python -m benchmarks.run --only farm_scaling --smoke --seed 0 \
    --out artifacts/bench
python examples/chip_in_the_loop.py --chips 4 --steps 300 --eval-every 150
echo "smoke OK"
