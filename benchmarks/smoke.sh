#!/usr/bin/env sh
# CI-style smoke: kernel correctness + fused-probe path + one bench config,
# all on the CPU/interpret backend.  Run from the repo root:
#   sh benchmarks/smoke.sh
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q tests/test_kernels.py tests/test_fused_probe.py
python -m benchmarks.run --only fused_probe --out artifacts/bench
echo "smoke OK"
