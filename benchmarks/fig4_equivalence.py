"""Paper Fig. 4: MGD ≡ backprop on XOR as τ_θ grows.

Reproduces both panels at reduced statistics: cost-vs-epoch for
τ_θ = τ_x ∈ {1, 100} against backprop, and cost-vs-iteration showing the
short-τ_θ data-efficiency/time tradeoff.
"""
from __future__ import annotations

import jax

from repro.api import DriverConfig, driver, make_epoch
from repro.core import mse
from repro.data import tasks
from repro.data.pipeline import dataset_sampler
from repro.models.simple import mlp_apply, mlp_init
from repro.training.train_loop import train_backprop

N_SEEDS = 5


def _mgd_curve(tau, seed, iters=40000, chunk=2000):
    x, y = tasks.xor_dataset()
    params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])  # noqa: E731
    # τ_θ = τ_x = tau: each sample integrated tau steps (batch size 1).
    # G accumulates ∝ τ_θ, so η·τ_θ is held ≈ constant across the sweep
    # (the paper's Fig. 6b max-η ∝ 1/τ_θ observation).
    cfg = DriverConfig(dtheta=1e-2, eta=1.0 / tau if tau > 1 else 1.0,
                       tau_theta=tau, tau_x=tau, seed=seed)
    mgd = driver("discrete", cfg, loss_fn)
    run = make_epoch(mgd, chunk, dataset_sampler(x, y, 1))
    state = mgd.init(params)
    for _ in range(iters // chunk):
        params, state, _ = run(params, state)
    return float(mse(mlp_apply(params, x), y))


def run():
    rows = []
    x, y = tasks.xor_dataset()
    for tau in (1, 100):
        finals = [_mgd_curve(tau, s) for s in range(N_SEEDS)]
        rows.append({
            "bench": "fig4", "name": f"mgd_tau_{tau}_final_cost",
            "value": sorted(finals)[N_SEEDS // 2],
            "detail": f"median of {N_SEEDS} seeds, 40k iterations",
        })
    # backprop reference
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])  # noqa: E731
    finals = []
    for s in range(N_SEEDS):
        params = mlp_init(jax.random.PRNGKey(s), (2, 2, 1))
        res = train_backprop(loss_fn, params,
                             dataset_sampler(x, y, 4), 4000, eta=2.0,
                             log=None)
        finals.append(float(mse(mlp_apply(res.params, x), y)))
    rows.append({"bench": "fig4", "name": "backprop_final_cost",
                 "value": sorted(finals)[N_SEEDS // 2],
                 "detail": f"median of {N_SEEDS} seeds, 4k steps"})
    return rows
