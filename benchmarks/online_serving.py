"""Online-serving load test: latency/QPS under simulated traffic, with
accuracy-under-drift as the quality axis.

The drift study (``benchmarks/drift_aging.py``) showed continuous MGD
re-trim holds ~0.9 of drift-free accuracy where the unmitigated device
collapses.  This benchmark runs the same regime through the PRODUCT —
``repro.serve``'s ``OnlineService`` — so the numbers measure the serving
tier end to end:

* **Load test** — N requests fired from concurrent client threads
  through the fixed-slot dispatcher; p50/p99 request latency and
  sustained QPS (informational: machine-dependent).
* **Accuracy under drift** (CI-gated) — a ``DriftingPlant`` aging at the
  σ_d where the drift study's unmitigated device collapses serves eval
  traffic while labeled traffic flows into the replay buffer:
    - ``no_trim``      — the trimmer probes but never corrects (η = 0):
      served accuracy must collapse below half the above-chance margin.
    - ``online_trim``  — background MGD re-trim from replay samples with
      fenced publishes: served accuracy must hold ≥ ~0.85 of drift-free.
  Accuracy is measured from the service's actual responses, not from a
  parameter readout — swaps, batching and the alive-mask path are all
  inside the measurement.
* **Torn swaps** (CI-gated, zero tolerance) — a publisher hammers
  parameter swaps while clients decode; every response is checked for
  leaf consistency against its stamped snapshot version.
* **Resume bit-exactness** (CI-gated, zero tolerance) — serve → trim →
  checkpoint → restore → trim equals the uninterrupted trajectory, f32.

Trim steps for the gated rows run synchronously (``service.trim``) so
the trajectory is counter-keyed deterministic; the load-test rows run
the background trainer thread to exercise real concurrency.
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.api import DriverConfig
from repro.core.cost import mse
from repro.data import tasks
from repro.data.pipeline import generator_sampler
from repro.hardware import DriftingPlant, IdealPlant
from repro.models.simple import mlp_apply, mlp_init
from repro.serving.online import OnlineService, ServiceConfig, TrimConfig
from repro.training import TrainLoopConfig, train_mgd

SIZES = (49, 4, 4)
CHANCE = 0.25                       # 4-way nist7x7 classification
SIGMA_D = 0.08                      # the drift study's no-mitigation collapse
COLLAPSE_FRAC = 0.5
ETA_RETRIM = 1.6
PROBES_RETRIM = 4
REF_STEPS = 2000
WINDOW = 1000                       # trim steps per drift strategy
SLOTS = 16


def _loss(params, batch):
    return mse(mlp_apply(params, batch["x"]), batch["y"])


def _predict(params, batch):
    return mlp_apply(params, batch["x"])


def _service_cfg(**kw):
    base = dict(slots=SLOTS, batch_window_s=0.002, replay_capacity=2048,
                trim_batch=8, min_fill=64, publish_every=10)
    base.update(kw)
    return ServiceConfig(**base)


def _reference(seed):
    """Drift-free MGD training → (θ*, A₀)."""
    params = mlp_init(jax.random.PRNGKey(seed), SIZES)
    cfg = DriverConfig(dtheta=2e-2, eta=0.4, mode="central", seed=seed)
    res = train_mgd(_loss, params, cfg,
                    generator_sampler(tasks.nist7x7_batch, 8, seed=11),
                    REF_STEPS,
                    loop=TrainLoopConfig(chunk=REF_STEPS // 4, log=None))
    xe, ye = tasks.nist7x7_batch(jax.random.PRNGKey(99), 512)
    return res.params, _served_free_accuracy(res.params, xe, ye)


def _served_free_accuracy(params, xe, ye):
    pred = np.argmax(np.asarray(mlp_apply(params, xe)), -1)
    return float(np.mean(pred == np.argmax(np.asarray(ye), -1)))


def _serve_eval_accuracy(svc, xe, ye):
    """Accuracy measured from the service's responses (no feedback —
    eval traffic must not enter the replay buffer)."""
    futs = [svc.submit({"x": np.asarray(xe[i])}) for i in range(len(xe))]
    outs = np.stack([np.asarray(f.result(60).output) for f in futs])
    return float(np.mean(np.argmax(outs, -1) == np.argmax(np.asarray(ye),
                                                          -1)))


def _feed_labeled(svc, seed, batches, batch_size=8):
    """Serve labeled traffic (predictions + eventual cost feedback) —
    this is what fills the replay buffer that feeds the trimmer."""
    futs = []
    for b in range(batches):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), b)
        x, y = tasks.nist7x7_batch(key, batch_size)
        x, y = np.asarray(x), np.asarray(y)
        futs += [svc.submit({"x": x[i]}, feedback={"y": y[i]})
                 for i in range(batch_size)]
    for f in futs:
        f.result(60)


def _drift_strategy(strategy, theta_star, seed):
    """Serve eval traffic from a drifting device for WINDOW trim steps;
    returns tail served accuracy (mean of last 3 evals)."""
    trim_eta = ETA_RETRIM if strategy == "online_trim" else 0.0
    probes = PROBES_RETRIM if strategy == "online_trim" else 1
    plant = DriftingPlant(IdealPlant(_loss), mode="walk",
                          drift_rate=SIGMA_D, seed=seed + 41)
    trim = TrimConfig(DriverConfig(dtheta=2e-2, eta=trim_eta, probes=probes,
                                   mode="central", seed=seed),
                      _loss, plant=plant)
    xe, ye = tasks.nist7x7_batch(jax.random.PRNGKey(99), 512)
    svc = OnlineService(_predict, theta_star, _service_cfg(), trim=trim)
    svc.start(background_trim=False)   # synchronous trim → deterministic
    accs = []
    try:
        _feed_labeled(svc, seed, batches=16)     # 128 examples ≥ min_fill
        phases = 8
        for phase in range(phases):
            _feed_labeled(svc, seed + 1000 + phase, batches=4)
            took = svc.trim(WINDOW // phases)
            assert took == WINDOW // phases, (strategy, phase, took)
            svc.publish()              # fresh snapshot for the eval pass
            accs.append(_serve_eval_accuracy(svc, xe, ye))
        svc.fence()
    finally:
        svc.close()
    return float(np.mean(accs[-3:]))


def _load_test(theta_star, requests, clients=4):
    """Fire ``requests`` total requests from ``clients`` threads through
    a trim-free service; report latency percentiles and sustained QPS."""
    svc = OnlineService(_predict, theta_star, _service_cfg())
    svc.start()
    xs = np.asarray(tasks.nist7x7_batch(jax.random.PRNGKey(7),
                                        max(requests // 8, 1))[0])
    lats = []
    lats_lock = threading.Lock()

    def client(n, seed):
        rng = np.random.default_rng(seed)
        futs = [svc.submit({"x": xs[rng.integers(0, len(xs))]})
                for _ in range(n)]
        got = [f.result(60).latency_s for f in futs]
        with lats_lock:
            lats.extend(got)

    try:
        svc.serve({"x": xs[0]})        # compile outside the timed window
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client,
                                    args=(requests // clients, c))
                   for c in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = svc.stats()
    finally:
        svc.close()
    lat = np.asarray(lats, np.float64)
    return {
        "latency_p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "latency_p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "sustained_qps": len(lats) / wall,
        "mean_batch_fill": stats["served"] / max(stats["batches"], 1),
    }


def _torn_swap_hammer(requests):
    """Concurrent publish/decode: count responses whose parameter leaves
    disagree or whose decoded value mismatches the stamped version."""
    import jax.numpy as jnp

    def paired_predict(p, batch):
        a = jnp.sum(batch["x"] * 0) + p["a"][0]
        return jnp.stack(
            [jnp.broadcast_to(a - p["b"][0], batch["x"].shape[:1]),
             jnp.broadcast_to(a, batch["x"].shape[:1])], -1)

    params = {"a": jnp.zeros((256,)), "b": jnp.zeros((256,))}
    svc = OnlineService(paired_predict, params,
                        _service_cfg(slots=8, batch_window_s=0.0005))
    svc.start()
    stop = threading.Event()

    def publisher():
        v = 0
        while not stop.is_set():
            v += 1
            fill = jnp.full((256,), float(v))
            svc.store.publish({"a": fill, "b": fill})

    pub = threading.Thread(target=publisher, daemon=True)
    pub.start()
    torn = 0
    try:
        futs = [svc.submit({"x": np.zeros(3, np.float32)})
                for _ in range(requests)]
        for f in futs:
            r = f.result(60)
            if float(r.output[0]) != 0.0 or \
                    float(r.output[1]) != float(r.version):
                torn += 1
    finally:
        stop.set()
        pub.join(timeout=30)
        svc.close()
    return torn


def _resume_bitexact(seed, tmpdir):
    """serve → trim(10, ckpt@5) → restore → trim(5)  ==  trim(15)."""
    theta0 = mlp_init(jax.random.PRNGKey(seed), SIZES)

    def make(d):
        trim = TrimConfig(DriverConfig(dtheta=2e-2, eta=ETA_RETRIM,
                                       mode="central", seed=seed), _loss)
        cfg = _service_cfg(min_fill=8, checkpoint_dir=d, checkpoint_every=5)
        svc = OnlineService(_predict, theta0, cfg, trim=trim)
        return svc.start(background_trim=False)

    d = f"{tmpdir}/serve_ck"
    a = make(d)
    _feed_labeled(a, seed, batches=2)
    a.trim(10)
    a.close()
    b = make(d)
    assert b.resumed_step == 10, b.resumed_step
    b.trim(5)
    w_resumed = jax.tree_util.tree_leaves(b.trimmer.params)
    b.close()
    c = make(f"{tmpdir}/serve_ck_straight")
    _feed_labeled(c, seed, batches=2)
    c.trim(15)
    w_straight = jax.tree_util.tree_leaves(c.trimmer.params)
    c.close()
    exact = all(np.array_equal(np.asarray(x), np.asarray(y))
                for x, y in zip(w_resumed, w_straight))
    return 1.0 if exact else 0.0


def run(seed: int = 0, smoke: bool = False):
    import tempfile

    requests = 512 if smoke else 2048
    rows = []

    theta_star, a0 = _reference(seed)
    collapse_acc = CHANCE + COLLAPSE_FRAC * (a0 - CHANCE)
    rows.append({
        "bench": "online_serving", "name": "driftfree_accuracy",
        "value": a0,
        "detail": f"reference MGD training, {REF_STEPS} steps, nist7x7",
    })

    # -- load test (informational: machine-dependent) -----------------------
    load = _load_test(theta_star, requests)
    for k, v in load.items():
        rows.append({
            "bench": "online_serving", "name": k, "value": v,
            "detail": f"{requests} requests, 4 client threads, "
                      f"{SLOTS} decode slots",
        })

    # -- accuracy under drift (the quality axis; gated) ---------------------
    tail = {}
    for strategy in ("no_trim", "online_trim"):
        tail[strategy] = _drift_strategy(strategy, theta_star, seed)
        rows.append({
            "bench": "online_serving",
            "name": f"served_acc_{strategy}_sigma{SIGMA_D:g}",
            "value": tail[strategy],
            "detail": f"tail served accuracy after {WINDOW} trim steps on "
                      f"a drifting plant (OU walk sigma_d={SIGMA_D:g})",
        })
    rows.append({
        "bench": "online_serving", "name": "no_trim_collapsed",
        "value": 1.0 if tail["no_trim"] < collapse_acc else 0.0,
        "detail": f"1.0 iff no-trim served accuracy fell below half the "
                  f"above-chance margin ({collapse_acc:.3f})",
    })
    rows.append({
        "bench": "online_serving", "name": "serve_trim_hold_frac",
        "value": tail["online_trim"] / a0,
        "detail": f"served-while-trimming accuracy / drift-free A0 at "
                  f"sigma_d={SIGMA_D:g} (acceptance: >= 0.85)",
    })

    # -- consistency invariants (gated at zero tolerance) -------------------
    rows.append({
        "bench": "online_serving", "name": "torn_swaps",
        "value": float(_torn_swap_hammer(max(requests // 2, 256))),
        "detail": "responses observing a mixed parameter tree under a "
                  "concurrent publish hammer (must be 0)",
    })
    with tempfile.TemporaryDirectory() as tmp:
        rows.append({
            "bench": "online_serving", "name": "resume_bitexact",
            "value": _resume_bitexact(seed, tmp),
            "detail": "serve->trim->checkpoint->restore->trim equals the "
                      "uninterrupted trajectory (f32)",
        })
    return rows
