"""Paper Fig. 7: the four perturbation types train XOR at comparable speed
(fixed-bandwidth feedback argument)."""
from __future__ import annotations

from repro.core import MGDConfig

from .common import median, time_to_solve_xor

N_SEEDS = 4
TYPES = ("rademacher", "walsh", "sequential", "sinusoidal")


def run():
    """Paper protocol: τ_x = 250 (sample held while the codes integrate),
    τ_θ = 1, one shared η for every type.  Deterministic codes (Walsh,
    sinusoidal) NEED the long τ_x — their orthogonality is only realized
    over a full code period, so sample churn at τ_x = 1 aliases with the
    code structure (verified: Walsh fails at τ_x = 1, works here)."""
    rows = []
    for ptype in TYPES:
        cfg = MGDConfig(ptype=ptype, dtheta=1e-2, eta=0.2, tau_theta=1,
                        tau_x=250)
        times = [time_to_solve_xor(cfg, s, max_steps=120000, chunk=10000)
                 for s in range(N_SEEDS)]
        solved = [t for t in times if t is not None]
        rows.append({
            "bench": "fig7", "name": f"{ptype}_steps_to_solve",
            "value": median(solved) if solved else -1,
            "detail": f"{len(solved)}/{N_SEEDS} solved (eta=0.2 shared); "
                      "paper: all four types approximately equivalent",
        })
    return rows
