"""Paper Fig. 5: angle between G and the true gradient vs integration time
for 2-bit parity (9 params), 4-bit parity (25 params), NIST7x7 (220)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import DriverConfig, driver
from repro.core import mse
from repro.core.forward_grad import gradient_angle, true_gradient
from repro.data import tasks
from repro.models.simple import mlp_apply, mlp_init

CHECKPOINTS = (100, 1000, 10000)
N_SEEDS = 5


def _angles(sizes, batch, seeds=N_SEEDS, iters=max(CHECKPOINTS)):
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])  # noqa: E731
    out = {t: [] for t in CHECKPOINTS}
    for seed in range(seeds):
        params = mlp_init(jax.random.PRNGKey(seed), sizes)
        cfg = DriverConfig(dtheta=1e-3, eta=0.0, tau_theta=10**9, seed=seed)
        mgd = driver("discrete", cfg, loss_fn)
        state = mgd.init(params)
        step = jax.jit(mgd.step)
        g_true = true_gradient(loss_fn, params, batch)
        p = params
        for t in range(1, iters + 1):
            p, state, _ = step(p, state, batch)
            if t in CHECKPOINTS:
                out[t].append(float(gradient_angle(state.g, g_true)))
    return {t: sorted(v)[len(v) // 2] for t, v in out.items()}


def run():
    rows = []
    for name, sizes, data in [
        ("parity2", (2, 2, 1), tasks.parity_dataset(2)),
        ("parity4", (4, 4, 1), tasks.parity_dataset(4)),
        ("nist7x7", (49, 4, 4), tasks.nist7x7_batch(jax.random.PRNGKey(0),
                                                    64)),
    ]:
        batch = {"x": data[0], "y": data[1]}
        angles = _angles(sizes, batch)
        for t, a in angles.items():
            rows.append({"bench": "fig5", "name": f"{name}_angle_t{t}",
                         "value": a, "detail": "median rad; expect "
                         "monotone decrease with t, larger nets slower"})
    return rows
