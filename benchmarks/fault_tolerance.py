"""Fault tolerance: the chip farm surviving hangs, crashes and garbage.

The paper's deployment endgame is training on *imperfect physical
hardware* — and real instruments hang, crash and return garbage, not
just Gaussian noise; k-chip probe parallelism multiplies that fault
surface by k.  This benchmark sweeps fault kind × host-boundary policy
{none, retry, retry+quarantine, +robust-aggregation} on nist7x7 farms
and records how gracefully accuracy degrades:

* ``fault_free_accuracy`` — the clean farm's accuracy (the yardstick).
* ``acc_none_silent`` — silent corruption (NaN + outlier costs) with NO
  policy: one NaN poisons the averaged update for every chip and the
  run collapses.  Informational: it demonstrates the failure mode.
* ``hold_frac_retry_transient`` — 10% transient faults healed by
  retries.  Counter-keyed readouts make a successful retry return the
  identical value the fault-free run reads, and σ_θ = 0 silences the
  only live-RNG stream, so this trajectory is BIT-IDENTICAL to the
  fault-free one: the hold fraction is exactly 1.0.
* ``hold_frac_full_silent`` — 10% silent faults under the full policy
  (retry + quarantine + MAD aggregation over the gathered scalars).
  GATED ≥ 0.95 in-benchmark: NaNs are rejected at the boundary and
  retried, finite outliers only the statistics can catch.
* ``hold_frac_quarantine_broken_chip`` — chip 3 dies permanently at
  step 20; quarantine stops burning (retries+1)×timeout on it every
  step while the masked average (η-rescaling rule) keeps training on
  the 3 survivors.  ``broken_chip_attempt_frac`` records the I/O saved.
* ``hang_stall_s`` — a chip that HANGS (sleep > timeout) stalls its
  step by at most the configured timeout, never hang_s, never forever.
* ``resume_bitexact`` — checkpoint/resume through injected faults:
  retries are host-side, the traced trajectory is a pure function of
  the gathered costs, so resume == uninterrupted, bit for bit.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from repro.api import DriverConfig, driver
from repro.data import tasks
from repro.data.pipeline import generator_sampler
from repro.hardware import (ChipFarm, FaultPolicy, FaultSpec, FaultyChip,
                            simulated_chip_farm)
from repro.models.simple import mlp_init
from repro.training.train_loop import train_mgd

K = 4
SIZES = (49, 4, 4)
RATE = 0.10                       # headline transient/silent fault rate
HOLD_TARGET = 0.95                # full policy must keep ≥95% of clean acc


def _policy(**kw):
    base = dict(timeout_s=5.0, retries=3, backoff_s=0.01,
                backoff_factor=2.0, backoff_max_s=0.1)
    base.update(kw)
    return FaultPolicy(**base)


# the sweep's policy ladder: nothing → retry → +quarantine → +robust agg
POLICIES = {
    "none": None,
    "retry": _policy(),
    "retry_quarantine": _policy(quarantine_after=4, reprobe_every=60),
    "full": _policy(quarantine_after=4, reprobe_every=60,
                    aggregate="mad", mad_threshold=8.0),
}

TRANSIENT = FaultSpec(transient=RATE)
SILENT = FaultSpec(nan=RATE / 2, outlier=RATE / 2, outlier_scale=50.0)


def _farm(seed, steps, *, faults=None, policy=None):
    # σ_θ = 0: the persistent-write draw is the only live-RNG stream;
    # silencing it makes transient-fault + retry runs BIT-identical to
    # the fault-free run (readouts are (step, tag) counter-keyed)
    return simulated_chip_farm(K, SIZES, base_seed=100 * seed, sigma_a=0.15,
                               sigma_theta=0.0, sigma_c=1e-4,
                               faults=faults, fault_seed=1000 + seed,
                               fault_policy=policy)


def _train(farm, seed, steps):
    cfg = DriverConfig(dtheta=2e-2, eta=0.125 * K, mode="central", seed=seed)
    params = mlp_init(jax.random.PRNGKey(seed), SIZES)
    res = train_mgd(None, params, cfg,
                    generator_sampler(tasks.nist7x7_batch, 8, seed=11 + seed),
                    steps, algorithm="probe_parallel_external", plant=farm,
                    chunk=max(steps // 4, 1), log=None)
    xe, ye = tasks.nist7x7_batch(jax.random.PRNGKey(99), 512)
    acc = farm.measure_accuracy(res.params,
                                {"x": np.asarray(xe), "y": np.asarray(ye)})
    return float(acc), res


def _sweep_rows(seed, steps):
    rows = []
    acc_clean, _ = _train(_farm(seed, steps), seed, steps)
    rows.append({"bench": "fault_tolerance", "name": "fault_free_accuracy",
                 "value": acc_clean,
                 "detail": f"k={K} nist7x7 farm, {steps} steps, no faults"})

    # the failure mode: silent NaN/outlier corruption, no policy at all
    acc_none, _ = _train(_farm(seed, steps, faults=SILENT), seed, steps)
    rows.append({"bench": "fault_tolerance", "name": "acc_none_silent",
                 "value": acc_none,
                 "detail": f"{RATE:.0%} NaN/outlier faults, no policy — "
                           f"one NaN poisons every chip's update "
                           f"(clean: {acc_clean:.3f})"})

    # transient faults healed by retries: bit-identical to fault-free
    acc_retry, _ = _train(
        _farm(seed, steps, faults=TRANSIENT, policy=POLICIES["retry"]),
        seed, steps)
    hold_retry = acc_retry / acc_clean if acc_clean else 0.0
    rows.append({"bench": "fault_tolerance",
                 "name": "hold_frac_retry_transient", "value": hold_retry,
                 "detail": f"{RATE:.0%} transient faults + retry policy; "
                           f"counter-keyed retries make this exactly 1.0"})
    if hold_retry != 1.0:
        raise RuntimeError(
            f"transient faults healed by retries must be bit-invisible "
            f"(hold fraction 1.0), got {hold_retry}")

    # the headline: silent corruption under the full policy
    farm_full = _farm(seed, steps, faults=SILENT, policy=POLICIES["full"])
    acc_full, _ = _train(farm_full, seed, steps)
    hold_full = acc_full / acc_clean if acc_clean else 0.0
    rows.append({"bench": "fault_tolerance", "name": "hold_frac_full_silent",
                 "value": hold_full,
                 "detail": f"{RATE:.0%} NaN/outlier faults + retry + "
                           f"quarantine + MAD aggregation; "
                           f"{farm_full.fault_summary()['by_kind']}"})
    if hold_full < HOLD_TARGET:
        raise RuntimeError(
            f"full policy held only {hold_full:.3f} of fault-free accuracy "
            f"at {RATE:.0%} silent faults (target ≥ {HOLD_TARGET})")

    # a permanently-broken chip: quarantine + masked average (η rescale)
    broken = FaultSpec(transient=1.0, only_steps=(20, 10 ** 9))
    specs = [None] * (K - 1) + [broken]
    farm_q = _farm(seed, steps, faults=specs,
                   policy=POLICIES["retry_quarantine"])
    acc_broken, _ = _train(farm_q, seed, steps)
    rows.append({"bench": "fault_tolerance",
                 "name": "hold_frac_quarantine_broken_chip",
                 "value": acc_broken / acc_clean if acc_clean else 0.0,
                 "detail": f"chip {K-1} dies at step 20; survivors train "
                           f"on the masked average; "
                           f"{farm_q.fault_summary()['by_kind']}"})
    broken_chip = farm_q.devices[-1]
    assert isinstance(broken_chip, FaultyChip)
    attempt_frac = broken_chip.readouts / steps
    rows.append({"bench": "fault_tolerance", "name": "broken_chip_attempt_frac",
                 "value": attempt_frac,
                 "detail": f"broken chip readout attempts per step; without "
                           f"quarantine every step would burn "
                           f"{POLICIES['retry_quarantine'].retries + 1} "
                           f"attempts (+timeouts) on it"})
    return rows


def _hang_row():
    """A hung chip stalls one step by ≈timeout_s, not hang_s: tiny xor
    farm, chip 0 hangs 1.0 s at step 1, policy timeout 0.2 s."""
    x, y = tasks.xor_dataset()
    batch = {"x": x, "y": y}
    hang_s, timeout_s = 1.0, 0.2
    devices = [FaultyChip(
        _small_chip(s), FaultSpec(hang=1.0, hang_s=hang_s,
                                  only_steps=(1, 2)) if s == 0 else
        FaultSpec(), seed=s) for s in range(3)]
    farm = ChipFarm(devices, fault_policy=_policy(timeout_s=timeout_s,
                                                  retries=0))
    cfg = DriverConfig(dtheta=1e-2, eta=0.3, mode="central", seed=0)
    mgd = driver("probe_parallel_external", cfg, plant=farm)
    params = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
    p, s = params, mgd.init(params)
    p, s, _ = mgd.step(p, s, batch)        # step 0: compile + warm up
    jax.block_until_ready(p)
    t0 = time.monotonic()
    p, s, m = mgd.step(p, s, batch)        # step 1: chip 0 hangs
    jax.block_until_ready(p)
    stall = time.monotonic() - t0
    if stall >= 0.85 * hang_s:
        raise RuntimeError(
            f"hung chip stalled the step {stall:.2f}s — the {timeout_s}s "
            f"timeout did not bound it (hang_s={hang_s}s)")
    if int(m["n_valid"]) != 2:
        raise RuntimeError(f"hung chip was not masked: n_valid="
                           f"{int(m['n_valid'])}")
    return {"bench": "fault_tolerance", "name": "hang_stall_s",
            "value": stall,
            "detail": f"step wall-clock with one chip hanging {hang_s}s "
                      f"under timeout_s={timeout_s}; n_valid=2/3"}


def _small_chip(seed):
    from repro.hardware import SimulatedAnalogChip
    return SimulatedAnalogChip((2, 2, 1), seed=seed, sigma_a=0.1,
                               sigma_theta=0.0, sigma_c=1e-3)


def _resume_row(seed):
    """Checkpoint/resume bit-exactness through transient faults healed
    by retries (σ_θ = 0: the traced trajectory is a pure function of the
    counter-keyed gathered costs)."""
    x, y = tasks.xor_dataset()
    batch = {"x": x, "y": y}

    def farm():
        return simulated_chip_farm(
            2, (2, 2, 1), base_seed=seed, sigma_a=0.1, sigma_theta=0.0,
            sigma_c=1e-3, faults=FaultSpec(transient=0.15),
            fault_seed=500 + seed, fault_policy=_policy())

    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=seed)
    p0 = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
    sample_fn = lambda i: batch                       # noqa: E731
    cont = train_mgd(None, p0, cfg, sample_fn, 16,
                     algorithm="probe_parallel_external", plant=farm(),
                     chunk=4, log=None)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        train_mgd(None, p0, cfg, sample_fn, 8,
                  algorithm="probe_parallel_external", plant=farm(),
                  chunk=4, log=None, checkpoint_dir=ckpt_dir,
                  checkpoint_every=8)
        res = train_mgd(None, p0, cfg, sample_fn, 16,
                        algorithm="probe_parallel_external", plant=farm(),
                        chunk=4, log=None, checkpoint_dir=ckpt_dir)
    exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(cont.params),
                        jax.tree_util.tree_leaves(res.params)))
    if not exact:
        raise RuntimeError("farm resume through injected faults is not "
                           "bit-exact to the uninterrupted run")
    return {"bench": "fault_tolerance", "name": "resume_bitexact",
            "value": 1.0 if exact else 0.0,
            "detail": "8+8 resumed == 16 uninterrupted, faults injected at "
                      "the same counter-keyed steps, healed by retries"}


def run(seed: int = 0, smoke: bool = False):
    steps = 400 if smoke else 2000
    if os.environ.get("FAULT_TOLERANCE_STEPS"):
        steps = int(os.environ["FAULT_TOLERANCE_STEPS"])
    rows = _sweep_rows(seed, steps)
    rows.append(_hang_row())
    rows.append(_resume_row(seed))
    return rows
