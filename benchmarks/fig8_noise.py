"""Paper Figs. 8–10: noise/defect robustness benchmarks, on hardware plants.

Every imperfect device is an explicit ``repro.hardware`` plant driven
through the one MGD code path (no optimizer-side noise flags):

fig8  — σ_C cost-readout noise (``NoisyPlant``): training time grows,
        then convergence fails.
fig9  — σ_θ write noise (``NoisyPlant``): τ_θ = 100 tolerates noise that
        τ_θ = 1 cannot.
fig10 — σ_a activation defects (defective-device plant): moderate
        defects only slow training.
"""
from __future__ import annotations

import jax

from repro.core import MGDConfig
from repro.data import tasks
from repro.data.pipeline import dataset_sampler
from repro.hardware import noisy_mlp_plant
from repro.models.simple import mlp_init

from .common import median, time_to_solve_xor, train_until

N_SEEDS = 3


def run():
    rows = []
    # fig8: cost-readout noise sweep (device seed = param seed → three
    # different chips, the paper's device-to-device axis)
    for sigma_c in (0.0, 1e-3, 1e-2, 3e-1):
        cfg = MGDConfig(dtheta=1e-2, eta=1.0)
        times = []
        for s in range(N_SEEDS):
            plant = noisy_mlp_plant((2, 2, 1), sigma_c=sigma_c,
                                    dtheta=cfg.dtheta, device_seed=s)
            times.append(time_to_solve_xor(cfg, s, max_steps=60000,
                                           chunk=3000, plant=plant))
        solved = [t for t in times if t is not None]
        rows.append({
            "bench": "fig8", "name": f"sigma_c_{sigma_c}_steps",
            "value": median(solved) if solved else -1,
            "detail": f"{len(solved)}/{N_SEEDS} solved "
                      f"({'IdealPlant' if sigma_c == 0 else 'NoisyPlant'})",
        })
    # fig9: write noise at tau_theta 1 vs 100 (η·τ_θ held constant so the
    # update magnitude matches; the noise-per-write is then relatively
    # τ_θ× smaller for the long integration — paper Fig. 9b/d)
    for tau in (1, 100):
        for sigma_t in (0.1, 0.4):
            cfg = MGDConfig(dtheta=1e-2, eta=1.0 / tau, tau_theta=tau)
            times = []
            for s in range(N_SEEDS):
                plant = noisy_mlp_plant((2, 2, 1), sigma_theta=sigma_t,
                                        dtheta=cfg.dtheta, device_seed=s)
                times.append(time_to_solve_xor(cfg, s, max_steps=60000,
                                               chunk=3000, plant=plant))
            solved = [t for t in times if t is not None]
            rows.append({
                "bench": "fig9",
                "name": f"tau{tau}_sigma_theta_{sigma_t}_converged",
                "value": len(solved) / N_SEEDS,
                "detail": "paper: larger tau_theta suppresses update noise "
                          "(NB the 60k budget is only 600 updates at "
                          "tau=100 — plateau-dominated at xor scale; "
                          "tests/test_noise_robustness.py asserts the "
                          "magnitude mechanism directly)",
            })
    # fig10: activation defects — the defect pattern is part of the device
    # (per-device seed), invisible to the optimizer
    x, y = tasks.xor_dataset()
    for sigma_a in (0.0, 0.1, 0.25):
        solved_count = 0
        for seed in range(N_SEEDS):
            plant = noisy_mlp_plant((2, 2, 1), sigma_a=sigma_a,
                                    device_seed=seed)
            params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
            cfg = MGDConfig(dtheta=1e-2, eta=1.0, seed=seed)

            def thresh(p, plant=plant):
                return float(plant.loss_fn(p, {"x": x, "y": y})) < 0.05

            _, steps, ok = train_until(
                None, params, cfg, dataset_sampler(x, y, 1),
                max_steps=60000, threshold_fn=thresh, chunk=3000,
                plant=plant)
            solved_count += int(ok)
        rows.append({
            "bench": "fig10", "name": f"sigma_a_{sigma_a}_converged",
            "value": solved_count / N_SEEDS,
            "detail": "static per-neuron logistic defects (device plant)",
        })
    return rows
