"""Paper Figs. 8–10: noise/defect robustness benchmarks.

fig8  — cost-signal noise σ_C: training time grows, then convergence fails.
fig9  — update noise σ_θ: τ_θ = 100 tolerates noise that τ_θ = 1 cannot.
fig10 — activation defects σ_a: moderate defects only slow training.
"""
from __future__ import annotations

import jax

from repro.core import MGDConfig, mse
from repro.core.noise import sample_defects
from repro.data import tasks
from repro.data.pipeline import dataset_sampler
from repro.models.simple import mlp_apply, mlp_init

from .common import median, time_to_solve_xor, train_until

N_SEEDS = 3


def run():
    rows = []
    # fig8: cost noise sweep
    for sigma_c in (0.0, 1e-3, 1e-2, 3e-1):
        cfg = MGDConfig(dtheta=1e-2, eta=1.0, cost_noise=sigma_c)
        times = [time_to_solve_xor(cfg, s, max_steps=60000, chunk=3000)
                 for s in range(N_SEEDS)]
        solved = [t for t in times if t is not None]
        rows.append({
            "bench": "fig8", "name": f"sigma_c_{sigma_c}_steps",
            "value": median(solved) if solved else -1,
            "detail": f"{len(solved)}/{N_SEEDS} solved",
        })
    # fig9: update noise at tau_theta 1 vs 100 (η·τ_θ held constant so the
    # update magnitude matches; the noise-per-write is then relatively
    # τ_θ× smaller for the long integration — paper Fig. 9b/d)
    for tau in (1, 100):
        for sigma_t in (0.1, 0.4):
            cfg = MGDConfig(dtheta=1e-2, eta=1.0 / tau, tau_theta=tau,
                            update_noise=sigma_t)
            times = [time_to_solve_xor(cfg, s, max_steps=60000, chunk=3000)
                     for s in range(N_SEEDS)]
            solved = [t for t in times if t is not None]
            rows.append({
                "bench": "fig9",
                "name": f"tau{tau}_sigma_theta_{sigma_t}_converged",
                "value": len(solved) / N_SEEDS,
                "detail": "paper: larger tau_theta suppresses update noise",
            })
    # fig10: activation defects
    x, y = tasks.xor_dataset()
    for sigma_a in (0.0, 0.1, 0.25):
        solved_count = 0
        for seed in range(N_SEEDS):
            defects = [sample_defects(seed, 2, sigma_a),
                       sample_defects(seed + 99, 1, sigma_a)]
            loss_fn = lambda p, b: mse(                      # noqa: E731
                mlp_apply(p, b["x"], defects=defects), b["y"])
            params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
            cfg = MGDConfig(dtheta=1e-2, eta=1.0, seed=seed)

            def thresh(p, d=defects):
                return float(mse(mlp_apply(p, x, defects=d), y)) < 0.05

            _, steps, ok = train_until(
                loss_fn, params, cfg, dataset_sampler(x, y, 1),
                max_steps=60000, threshold_fn=thresh, chunk=3000)
            solved_count += int(ok)
        rows.append({
            "bench": "fig10", "name": f"sigma_a_{sigma_a}_converged",
            "value": solved_count / N_SEEDS,
            "detail": "static per-neuron logistic defects",
        })
    return rows
