"""Hardware-plant robustness curves (EXPERIMENTS.md §Hardware).

One optimizer, many devices: the same driver config drives IdealPlant,
NoisyPlant (σ_C / σ_θ / σ_a), and QuantizedPlant (k-bit DAC writes,
slow-write τ_w, k-bit ADC cost readout) on xor and nist7x7 — the
scenario matrix the plant interface unlocks.  Also projects wall-clock
per-step cost from ``PlantMeta`` latency metadata (Table-3 style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import DriverConfig, driver, make_epoch
from repro.data import tasks
from repro.data.pipeline import dataset_sampler, generator_sampler
from repro.hardware import (PlantMeta, mlp_device_fns, noisy_mlp_plant,
                            quantized_mlp_plant)
from repro.models.simple import mlp_apply, mlp_init

from .common import median, train_until

N_SEEDS = 3
XOR_PLANTS = [
    ("ideal", dict()),
    ("sigma_c_1e-3", dict(sigma_c=1e-3)),
    ("sigma_c_1e-2", dict(sigma_c=1e-2)),
    ("sigma_theta_0.1", dict(sigma_theta=0.1)),
    ("sigma_a_0.15", dict(sigma_a=0.15)),
]
# w_clip=8: the 2-2-1 XOR solution needs |w| ≈ 5–7, so a ±2 swing makes
# CLIPPING the binding constraint (0/3 solve at any bit depth); at ±8 the
# curve measures quantization itself (LSB 16/(2^bits − 1)).
XOR_DACS = [("dac10", dict(bits=10, w_clip=8.0)),
            ("dac8", dict(bits=8, w_clip=8.0)),
            ("dac6", dict(bits=6, w_clip=8.0)),
            ("dac8_tauw4", dict(bits=8, w_clip=8.0, write_tau=4.0))]


def _xor_row(name, plant_fn, detail, seed0=0, mode="forward"):
    """Steps to solve xor ON THE DEVICE: the solved threshold reads the
    plant's loss_fn (defects included) — the optimizer's actual target,
    not a defect-free twin's.  Deliberately PRE-readout-conversion: for
    ADC devices the converter quantizes the training feedback, but
    judging 'solved' on the quantized readout would be undecidable below
    one LSB — the experimenter's bench meter, not the chip's own ADC,
    decides whether training through the ADC found a solution."""
    cfg = DriverConfig(dtheta=1e-2, eta=1.0, mode=mode)
    x, y = tasks.xor_dataset()
    times = []
    for s in range(seed0, seed0 + N_SEEDS):
        plant = plant_fn(s)
        params = mlp_init(jax.random.PRNGKey(s), (2, 2, 1))

        def thresh(p, plant=plant):
            return float(plant.loss_fn(p, {"x": x, "y": y})) < 0.04

        _, steps, ok = train_until(
            None, params, cfg, dataset_sampler(x, y, 1),
            max_steps=60000, threshold_fn=thresh, chunk=3000, plant=plant)
        times.append(steps if ok else None)
    solved = [t for t in times if t is not None]
    return {
        "bench": "hw_plants", "name": f"xor_{name}_steps",
        "value": median(solved) if solved else -1,
        "detail": f"{len(solved)}/{N_SEEDS} solved; {detail}",
    }


def _nist_accuracy(plant, defects, seed, steps=30000, chunk=6000):
    """49-4-4 nist7x7 through ``plant``; accuracy read on the device
    (its defects included) over a fixed eval batch."""
    params = mlp_init(jax.random.PRNGKey(seed), (49, 4, 4))
    cfg = DriverConfig(dtheta=1e-2, eta=0.1, seed=seed)
    sample_fn = generator_sampler(tasks.nist7x7_batch, 8, seed=11 + seed)
    mgd = driver("discrete", cfg, None, plant=plant)
    run = make_epoch(mgd, chunk, sample_fn)
    state = mgd.init(params)
    for _ in range(steps // chunk):
        params, state, _ = run(params, state)
    xe, ye = tasks.nist7x7_batch(jax.random.PRNGKey(99), 512)
    pred = mlp_apply(params, xe, defects=defects)
    return float(jnp.mean((jnp.argmax(pred, -1)
                           == jnp.argmax(ye, -1)).astype(jnp.float32)))


# Mixed-precision READOUT (the DAC's dual): xor cost lives in [0, ~0.3]
# on a unit-range ADC, and the central-mode signal is |C̃| ≈ |g|·Δθ ≈
# 4e-3 at Δθ = 1e-2 — so the 8-bit LSB (3.9e-3) is the last depth where
# the error signal clears one code.  Measured (EXPERIMENTS.md §Hardware):
# ≥8 bits solves in either rounding mode, ≤7 bits solves in neither —
# deterministic rounding floors C̃ (quantization), stochastic rounding
# trades the bias for LSB-scale readout variance (≈ σ_C = LSB/√12,
# which at 7 bits sits in the σ_C ≈ 1e-2 failure band of fig8).  The
# paper Fig. 8 noise cliff, mapped onto ADC bits.
XOR_ADCS = [("adc12_round", dict(bits=12, w_clip=8.0, adc_bits=12)),
            ("adc10_round", dict(bits=12, w_clip=8.0, adc_bits=10)),
            ("adc8_round", dict(bits=12, w_clip=8.0, adc_bits=8)),
            ("adc8_stoch", dict(bits=12, w_clip=8.0, adc_bits=8,
                                adc_mode="stochastic")),
            ("adc7_round", dict(bits=12, w_clip=8.0, adc_bits=7)),
            ("adc7_stoch", dict(bits=12, w_clip=8.0, adc_bits=7,
                                adc_mode="stochastic")),
            ("adc6_round", dict(bits=12, w_clip=8.0, adc_bits=6)),
            ("adc6_stoch", dict(bits=12, w_clip=8.0, adc_bits=6,
                                adc_mode="stochastic"))]


def run(seed: int = 0):
    rows = []
    for name, kw in XOR_PLANTS:
        rows.append(_xor_row(
            name,
            lambda s, kw=kw: noisy_mlp_plant((2, 2, 1), dtheta=1e-2,
                                             device_seed=s, **kw),
            f"NoisyPlant {kw or 'σ=0'}", seed0=seed))
    for name, kw in XOR_DACS:
        rows.append(_xor_row(
            name,
            lambda s, kw=kw: quantized_mlp_plant((2, 2, 1), device_seed=s,
                                                 **kw),
            f"QuantizedPlant {kw}", seed0=seed))
    for name, kw in XOR_ADCS:
        rows.append(_xor_row(
            name,
            lambda s, kw=kw: quantized_mlp_plant((2, 2, 1), device_seed=s,
                                                 **kw),
            f"QuantizedPlant {kw}", seed0=seed, mode="central"))

    # nist7x7: ideal vs full §3.5 device vs 8-bit DAC device
    nist_devices = [
        ("ideal", dict(), dict()),
        ("noisy", dict(sigma_c=1e-4, sigma_theta=0.01, sigma_a=0.15),
         dict()),
        ("dac8", dict(), dict(bits=8)),
    ]
    for name, noisy_kw, dac_kw in nist_devices:
        accs = []
        for dev in range(seed, seed + N_SEEDS):
            sigma_a = noisy_kw.get("sigma_a", 0.0)
            _, _, defects = mlp_device_fns((49, 4, 4), sigma_a=sigma_a,
                                           device_seed=dev)
            if dac_kw:
                plant = quantized_mlp_plant((49, 4, 4), device_seed=dev,
                                            **dac_kw)
            else:
                plant = noisy_mlp_plant((49, 4, 4), dtheta=1e-2,
                                        device_seed=dev, **noisy_kw)
            accs.append(_nist_accuracy(plant, defects, dev))
        rows.append({
            "bench": "hw_plants", "name": f"nist7x7_{name}_accuracy",
            "value": median(accs),
            "detail": f"median of {N_SEEDS} devices, 30k steps",
        })

    # Table-3-style projection from plant metadata
    for name, meta in [
        ("HW1_chip_in_loop", PlantMeta(name="HW1", read_latency_s=1e-3,
                                       external=True)),
        ("HW2_memcompute", PlantMeta(name="HW2", read_latency_s=10e-9)),
    ]:
        rows.append({
            "bench": "hw_plants", "name": f"xor_{name}_projected_s",
            "value": 1e4 * meta.step_latency_s(reads_per_step=1,
                                               writes_per_step=0),
            "detail": "1e4-step xor budget × PlantMeta read latency",
        })
    rows += stability_grid_rows(seed)
    return rows


# ---------------------------------------------------------------------------
# write_tau × tau_theta stability grid (§5 slow-write bound)
# ---------------------------------------------------------------------------
#
# The analog constraint: the parameter move per persistent write,
# η·|G|·dt (dt = τ_θ steps of accumulated update), must stay well under
# Δθ or the probes measure a plant that has already moved — and a slow
# write (τ_w > 0) makes it worse by low-pass filtering the writes, so
# the chip lags the optimizer by ≈ τ_w additional write periods.  Each
# grid cell reports the MEASURED bound ratio η·|ĝ|·dt_eff/Δθ (median
# per-write max-abs host update over dt_eff = τ_θ·(1+τ_w), divided by
# Δθ) next to the steps-to-solve, so EXPERIMENTS.md can record the
# frontier ratio separating solving from non-solving cells.
STABILITY_WRITE_TAUS = (0.0, 4.0, 16.0)
STABILITY_TAU_THETAS = (1, 8, 32)


def _bound_ratio(write_tau, tau_theta, seed, writes=100):
    """Measured η·|ĝ|·dt/Δθ: MEAN max-abs parameter change across a
    write interval, over the first ``writes`` intervals, in Δθ units
    scaled by the slow-write lag factor (1 + τ_w).  Mean, not median:
    through a quantized DAC the update stream goes zero-heavy once the
    driver reaches a code plateau, and the median of a zero-heavy
    stream reads 0.0 even while the transient moved whole LSBs."""
    plant = quantized_mlp_plant((2, 2, 1), device_seed=seed, bits=12,
                                w_clip=8.0, write_tau=write_tau)
    cfg = DriverConfig(dtheta=1e-2, eta=1.0, mode="forward",
                       tau_theta=tau_theta, seed=seed)
    x, y = tasks.xor_dataset()
    batch = {"x": x, "y": y}
    mgd = driver("discrete", cfg, None, plant=plant)
    p = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
    s = mgd.init(p)
    prev = jnp.concatenate([jnp.ravel(l)
                            for l in jax.tree_util.tree_leaves(p)])
    deltas = []
    for n in range(writes * tau_theta):
        p, s, _ = mgd.step(p, s, batch)
        if (n + 1) % tau_theta == 0:
            flat = jnp.concatenate([jnp.ravel(l)
                                    for l in jax.tree_util.tree_leaves(p)])
            deltas.append(float(jnp.max(jnp.abs(flat - prev))))
            prev = flat
    return (sum(deltas) / len(deltas)) * (1.0 + write_tau) / cfg.dtheta


def stability_grid_rows(seed: int = 0):
    """One row pair (steps-to-solve, bound ratio) per grid cell, plus the
    measured frontier: the largest bound ratio that still solved and the
    smallest that failed."""
    rows = []
    solved_ratios, failed_ratios = [], []
    for wt in STABILITY_WRITE_TAUS:
        for tt in STABILITY_TAU_THETAS:
            cell = f"wtau{wt:g}_tautheta{tt}"
            cfg = DriverConfig(dtheta=1e-2, eta=1.0, mode="forward",
                               tau_theta=tt)
            x, y = tasks.xor_dataset()
            times = []
            for s in range(seed, seed + N_SEEDS):
                plant = quantized_mlp_plant((2, 2, 1), device_seed=s,
                                            bits=12, w_clip=8.0,
                                            write_tau=wt)
                params = mlp_init(jax.random.PRNGKey(s), (2, 2, 1))

                def thresh(p, plant=plant):
                    return float(plant.loss_fn(p, {"x": x, "y": y})) < 0.04

                _, steps, ok = train_until(
                    None, params, cfg, dataset_sampler(x, y, 1),
                    max_steps=40000, threshold_fn=thresh, chunk=2000,
                    plant=plant)
                times.append(steps if ok else None)
            solved = [t for t in times if t is not None]
            ratio = _bound_ratio(wt, tt, seed)
            (solved_ratios if len(solved) > N_SEEDS // 2
             else failed_ratios).append(ratio)
            rows.append({
                "bench": "hw_plants", "name": f"stability_{cell}_steps",
                "value": median(solved) if solved else -1,
                "detail": f"{len(solved)}/{N_SEEDS} solved; write_tau={wt} "
                          f"tau_theta={tt}"})
            rows.append({
                "bench": "hw_plants", "name": f"stability_{cell}_bound",
                "value": ratio,
                "detail": "measured η·|ĝ|·τ_θ·(1+τ_w)/Δθ (≪1 ⇒ stable)"})
    rows.append({
        "bench": "hw_plants", "name": "stability_frontier_max_solved_bound",
        "value": max(solved_ratios) if solved_ratios else -1,
        "detail": "largest bound ratio among solving cells"})
    rows.append({
        "bench": "hw_plants", "name": "stability_frontier_min_failed_bound",
        "value": min(failed_ratios) if failed_ratios else -1,
        "detail": "smallest bound ratio among non-solving cells"})
    return rows
