"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--only fig4,table2]`` runs each benchmark,
prints a CSV (bench,name,value,detail) and writes artifacts/bench/*.json.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time
import traceback

BENCHES = [
    "fig4_equivalence",
    "fig5_angle",
    "fig6_tau_theta",
    "fig7_perturbations",
    "fig8_noise",
    "table2_datasets",
    "table3_hardware",
    "hardware_plants",
    "fused_probe",
    "roofline_report",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark name substrings")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()

    selected = BENCHES
    if args.only:
        keys = args.only.split(",")
        selected = [b for b in BENCHES if any(k in b for k in keys)]

    os.makedirs(args.out, exist_ok=True)
    print("bench,name,value,detail")
    failures = []
    for name in selected:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:    # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc(limit=5, file=sys.stderr)
            continue
        dt = time.time() - t0
        for r in rows:
            detail = str(r.get("detail", "")).replace(",", ";")
            print(f"{r['bench']},{r['name']},{r['value']},{detail}")
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump({"rows": rows, "seconds": dt}, f, indent=1)
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
