"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--only fig4,table2] [--seed 7]`` runs each
benchmark, prints a CSV (bench,name,value,detail) and writes
artifacts/bench/*.json.  ``--list`` enumerates the registered benchmarks
without running anything.  Any selected benchmark that raises makes the
harness exit non-zero (after running the rest), so CI smoke cannot pass
on a broken benchmark.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import sys
import time
import traceback

BENCHES = [
    "scaling_laws",
    "fig4_equivalence",
    "fig5_angle",
    "fig6_tau_theta",
    "fig7_perturbations",
    "fig8_noise",
    "table2_datasets",
    "table3_hardware",
    "hardware_plants",
    "fused_probe",
    "farm_scaling",
    "drift_aging",
    "fault_tolerance",
    "online_serving",
    "roofline_report",
]


def _call_run(mod, seed, smoke=False):
    """Benchmarks that take run(seed=...) get the harness seed; the rest
    keep their built-in seed grids (their statistics are seed-medians
    already).  ``--smoke`` likewise forwards smoke=True only to
    benchmarks that declare it (reduced grids for CI).  Returns
    (rows, seed_used) — None when the benchmark ignores the flag, so
    artifacts never claim a seed that wasn't used."""
    params = inspect.signature(mod.run).parameters
    kwargs = {}
    if "seed" in params:
        kwargs["seed"] = seed
    if smoke and "smoke" in params:
        kwargs["smoke"] = True
    return mod.run(**kwargs), kwargs.get("seed")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark name substrings")
    ap.add_argument("--list", action="store_true",
                    help="print the registered benchmark names and exit")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed forwarded to benchmarks that accept "
                         "run(seed=...)")
    ap.add_argument("--smoke", action="store_true",
                    help="forward smoke=True to benchmarks that accept it "
                         "(reduced grids for CI)")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args(argv)

    if args.list:
        for name in BENCHES:
            print(name)
        return 0

    selected = BENCHES
    if args.only:
        keys = args.only.split(",")
        unknown = [k for k in keys if not any(k in b for b in BENCHES)]
        if unknown:
            print(f"--only matched no benchmark for {unknown}; "
                  f"registered: {BENCHES}", file=sys.stderr)
            return 2
        selected = [b for b in BENCHES if any(k in b for k in keys)]

    os.makedirs(args.out, exist_ok=True)
    print("bench,name,value,detail")
    failures = []
    for name in selected:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            rows, seed_used = _call_run(mod, args.seed, smoke=args.smoke)
        except Exception as e:    # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc(limit=5, file=sys.stderr)
            continue
        dt = time.time() - t0
        for r in rows:
            detail = str(r.get("detail", "")).replace(",", ";")
            print(f"{r['bench']},{r['name']},{r['value']},{detail}")
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump({"rows": rows, "seconds": dt, "seed": seed_used},
                      f, indent=1)
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
