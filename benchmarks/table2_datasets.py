"""Paper Table 2: MGD vs backprop accuracy on the four tasks.

Offline container → Fashion-MNIST/CIFAR-10 are procedural stand-ins of
identical shape (DESIGN.md §Honest limitations): the claim validated is
the MGD-vs-backprop gap ON THE SAME DATA at matched budgets, not absolute
paper accuracies.

Hyperparameter note (EXPERIMENTS.md §Paper): the paper's Table-2 η values
(5/3/9) presume an unstated Δθ — η only enters MGD through η·C̃/Δθ², so
absolute η is meaningless without it.  We recalibrate per task at the
SPSA-stability limit η ≲ 2/(λP) with the probe Δθ well below each
network's weight scale, and report the calibration next to each row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import driver, make_epoch
from repro.core import MGDConfig, mse
from repro.data import tasks
from repro.data.pipeline import dataset_sampler, generator_sampler
from repro.models.simple import (cifar_cnn_apply, cifar_cnn_init,
                                 fashion_cnn_apply, fashion_cnn_init,
                                 mlp_apply, mlp_init)
from repro.training.train_loop import train_backprop


def _acc(apply_fn, params, x, y):
    return float(jnp.mean((jnp.argmax(apply_fn(params, x), -1)
                           == jnp.argmax(y, -1)).astype(jnp.float32)))


def _mse_loss(apply_fn):
    def loss(p, b):
        return mse(apply_fn(p, b["x"]), b["y"])
    return loss


def _train_mgd(loss_fn, params, cfg, sample_fn, steps, chunk):
    mgd = driver("discrete", cfg, loss_fn)
    run = make_epoch(mgd, chunk, sample_fn)
    state = mgd.init(params)
    for _ in range(max(1, steps // chunk)):
        params, state, _ = run(params, state)
    return params


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # --- XOR (paper: 100% at 1e4 steps) ---
    x, y = tasks.xor_dataset()
    loss = _mse_loss(mlp_apply)
    p = mlp_init(jax.random.PRNGKey(2), (2, 2, 1))
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, seed=0)
    p = _train_mgd(loss, p, cfg, dataset_sampler(x, y, 1), 10000, 2000)
    rows.append({"bench": "table2", "name": "xor_mgd_1e4_solved",
                 "value": float(float(mse(mlp_apply(p, x), y)) < 0.04),
                 "detail": "paper: 100% (eta=1, dtheta=1e-2 calibrated)"})

    # --- NIST7x7 (paper: 38% @1e4, 81% @1e5) ---
    p = mlp_init(jax.random.PRNGKey(2), (49, 4, 4))
    cfg = MGDConfig(dtheta=1e-2, eta=0.1, seed=1)
    sample = generator_sampler(tasks.nist7x7_batch, 1, seed=7)
    xe, ye = tasks.nist7x7_batch(jax.random.PRNGKey(99), 512)
    loss = _mse_loss(mlp_apply)
    p = _train_mgd(loss, p, cfg, sample, 10000, 5000)
    rows.append({"bench": "table2", "name": "nist7x7_mgd_1e4_acc",
                 "value": _acc(mlp_apply, p, xe, ye),
                 "detail": "paper 38% @1e4 (eta=0.1)"})
    p = _train_mgd(loss, p, cfg, sample, 90000, 15000)
    rows.append({"bench": "table2", "name": "nist7x7_mgd_1e5_acc",
                 "value": _acc(mlp_apply, p, xe, ye),
                 "detail": "paper 81% @1e5"})
    pb = mlp_init(jax.random.PRNGKey(2), (49, 4, 4))
    res = train_backprop(loss, pb,
                         generator_sampler(tasks.nist7x7_batch, 32, seed=7),
                         3000, eta=1.0, log=None)
    rows.append({"bench": "table2", "name": "nist7x7_backprop_acc",
                 "value": _acc(mlp_apply, res.params, xe, ye),
                 "detail": "paper 99.8%"})

    # --- Fashion-MNIST stand-in CNN (paper: 34.2% @1e4, 88.6% backprop) ---
    loss = _mse_loss(fashion_cnn_apply)
    p = fashion_cnn_init(key)
    nparams = sum(int(v.size) for v in jax.tree_util.tree_leaves(p))
    cfg = MGDConfig(dtheta=1e-3, eta=1e-4, seed=1)
    sample = generator_sampler(tasks.fashion_batch, 64, seed=3)
    p = _train_mgd(loss, p, cfg, sample, 8000, 2000)
    xe, ye = tasks.fashion_batch(jax.random.PRNGKey(98), 512)
    rows.append({"bench": "table2", "name": "fashion_cnn_params",
                 "value": nparams,
                 "detail": "paper 14378 (head wiring ambiguity documented)"})
    rows.append({"bench": "table2", "name": "fashion_mgd_8e3_acc",
                 "value": _acc(fashion_cnn_apply, p, xe, ye),
                 "detail": "paper 34.2% @1e4 (procedural stand-in; "
                           "eta=1e-4 dtheta=1e-3 batch 64)"})
    pb = fashion_cnn_init(key)
    res = train_backprop(loss, pb, sample, 400, eta=0.02, chunk=200,
                         log=None)
    rows.append({"bench": "table2", "name": "fashion_backprop_acc",
                 "value": _acc(fashion_cnn_apply, res.params, xe, ye),
                 "detail": "paper 88.6%; same data/arch as the MGD row"})

    # --- CIFAR-10 stand-in CNN (paper 26154 params; 12% @1e4) ---
    loss = _mse_loss(cifar_cnn_apply)
    p = cifar_cnn_init(key)
    nparams = sum(int(v.size) for v in jax.tree_util.tree_leaves(p))
    cfg = MGDConfig(dtheta=1e-3, eta=5e-5, seed=1)
    sample = generator_sampler(tasks.cifar_batch, 64, seed=4)
    p = _train_mgd(loss, p, cfg, sample, 6000, 2000)
    xe, ye = tasks.cifar_batch(jax.random.PRNGKey(97), 512)
    rows.append({"bench": "table2", "name": "cifar_cnn_params",
                 "value": nparams, "detail": "paper 26154"})
    rows.append({"bench": "table2", "name": "cifar_mgd_6e3_acc",
                 "value": _acc(cifar_cnn_apply, p, xe, ye),
                 "detail": "paper 12% @1e4 (procedural stand-in)"})
    pb = cifar_cnn_init(key)
    res = train_backprop(loss, pb, sample, 400, eta=0.02, chunk=200,
                         log=None)
    rows.append({"bench": "table2", "name": "cifar_backprop_acc",
                 "value": _acc(cifar_cnn_apply, res.params, xe, ye),
                 "detail": "paper 68%; same data/arch"})
    return rows
