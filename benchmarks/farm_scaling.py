"""Farm scaling: probe-parallel MGD over k external chips (§6).

Three questions, all driven through ``repro.driver("probe_parallel_external",
cfg, plant=ChipFarm(...))``:

* **Estimator variance vs k** — the k-chip averaged error signal
  ``(1/k)Σ C̃_k·θ̃_k/Δθ²`` is k independent probe estimates of the same
  gradient, so its variance should fall ∝ 1/k (Oripov et al. 2025's
  scaling axis) at ZERO extra wall-clock: the chips evaluate their pairs
  concurrently.  Measured as the across-step variance of one update
  component at frozen parameters.
* **Convergence vs k** — nist7x7 through farms of k defective chips
  (distinct device seeds); mean on-chip accuracy after a fixed budget.
* **Wall-clock projection** — ``PlantMeta.step_latency_s`` with per-chip
  read counts: a single chip probing k times serially pays 2k reads per
  step; the k-chip farm pays 2 (concurrent pairs), Table-3 style.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DriverConfig, driver, replace_step
from repro.data import tasks
from repro.data.pipeline import generator_sampler
from repro.hardware import PlantMeta, simulated_chip_farm
from repro.models.simple import mlp_init
from repro.training.train_loop import train_mgd

from .common import median

KS = (1, 2, 4, 8)
N_SEEDS = 3


# Two chip flavors for the variance law: MATCHED chips (no defects, no
# write noise — every chip measures the same cost, so the averaged
# estimator is k iid probe estimates and the textbook 1/k shows up
# clean) and DIVERSE chips (distinct σ_a defect draws + σ_θ writes — the
# realistic farm, where per-chip gradient magnitudes differ and the law
# saturates: averaging still helps, just sub-linearly).
VARIANCE_CHIPS = [
    ("matched", dict(sigma_a=0.0, sigma_theta=0.0, sigma_c=1e-3)),
    ("diverse", dict(sigma_a=0.1, sigma_theta=0.01, sigma_c=1e-3)),
]


def _variance_rows(ks, rounds, seed):
    """Across-step variance of one averaged-update component at frozen
    params — the C̃-estimator variance the farm averages down."""
    x, y = tasks.xor_dataset()
    batch = {"x": x, "y": y}
    params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
    cfg = DriverConfig(dtheta=1e-2, eta=1.0, mode="central", seed=seed)
    rows = []
    for flavor, chip_kw in VARIANCE_CHIPS:
        variances = {}
        for k in ks:
            farm = simulated_chip_farm(k, (2, 2, 1), base_seed=seed,
                                       **chip_kw)
            mgd = driver("probe_parallel_external", cfg, plant=farm)
            state = mgd.init(params)
            w0 = np.asarray(jax.tree_util.tree_leaves(params)[1])[0, 0]
            samples = []
            for t in range(rounds):
                new_params, _, _ = mgd.step(params,
                                            replace_step(state, t), batch)
                w1 = np.asarray(
                    jax.tree_util.tree_leaves(new_params)[1])[0, 0]
                samples.append((w1 - w0) / cfg.eta)   # one ĝ component
            variances[k] = float(np.var(samples))
            rows.append({
                "bench": "farm_scaling",
                "name": f"ghat_variance_{flavor}_k{k}",
                "value": variances[k],
                "detail": f"{rounds} frozen-param steps; {flavor} chips "
                          f"{chip_kw}",
            })
        for k in ks[1:]:
            rows.append({
                "bench": "farm_scaling",
                "name": f"variance_ratio_{flavor}_k{k}",
                "value": (variances[ks[0]] / variances[k]
                          if variances[k] else -1),
                "detail": f"var(k=1)/var(k={k}) — ≈{k} if variance ∝ 1/k",
            })
    return rows


def _convergence_rows(ks, steps, seed, n_seeds):
    """nist7x7 accuracy (mean on-chip readout across the farm) after a
    fixed step budget, vs farm size."""
    rows = []
    xe, ye = tasks.nist7x7_batch(jax.random.PRNGKey(99), 512)
    eval_batch = {"x": np.asarray(xe), "y": np.asarray(ye)}
    for k in ks:
        # the k-averaged error signal has 1/k the variance, so it
        # tolerates a proportionally larger step — η = 0.125·k (the
        # linear-scaling rule; at fixed η=0.1 the diverse-chip consensus
        # objective converges k-times slower instead)
        cfg = DriverConfig(dtheta=2e-2, eta=0.125 * k, mode="central",
                           seed=seed)
        accs = []
        for s in range(seed, seed + n_seeds):
            farm = simulated_chip_farm(k, (49, 4, 4), base_seed=100 * s,
                                       sigma_a=0.15, sigma_theta=0.01,
                                       sigma_c=1e-4)
            params = mlp_init(jax.random.PRNGKey(s), (49, 4, 4))
            res = train_mgd(
                None, params, cfg.replace(seed=s),
                generator_sampler(tasks.nist7x7_batch, 8, seed=11 + s),
                steps, algorithm="probe_parallel_external", plant=farm,
                chunk=max(steps // 4, 1), log=None)
            accs.append(farm.measure_accuracy(res.params, eval_batch))
        rows.append({
            "bench": "farm_scaling", "name": f"nist7x7_k{k}_accuracy",
            "value": median(accs),
            "detail": f"median of {n_seeds} farms, {steps} steps, "
                      f"eta=0.125k, mean on-chip readout",
        })
    return rows


def _latency_rows(ks):
    """Projected wall-clock for 1e4 steps on HW1-style chips (1 ms cost
    read): k serial probes on one chip vs one concurrent farm pair."""
    rows = []
    for k in ks:
        serial = PlantMeta(name="HW1-serial", read_latency_s=1e-3,
                           external=True)
        farm = PlantMeta(name=f"HW1-farm-{k}", read_latency_s=1e-3,
                         external=True, chips=k)
        rows.append({
            "bench": "farm_scaling", "name": f"projected_1e4steps_k{k}_s",
            "value": 1e4 * farm.step_latency_s(reads_per_step=2,
                                               writes_per_step=0),
            "detail": f"farm: 2 concurrent reads/step; serial k-probe "
                      f"chip would need "
                      f"{1e4 * serial.step_latency_s(2 * k, 0):.0f}s",
        })
    return rows


def run(seed: int = 0, smoke: bool = False):
    ks = (1, 2, 4) if smoke else KS
    rounds = 24 if smoke else 192
    steps = 300 if smoke else 3000
    rows = _variance_rows(ks, rounds, seed)
    rows += _convergence_rows(ks, steps, seed, 1 if smoke else N_SEEDS)
    rows += _latency_rows(ks)
    return rows
