"""Farm scaling: probe-parallel MGD over k external chips (§6).

Three questions, all driven through ``repro.driver("probe_parallel_external",
cfg, plant=ChipFarm(...))``:

* **Estimator variance vs k** — the k-chip averaged error signal
  ``(1/k)Σ C̃_k·θ̃_k/Δθ²`` is k independent probe estimates of the same
  gradient, so its variance should fall ∝ 1/k (Oripov et al. 2025's
  scaling axis) at ZERO extra wall-clock: the chips evaluate their pairs
  concurrently.  Measured as the across-step variance of one update
  component at frozen parameters.
* **Convergence vs k** — nist7x7 through farms of k defective chips
  (distinct device seeds); mean on-chip accuracy after a fixed budget.
* **Wall-clock projection** — ``PlantMeta.step_latency_s`` with per-chip
  read counts: a single chip probing k times serially pays 2k reads per
  step; the k-chip farm pays 2 (concurrent pairs), Table-3 style.
* **Measured backend throughput** — steps/s through REAL farms of
  GIL-holding chips (``py_busy_ms``: the honest pure-Python-instrument-
  driver model) on the thread vs process backends with the
  double-buffered pipeline on.  The thread backend serializes (k chips →
  ~k× single-chip step time); the process backend stays flat in k and
  reports its measured pipeline utilization (device-busy seconds /
  k × wall).  ``python -m benchmarks.farm_scaling --backend process
  --smoke`` runs just one backend's sweep.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DriverConfig, driver, replace_step
from repro.data import tasks
from repro.data.pipeline import generator_sampler
from repro.hardware import PlantMeta, simulated_chip_farm
from repro.models.simple import mlp_init
from repro.training.train_loop import train_mgd

from .common import median

KS = (1, 2, 4, 8)
N_SEEDS = 3
THROUGHPUT_BACKENDS = ("thread", "process")


# Two chip flavors for the variance law: MATCHED chips (no defects, no
# write noise — every chip measures the same cost, so the averaged
# estimator is k iid probe estimates and the textbook 1/k shows up
# clean) and DIVERSE chips (distinct σ_a defect draws + σ_θ writes — the
# realistic farm, where per-chip gradient magnitudes differ and the law
# saturates: averaging still helps, just sub-linearly).
VARIANCE_CHIPS = [
    ("matched", dict(sigma_a=0.0, sigma_theta=0.0, sigma_c=1e-3)),
    ("diverse", dict(sigma_a=0.1, sigma_theta=0.01, sigma_c=1e-3)),
]


def _variance_rows(ks, rounds, seed):
    """Across-step variance of one averaged-update component at frozen
    params — the C̃-estimator variance the farm averages down."""
    x, y = tasks.xor_dataset()
    batch = {"x": x, "y": y}
    params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
    cfg = DriverConfig(dtheta=1e-2, eta=1.0, mode="central", seed=seed)
    rows = []
    for flavor, chip_kw in VARIANCE_CHIPS:
        variances = {}
        for k in ks:
            farm = simulated_chip_farm(k, (2, 2, 1), base_seed=seed,
                                       **chip_kw)
            mgd = driver("probe_parallel_external", cfg, plant=farm)
            state = mgd.init(params)
            w0 = np.asarray(jax.tree_util.tree_leaves(params)[1])[0, 0]
            samples = []
            for t in range(rounds):
                new_params, _, _ = mgd.step(params,
                                            replace_step(state, t), batch)
                w1 = np.asarray(
                    jax.tree_util.tree_leaves(new_params)[1])[0, 0]
                samples.append((w1 - w0) / cfg.eta)   # one ĝ component
            variances[k] = float(np.var(samples))
            rows.append({
                "bench": "farm_scaling",
                "name": f"ghat_variance_{flavor}_k{k}",
                "value": variances[k],
                "detail": f"{rounds} frozen-param steps; {flavor} chips "
                          f"{chip_kw}",
            })
        for k in ks[1:]:
            rows.append({
                "bench": "farm_scaling",
                "name": f"variance_ratio_{flavor}_k{k}",
                "value": (variances[ks[0]] / variances[k]
                          if variances[k] else -1),
                "detail": f"var(k=1)/var(k={k}) — ≈{k} if variance ∝ 1/k",
            })
    return rows


def _convergence_rows(ks, steps, seed, n_seeds):
    """nist7x7 accuracy (mean on-chip readout across the farm) after a
    fixed step budget, vs farm size."""
    rows = []
    xe, ye = tasks.nist7x7_batch(jax.random.PRNGKey(99), 512)
    eval_batch = {"x": np.asarray(xe), "y": np.asarray(ye)}
    for k in ks:
        # the k-averaged error signal has 1/k the variance, so it
        # tolerates a proportionally larger step — η = 0.125·k (the
        # linear-scaling rule; at fixed η=0.1 the diverse-chip consensus
        # objective converges k-times slower instead)
        cfg = DriverConfig(dtheta=2e-2, eta=0.125 * k, mode="central",
                           seed=seed)
        accs = []
        for s in range(seed, seed + n_seeds):
            farm = simulated_chip_farm(k, (49, 4, 4), base_seed=100 * s,
                                       sigma_a=0.15, sigma_theta=0.01,
                                       sigma_c=1e-4)
            params = mlp_init(jax.random.PRNGKey(s), (49, 4, 4))
            res = train_mgd(
                None, params, cfg.replace(seed=s),
                generator_sampler(tasks.nist7x7_batch, 8, seed=11 + s),
                steps, algorithm="probe_parallel_external", plant=farm,
                chunk=max(steps // 4, 1), log=None)
            accs.append(farm.measure_accuracy(res.params, eval_batch))
        rows.append({
            "bench": "farm_scaling", "name": f"nist7x7_k{k}_accuracy",
            "value": median(accs),
            "detail": f"median of {n_seeds} farms, {steps} steps, "
                      f"eta=0.125k, mean on-chip readout",
        })
    return rows


def _latency_rows(ks):
    """Projected wall-clock for 1e4 steps on HW1-style chips (1 ms cost
    read): k serial probes on one chip vs one concurrent farm pair."""
    rows = []
    for k in ks:
        serial = PlantMeta(name="HW1-serial", read_latency_s=1e-3,
                           external=True)
        farm = PlantMeta(name=f"HW1-farm-{k}", read_latency_s=1e-3,
                         external=True, chips=k)
        rows.append({
            "bench": "farm_scaling", "name": f"projected_1e4steps_k{k}_s",
            "value": 1e4 * farm.step_latency_s(reads_per_step=2,
                                               writes_per_step=0),
            "detail": f"farm: 2 concurrent reads/step; serial k-probe "
                      f"chip would need "
                      f"{1e4 * serial.step_latency_s(2 * k, 0):.0f}s",
        })
    return rows


def _throughput_rows(ks, smoke, backends=THROUGHPUT_BACKENDS):
    """Measured steps/s through py_busy_ms farms per backend, pipeline
    on.  The chip holds the GIL for ``busy_ms`` per readout conversion
    (2 per central pair), so the thread backend serializes across chips
    while the process backend — one GIL per worker — stays flat in k."""
    # smoke keeps ks small but busy_ms high enough that device work
    # dominates per-step overhead — the gated flatness/utilization
    # ratios stay stable across differently-loaded CI machines
    busy_ms = 25.0 if smoke else 50.0
    n_steps = 8 if smoke else 16
    x, y = tasks.xor_dataset()
    batch = {"x": x, "y": y}
    params = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=0)
    cores = len(os.sched_getaffinity(0))
    rows = []
    step_s = {}           # (backend, k) -> measured seconds per step
    util = {}             # (backend, k) -> pipeline utilization
    for backend in backends:
        for k in ks:
            with simulated_chip_farm(k, (2, 2, 1), base_seed=0,
                                     sigma_a=0.0, sigma_theta=0.0,
                                     sigma_c=1e-3, py_busy_ms=busy_ms,
                                     backend=backend,
                                     pipeline=True) as farm:
                mgd = driver("probe_parallel_external", cfg, plant=farm)
                p, s = params, mgd.init(params)
                for _ in range(3):                 # compile + worker warmup
                    p, s, _ = mgd.step(p, s, batch)
                # steps dispatch asynchronously: block on the outputs
                # before fencing/timing, or the host races its own farm
                jax.block_until_ready((p, s))
                farm.fence()
                b0 = farm.backend.busy_seconds()
                t0 = time.perf_counter()
                for _ in range(n_steps):
                    p, s, _ = mgd.step(p, s, batch)
                jax.block_until_ready((p, s))
                farm.fence()
                wall = time.perf_counter() - t0
                busy = farm.backend.busy_seconds() - b0
            step_s[backend, k] = wall / n_steps
            util[backend, k] = busy / (wall * k) if wall else 0.0
            rows.append({
                "bench": "farm_scaling",
                "name": f"steps_per_s_{backend}_k{k}",
                "value": n_steps / wall,
                "detail": f"{1e3 * wall / n_steps:.1f} ms/step, "
                          f"busy {busy_ms} ms/conversion, "
                          f"util {util[backend, k]:.2f}, {cores} cores",
            })
    kmax = max(ks)
    if "process" in backends:
        rows.append({
            "bench": "farm_scaling",
            "name": f"wallclock_flat_process_k{kmax}",
            "value": step_s["process", kmax] / step_s["process", 1],
            "detail": f"process step-time ratio k={kmax} vs k=1 — "
                      "~1.0 when the farm is flat in k (target <= 1.25)",
        })
        rows.append({
            "bench": "farm_scaling",
            "name": f"pipeline_utilization_process_k{kmax}",
            "value": util["process", kmax],
            "detail": f"device-busy / (k x wall) at k={kmax}, "
                      "double-buffered (target >= 0.8)",
        })
    if "thread" in backends and "process" in backends:
        rows.append({
            "bench": "farm_scaling",
            "name": f"thread_over_process_k{kmax}",
            "value": step_s["thread", kmax] / step_s["process", kmax],
            "detail": f"GIL-bound thread farm serializes: ~{kmax}x the "
                      "process step time at the same k",
        })
    return rows


def run(seed: int = 0, smoke: bool = False):
    ks = (1, 2, 4) if smoke else KS
    rounds = 24 if smoke else 192
    steps = 300 if smoke else 3000
    rows = _variance_rows(ks, rounds, seed)
    rows += _convergence_rows(ks, steps, seed, 1 if smoke else N_SEEDS)
    rows += _latency_rows(ks)
    rows += _throughput_rows(ks, smoke)
    return rows


if __name__ == "__main__":
    # standalone backend sweep: the bench-smoke CI hook runs one backend
    # at a time (thread AND process) so a regression names its backend
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=list(THROUGHPUT_BACKENDS),
                    action="append",
                    help="backend(s) to sweep (default: all)")
    ap.add_argument("--smoke", action="store_true")
    cli = ap.parse_args()
    backends = tuple(cli.backend) if cli.backend else THROUGHPUT_BACKENDS
    out = _throughput_rows((1, 2, 4) if cli.smoke else KS, cli.smoke,
                           backends)
    for row in out:
        print(f"{row['bench']},{row['name']},{row['value']:.6g},"
              f"\"{row['detail']}\"")
