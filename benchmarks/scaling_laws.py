"""Scaling laws: accuracy + ĝ-variance vs parameter count N and probe
count k on virtual-device meshes (Oripov et al. 2025's follow-up axes).

Four sections, all through ``repro.driver("probe_parallel", cfg, loss,
mesh=...)`` on ``--xla_force_host_platform_device_count`` virtual CPUs:

* **ĝ-variance vs k (mesh)** — frozen params, k pods probing the SAME
  replicated batch (``batch_specs=P()``): the k-averaged estimator's
  variance falls ∝ 1/k; ``mesh_variance_ratio_k`` ≈ k.  A second sweep
  with the default ``P("pod")`` batch sharding shows the law survives
  per-pod data shards.
* **ĝ-variance vs N** — frozen params at fixed k across MLP widths: a
  single component's variance grows ∝ N (the Σ_{j≠i} g_j² cross-talk
  term), the reason the follow-up's probes-to-target budget scales N/k.
* **accuracy vs k** — XOR trained on a batch-sharded k-pod mesh for a
  fixed step budget.
* **mesh ≡ farm bit-equality** — the dyadic-exact LinearLaneChip
  trajectory: a batch-sharded 4-pod mesh must bit-match (f32) a 4-chip
  ``ChipFarm(shard_batch=True)``; reported as a 0/1 row gated at zero
  tolerance.

Parameter counts for the big configs come from
``launch.specs.abstract_params`` (eval_shape — zero allocation;
``launch.dryrun`` itself force-sets a 512-device XLA_FLAGS at import and
cannot be loaded after jax initializes, so the projection rows price
through ``PlantMeta`` + the abstract N directly): ``projected_*`` rows
extrapolate probes-to-target ∝ N/k and HW1-style step latency to
qwen3-14b / deepseek-v3-671b scale.

Needs ≥ 8 virtual devices for the full k grid — smoke.sh/nightly export
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on fewer devices
the k grid (and the bit-match row, k=4) shrink to what the host offers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.api import DriverConfig, driver, replace_step
from repro.core import mae, mse
from repro.data import tasks
from repro.hardware import ChipFarm, LinearLaneChip, PlantMeta
from repro.models.simple import linear_apply, mlp_apply, mlp_init

BENCH = "scaling_laws"
KS = (1, 2, 4, 8)
N_SIZES = ((2, 2, 1), (2, 8, 1), (2, 32, 1))
PROJECTED_ARCHS = ("qwen3-14b", "deepseek-v3-671b")
# chip-in-the-loop pricing for the projections (Table-3 HW1 class)
HW1 = PlantMeta(name="HW1", read_latency_s=1e-3, write_latency_s=1e-3)


def _mesh(k):
    return Mesh(np.array(jax.devices()[:k]).reshape(k), ("pod",))


def _feasible_ks():
    return tuple(k for k in KS if k <= len(jax.devices()))


def _loss(p, b):
    return mse(mlp_apply(p, b["x"]), b["y"])


def _n_params(tree):
    return int(sum(int(np.prod(np.shape(l)))
                   for l in jax.tree_util.tree_leaves(tree)))


def _xor8():
    x, y = tasks.xor_dataset()
    return {"x": jnp.tile(x, (2, 1)), "y": jnp.tile(y, (2, 1))}


def _ghat_samples(sizes, k, rounds, seed, *, replicate_batch):
    """Across-step samples of one averaged-update component at frozen
    params — (w1 − w0)/η per probe round, on a k-pod mesh."""
    cfg = DriverConfig(dtheta=1e-2, eta=1.0, mode="central", seed=seed)
    kw = {"batch_specs": P()} if replicate_batch else {}
    drv = driver("probe_parallel", cfg, _loss, mesh=_mesh(k), **kw)
    params = mlp_init(jax.random.PRNGKey(seed), sizes)
    state = drv.init(params)
    batch = _xor8()
    w0 = np.asarray(jax.tree_util.tree_leaves(params)[1])[0, 0]
    samples = []
    for t in range(rounds):
        new_params, _, _ = drv.step(params, replace_step(state, t), batch)
        w1 = np.asarray(jax.tree_util.tree_leaves(new_params)[1])[0, 0]
        samples.append((w1 - w0) / cfg.eta)
    return samples


def _variance_rows(ks, rounds, seed):
    rows = []
    for flavor, replicate in (("replicated", True), ("sharded", False)):
        variances = {}
        for k in ks:
            variances[k] = float(np.var(
                _ghat_samples((2, 2, 1), k, rounds, seed,
                              replicate_batch=replicate)))
            rows.append({
                "bench": BENCH, "name": f"mesh_ghat_variance_{flavor}_k{k}",
                "value": variances[k],
                "detail": f"{rounds} frozen-param mesh steps; "
                          f"{flavor} batch"})
        for k in ks[1:]:
            rows.append({
                "bench": BENCH, "name": f"mesh_variance_ratio_{flavor}_k{k}",
                "value": (variances[ks[0]] / variances[k]
                          if variances[k] else -1.0),
                "detail": f"var(k=1)/var(k={k}) — ≈{k} if variance ∝ 1/k"
                          + ("" if replicate else
                             "; per-shard objectives differ, law "
                             "saturates (sharded mode)")})
    return rows


def _variance_vs_n_rows(rounds, seed):
    """Single-component ĝ variance across model sizes at fixed k."""
    k = max(kk for kk in _feasible_ks() if kk <= 4)
    rows, measured = [], {}
    for sizes in N_SIZES:
        n = _n_params(mlp_init(jax.random.PRNGKey(0), sizes))
        measured[n] = float(np.var(
            _ghat_samples(sizes, k, rounds, seed, replicate_batch=True)))
        rows.append({
            "bench": BENCH, "name": f"ghat_variance_N{n}",
            "value": measured[n],
            "detail": f"mlp {sizes}, k={k}, {rounds} frozen-param steps"})
    ns = sorted(measured)
    rows.append({
        "bench": BENCH, "name": "variance_slope_N",
        "value": measured[ns[-1]] / measured[ns[0]],
        "detail": f"var(N={ns[-1]})/var(N={ns[0]}) — grows with N "
                  f"(cross-talk term ∝ Σ g_j²)"})
    return rows, measured


def _accuracy_rows(ks, steps, seed):
    """XOR accuracy/cost after a fixed budget on batch-sharded meshes."""
    rows = []
    batch = _xor8()
    for k in ks:
        cfg = DriverConfig(dtheta=1e-2, eta=2.0, mode="central", seed=seed)
        drv = driver("probe_parallel", cfg, _loss, mesh=_mesh(k))
        p = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
        s = drv.init(p)
        costs = []
        for _ in range(steps):
            p, s, aux = drv.step(p, s, batch)
            costs.append(float(aux["cost"]))
        pred = np.asarray(mlp_apply(p, batch["x"]))
        acc = float(np.mean((pred > 0.5) == (np.asarray(batch["y"]) > 0.5)))
        rows.append({
            "bench": BENCH, "name": f"xor_accuracy_k{k}", "value": acc,
            "detail": f"{steps} steps, batch-sharded {k}-pod mesh"})
        rows.append({
            "bench": BENCH, "name": f"xor_cost_k{k}",
            "value": float(np.mean(costs[-10:])),
            "detail": f"mean cost over final 10 of {steps} steps"})
    return rows


def _bitmatch_rows():
    """The acceptance law as a gated row: batch-sharded 4-pod mesh ≡
    4-chip shard_batch farm, bit for bit, over a dyadic-exact horizon."""
    if len(jax.devices()) < 4:
        return []

    def l1(p, b):
        return mae(b["y"], linear_apply(p, b["x"]))

    def init():
        return [{"w": jnp.array([[0.5], [-0.25]], jnp.float32),
                 "b": jnp.array([0.25], jnp.float32)}]

    batch = _xor8()
    cfg = dict(dtheta=0.5, eta=0.5, mode="central", seed=5)
    drv = driver("probe_parallel", DriverConfig(**cfg), l1, mesh=_mesh(4))
    farm = ChipFarm([LinearLaneChip() for _ in range(4)], shard_batch=True)
    ext = driver("probe_parallel_external", DriverConfig(**cfg), plant=farm)
    p_m, s_m = init(), drv.init(init())
    p_f, s_f = init(), ext.init(init())
    match = True
    for _ in range(4):
        p_m, s_m, _ = drv.step(p_m, s_m, batch)
        p_f, s_f, _ = ext.step(p_f, s_f, batch)
        match &= all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(p_m),
                            jax.tree_util.tree_leaves(p_f)))
    return [{
        "bench": BENCH, "name": "mesh_farm_bitmatch_f32",
        "value": 1.0 if match else 0.0,
        "detail": "4-pod P('pod') mesh vs 4-chip shard_batch LinearLane "
                  "farm, 4 dyadic-exact steps, params bit-compared"}]


def _projection_rows(var_by_n):
    """Big-config projections: abstract N (no allocation) + N/k probe
    budget + HW1 step pricing.  Pure arithmetic over committed inputs →
    deterministic, gated tight."""
    from repro.configs import get_config, get_smoke_config
    from repro.launch.specs import abstract_params

    rows = []
    ns = sorted(var_by_n)
    slope = var_by_n[ns[-1]] / ns[-1]        # var ≈ slope · N at k = 1-ish
    for arch in PROJECTED_ARCHS:
        tag = arch.replace("-", "_")
        n_full = _n_params(abstract_params(get_config(arch)))
        n_smoke = _n_params(abstract_params(get_smoke_config(arch)))
        rows.append({"bench": BENCH, "name": f"params_{tag}",
                     "value": float(n_full),
                     "detail": "abstract_params leaf-size sum"})
        rows.append({"bench": BENCH, "name": f"params_smoke_{tag}",
                     "value": float(n_smoke),
                     "detail": "smoke_config abstract N (CI scale)"})
        for k in (8, 4096):
            rows.append({
                "bench": BENCH, "name": f"projected_probe_budget_{tag}_k{k}",
                "value": float(n_full) / k,
                "detail": "probes-to-target ∝ N/k (follow-up scaling)"})
        rows.append({
            "bench": BENCH, "name": f"projected_step_s_{tag}",
            "value": HW1.step_latency_s(
                reads_per_step=2, writes_per_step=1,
                differential=True, pipelined=True),
            "detail": "HW1 pricing, k concurrent differential pairs, "
                      "pipelined write (k-independent wall-clock)"})
        rows.append({
            "bench": BENCH, "name": f"projected_ghat_variance_{tag}_k8",
            "value": slope * n_full / 8.0,
            "detail": f"measured var/N slope {slope:.3g} × N/k "
                      f"(informational extrapolation)"})
    return rows


def run(seed: int = 0, smoke: bool = False):
    rounds = 30 if smoke else 100
    steps = 300 if smoke else 800
    ks = _feasible_ks()
    rows = _variance_rows(ks, rounds, seed)
    n_rows, var_by_n = _variance_vs_n_rows(rounds, seed)
    rows += n_rows
    rows += _accuracy_rows(ks, steps, seed)
    rows += _bitmatch_rows()
    rows += _projection_rows(var_by_n)
    return rows


if __name__ == "__main__":
    for r in run(smoke=True):
        print(f"{r['name']},{r['value']},{r['detail']}")
