"""The one-driver API: registry construction, f32 trajectory parity with
the legacy entry points, train_mgd generality, and deprecation hygiene.

Load-bearing contracts:
* ``repro.driver(name, cfg, loss_fn, ...)`` constructs all three
  algorithms behind the uniform ``(init, step)`` pair with standardized
  ``aux`` (cost / c_tilde / grad_norm_proxy).
* Registry-built drivers are bit-identical (f32) to the raw
  ``build_*_step`` constructors — discrete (incl. fused + explicit
  NoisyPlant), analog, and probe-parallel.
* ``train_mgd`` drives ANY driver, checkpoints the full state pytree
  generically, and resumes Algorithm 2 onto the uninterrupted
  trajectory through a ``QuantizedPlant(write_tau=...)``.
* The retired PR 3 shims (``make_*_step``) raise with the registry
  one-liner; ambiguous config mixes are rejected with actionable errors.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import DriverConfig, MGDDriver, driver, make_epoch, state_step
from repro.core import (AnalogMGDConfig, MGDConfig, analog_init,
                        build_analog_step, build_mgd_step, mgd_init, mse)
from repro.data import tasks
from repro.hardware import IdealPlant, NoisyPlant, QuantizedPlant
from repro.models.simple import make_mlp_probe_fn, mlp_apply, mlp_init

X, Y = tasks.xor_dataset()
BATCH = {"x": X, "y": Y}


def _loss(p, b):
    return mse(mlp_apply(p, b["x"]), b["y"])


def _params(seed=0):
    return mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))


def _rollout(step_fn, params, state, steps=24):
    step = jax.jit(step_fn)
    cts = []
    for _ in range(steps):
        params, state, m = step(params, state, BATCH)
        cts.append(np.asarray(m["c_tilde"]))
    return params, state, np.array(cts)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# Parity: registry-built drivers == legacy entry points, bit for bit
# ---------------------------------------------------------------------------


DISCRETE_CFGS = [
    MGDConfig(dtheta=1e-2, eta=1.0, seed=3),
    MGDConfig(dtheta=1e-2, eta=0.5, mode="central", seed=3),
    MGDConfig(dtheta=1e-2, eta=0.5, tau_theta=4, replay=True, seed=1),
    MGDConfig(dtheta=1e-2, eta=0.25, tau_theta=3, momentum=0.9, probes=2,
              seed=2),
]


@pytest.mark.parametrize("cfg", DISCRETE_CFGS,
                         ids=["forward", "central", "replay", "momentum"])
def test_discrete_driver_matches_raw_build(cfg):
    p0 = _params()
    raw_step = build_mgd_step(_loss, cfg)
    p_a, s_a, ct_a = _rollout(raw_step, p0, mgd_init(p0, cfg))

    drv = repro.driver("discrete", cfg, _loss)
    p_b, s_b, ct_b = _rollout(drv.step, p0, drv.init(p0))
    np.testing.assert_array_equal(ct_a, ct_b)
    _assert_trees_equal(p_a, p_b)
    _assert_trees_equal(s_a, s_b)


def test_discrete_fused_driver_matches_raw_build():
    cfg = MGDConfig(dtheta=1e-2, eta=0.5, mode="central", fused=True,
                    kernel_impl="interpret", seed=2)
    probe_fn = make_mlp_probe_fn()
    p0 = _params()
    raw_step = build_mgd_step(_loss, cfg, probe_fn=probe_fn)
    p_a, _, ct_a = _rollout(raw_step, p0, mgd_init(p0, cfg))

    drv = driver("discrete", cfg, _loss, probe_fn=probe_fn)
    p_b, _, ct_b = _rollout(drv.step, p0, drv.init(p0))
    np.testing.assert_array_equal(ct_a, ct_b)
    _assert_trees_equal(p_a, p_b)


def test_discrete_noisy_plant_driver_matches_raw_build():
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, seed=5)
    plant = NoisyPlant(_loss, cost_noise=1e-3, write_noise=0.01,
                       dtheta=1e-2, seed=5)
    p0 = _params()
    raw_step = build_mgd_step(None, cfg, plant=plant)
    p_a, _, ct_a = _rollout(raw_step, p0, mgd_init(p0, cfg))

    drv = driver("discrete", cfg, plant=plant)
    p_b, _, ct_b = _rollout(drv.step, p0, drv.init(p0))
    np.testing.assert_array_equal(ct_a, ct_b)
    _assert_trees_equal(p_a, p_b)


def test_analog_driver_matches_raw_build():
    cfg = AnalogMGDConfig(dtheta=1e-2, eta=1e-3)
    p0 = _params()
    raw_step = build_analog_step(_loss, cfg)
    p_a, s_a, ct_a = _rollout(raw_step, p0, analog_init(p0, cfg), 50)

    drv = repro.driver("analog", cfg, _loss)
    p_b, s_b, ct_b = _rollout(drv.step, p0, drv.init(p0), 50)
    np.testing.assert_array_equal(ct_a, ct_b)
    _assert_trees_equal(p_a, p_b)
    _assert_trees_equal(s_a, s_b)


def test_probe_parallel_driver_matches_raw_build():
    from jax.sharding import Mesh
    from repro.core.probe_parallel import build_probe_parallel_step
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("pod",))
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, mode="central", seed=1)
    p0 = _params()
    batch = {"x": X[None], "y": Y[None]}      # [pods, ...] shard layout

    raw = build_probe_parallel_step(_loss, cfg, mesh)
    drv = driver("probe_parallel", cfg, _loss, mesh=mesh)
    p_a, p_b = p0, p0
    s_b = drv.init(p0)
    for i in range(6):
        p_a, m_a = raw(p_a, i, batch)
        p_b, s_b, m_b = drv.step(p_b, s_b, batch)
        np.testing.assert_array_equal(np.asarray(m_a["c_tilde_mean"]),
                                      np.asarray(m_b["c_tilde"]))
    assert int(s_b.step) == 6
    _assert_trees_equal(p_a, p_b)


# ---------------------------------------------------------------------------
# The uniform contract
# ---------------------------------------------------------------------------


def test_driver_config_resolves_per_algorithm_defaults():
    d = driver("discrete", DriverConfig(), _loss)
    a = driver("analog", DriverConfig(), _loss)
    assert (d.config.ptype, d.config.dtheta, d.config.eta) == \
        ("rademacher", 1e-3, 1e-2)
    assert (a.config.ptype, a.config.dtheta, a.config.eta) == \
        ("sinusoidal", 1e-2, 1e-3)
    assert isinstance(d, MGDDriver) and isinstance(a, MGDDriver)


@pytest.mark.parametrize("algorithm", ["discrete", "analog"])
def test_standardized_aux_keys(algorithm):
    drv = driver(algorithm, DriverConfig(dtheta=1e-2, eta=0.1), _loss)
    p = _params()
    _, s, aux = jax.jit(drv.step)(p, drv.init(p), BATCH)
    for key in ("cost", "c_tilde", "grad_norm_proxy"):
        assert key in aux, key
    np.testing.assert_allclose(
        np.asarray(aux["grad_norm_proxy"]),
        abs(np.asarray(aux["c_tilde"])) / 1e-2, rtol=1e-6)
    assert int(state_step(s)) == 1


def test_make_epoch_matches_stepwise():
    cfg = DriverConfig(dtheta=1e-2, eta=1.0, seed=4)
    drv = driver("discrete", cfg, _loss)
    p0 = _params()
    run = make_epoch(drv, 12, lambda i: BATCH)
    p_scan, s_scan, _ = run(p0, drv.init(p0))
    assert int(state_step(s_scan)) == 12
    # scanned vs python-loop stepping: same trajectory (allclose — the
    # scan and per-step programs are separately compiled)
    p_py, s_py = p0, drv.init(p0)
    step = jax.jit(drv.step)
    for _ in range(12):
        p_py, s_py, _ = step(p_py, s_py, BATCH)
    for a, b in zip(jax.tree_util.tree_leaves(p_scan),
                    jax.tree_util.tree_leaves(p_py)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# train_mgd consumes any driver; generic full-state checkpointing
# ---------------------------------------------------------------------------


def test_train_mgd_drives_algorithm2_with_checkpoint_resume(tmp_path):
    """Acceptance: Algorithm 2 through a QuantizedPlant(write_tau=...)
    end to end, resume == uninterrupted (generic full-state ckpt)."""
    from repro.training.train_loop import train_mgd

    def plant():
        return QuantizedPlant(_loss, bits=12, w_clip=8.0, write_tau=4.0)

    cfg = DriverConfig(dtheta=1e-2, eta=5e-3, tau_theta=5.0, tau_hp=50.0,
                       seed=1)
    p0 = _params(3)
    sample_fn = lambda i: BATCH                        # noqa: E731

    cont = train_mgd(None, p0, cfg, sample_fn, 40, algorithm="analog",
                     plant=plant(), chunk=10, log=None)
    assert type(cont.state).__name__ == "AnalogMGDState"

    train_mgd(None, p0, cfg, sample_fn, 20, algorithm="analog",
              plant=plant(), chunk=10, log=None,
              checkpoint_dir=str(tmp_path), checkpoint_every=10)
    res = train_mgd(None, p0, cfg, sample_fn, 40, algorithm="analog",
                    plant=plant(), chunk=10, log=None,
                    checkpoint_dir=str(tmp_path))
    assert res.steps_done == 40
    _assert_trees_equal(cont.params, res.params)
    # the analog filter memories resumed exactly too (full state pytree)
    _assert_trees_equal(cont.state, res.state)


def test_train_mgd_accepts_prebuilt_driver():
    from repro.training.train_loop import train_mgd
    drv = driver("discrete", DriverConfig(dtheta=1e-2, eta=1.0), _loss)
    res = train_mgd(None, _params(), drv, lambda i: BATCH, 20, chunk=10,
                    log=None)
    assert res.steps_done == 20
    with pytest.raises(ValueError, match="pre-built"):
        train_mgd(_loss, _params(), drv, lambda i: BATCH, 10, log=None)


def test_train_mgd_discrete_unchanged_by_redesign(tmp_path):
    """The historical call shape (loss_fn + MGDConfig) still trains and
    still resumes from its own checkpoints."""
    from repro.training.train_loop import train_mgd
    cfg = MGDConfig(dtheta=1e-2, eta=0.5, tau_theta=4, momentum=0.9, seed=2)
    p0 = _params(3)
    cont = train_mgd(_loss, p0, cfg, lambda i: BATCH, 30, chunk=10, log=None)
    train_mgd(_loss, p0, cfg, lambda i: BATCH, 10, chunk=10, log=None,
              checkpoint_dir=str(tmp_path), checkpoint_every=10)
    res = train_mgd(_loss, p0, cfg, lambda i: BATCH, 30, chunk=10, log=None,
                    checkpoint_dir=str(tmp_path))
    _assert_trees_equal(cont.params, res.params)
    _assert_trees_equal(cont.state.g, res.state.g)


# ---------------------------------------------------------------------------
# Retired-shim hygiene + ambiguous-mix rejection
# ---------------------------------------------------------------------------


def test_retired_shims_raise_with_registry_pointer():
    """The PR 3 deprecation shims graduated from warn to raise; the
    message carries the registry one-liner."""
    from repro.core import make_analog_step, make_mgd_step
    from repro.core.probe_parallel import make_probe_parallel_step
    for shim, algo in [(make_mgd_step, "discrete"),
                       (make_analog_step, "analog"),
                       (make_probe_parallel_step, "probe_parallel")]:
        with pytest.raises(RuntimeError, match="repro.driver") as e:
            shim(_loss, MGDConfig())
        assert algo in str(e.value)


@pytest.mark.parametrize("build,match", [
    (lambda: driver("nope", DriverConfig(), _loss), "unknown algorithm"),
    (lambda: driver("analog", DriverConfig(probes=4), _loss),
     "discrete-section"),
    (lambda: driver("analog", DriverConfig(momentum=0.9), _loss),
     "discrete-section"),
    (lambda: driver("analog", DriverConfig(fused=True), _loss),
     "discrete-section"),
    (lambda: driver("discrete", DriverConfig(dt=0.1), _loss),
     "analog-section"),
    (lambda: driver("discrete", DriverConfig(tau_hp=5.0), _loss),
     "analog-section"),
    (lambda: driver("discrete", DriverConfig(tau_theta=2.5), _loss),
     "integer"),
    (lambda: driver("probe_parallel", DriverConfig(mode="central"), _loss),
     "mesh"),
    (lambda: driver("analog", MGDConfig(), _loss), "discrete Algorithm 1"),
    (lambda: driver("discrete", AnalogMGDConfig(), _loss), "Algorithm 2"),
])
def test_ambiguous_mixes_rejected(build, match):
    with pytest.raises((ValueError, TypeError), match=match):
        build()


def test_probe_parallel_rejects_forward_mode_and_probes():
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("pod",))
    with pytest.raises(ValueError, match="central"):
        driver("probe_parallel", DriverConfig(), _loss, mesh=mesh)
    with pytest.raises(ValueError, match="probes"):
        driver("probe_parallel", DriverConfig(mode="central", probes=4),
               _loss, mesh=mesh)


# ---------------------------------------------------------------------------
# ADC cost readout (mixed-precision readout satellite)
# ---------------------------------------------------------------------------


def test_adc_rounds_cost_to_grid():
    plant = QuantizedPlant(_loss, bits=12, adc_bits=6, adc_range=1.0)
    c = plant.read_cost(_params(), BATCH, step=0)
    code = float(c) / plant.adc_lsb
    assert abs(code - round(code)) < 1e-4
    # the pair readout converts each half independently
    theta = jax.tree_util.tree_map(lambda x: 0.01 * jnp.ones_like(x),
                                   _params())
    cp, cm = plant.read_cost_pair(_params(), theta, BATCH, step=0)
    for v in (cp, cm):
        code = float(v) / plant.adc_lsb
        assert abs(code - round(code)) < 1e-4


def test_adc_floors_small_c_tilde_stochastic_recovers():
    """Sub-LSB cost differences vanish under deterministic rounding but
    survive (in expectation) under stochastic rounding."""
    det = QuantizedPlant(_loss, bits=12, adc_bits=4, adc_range=1.0)
    c1 = det.read_cost(_params(), BATCH, step=0, tag=0)
    c2 = det.read_cost(jax.tree_util.tree_map(
        lambda x: x + 1e-4, _params()), BATCH, step=0, tag=1)
    assert float(c1) == float(c2)     # Δcost ≪ LSB: identical codes

    sto = QuantizedPlant(_loss, bits=12, adc_bits=4, adc_range=1.0,
                         adc_mode="stochastic", seed=0)
    reads = [float(sto.read_cost(_params(), BATCH, step=s, tag=0))
             for s in range(400)]
    exact = float(_loss(_params(), BATCH))
    assert len({round(r / sto.adc_lsb) for r in reads}) >= 2  # dithers
    assert abs(np.mean(reads) - exact) < sto.adc_lsb / 4      # unbiased


def test_adc_validation():
    with pytest.raises(ValueError, match="adc_mode"):
        QuantizedPlant(_loss, adc_bits=8, adc_mode="truncate")
    with pytest.raises(ValueError, match="ADC"):
        QuantizedPlant(_loss, adc_bits=0)
