"""Distribution substrate: logical sharding translation (in-process) and
mesh-dependent behaviour (subprocess with virtual devices — the main test
process must keep the single real CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str, n_devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# --- in-process: logical translation is pure metadata ----------------------


class FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def test_logical_spec_translation():
    spec = shd.logical_spec((256, 4096), ["batch", None], FakeMesh())
    assert spec == P(("pod", "data"), None)


def test_logical_spec_drops_nondivisible():
    # batch 1 can't shard anywhere; kvseq picks up data×model
    spec = shd.logical_spec((1, 524288), ["batch", "kvseq"], FakeMesh())
    assert spec == P(None, ("data", "model"))


def test_logical_spec_dedups_axes():
    # batch eats pod+data; kvseq then only gets model
    spec = shd.logical_spec((128, 32768), ["batch", "kvseq"], FakeMesh())
    assert spec == P(("pod", "data"), "model")


def test_logical_spec_partial_axis_drop():
    # dim 8 divides data(16)? no → drop to pod(2)? 8 % 2 == 0 → ("pod",)
    spec = shd.logical_spec((8,), ["batch"], FakeMesh())
    assert spec == P("pod")


def test_param_specs_right_alignment():
    rules = [(r"w$", ("fsdp", "model"))]
    tree = {"layers": {"w": jax.ShapeDtypeStruct((28, 4096, 1024),
                                                 jax.numpy.float32)}}
    specs = shd.param_specs(tree, rules, FakeMesh())
    assert specs["layers"]["w"] == P(None, "data", "model")


# --- subprocess: actual multi-device semantics ------------------------------


def test_probe_parallel_converges():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.distributed.compat import make_mesh
        mesh = make_mesh((2, 2), ("pod", "data"))
        from repro.core.mgd import MGDConfig
        from repro.core.probe_parallel import build_probe_parallel_step
        target = jnp.array([1.0, -2.0, 3.0, 0.5])
        def loss(p, batch):
            pred = batch["x"] @ p["w"]
            return jnp.mean((pred - batch["x"] @ target)**2)
        params = {"w": jnp.zeros(4)}
        cfg = MGDConfig(mode="central", dtheta=1e-3, eta=0.1)
        step_fn = build_probe_parallel_step(loss, cfg, mesh)
        key = jax.random.PRNGKey(0)
        p = params
        for i in range(2000):
            x = jax.random.normal(jax.random.fold_in(key, i), (8, 4))
            p, m = step_fn(p, i, {"x": x})
        err = float(jnp.max(jnp.abs(p["w"] - target)))
        print("ERR", err)
        assert err < 0.05, err
    """, n_devices=4)
    assert "ERR" in out


def test_pipeline_forward_exact():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.distributed.compat import make_mesh
        mesh = make_mesh((4,), ("pod",))
        from repro.distributed.pipeline import pipeline_forward
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (4, 8, 8)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        stage = lambda w, x: jnp.tanh(x @ w)
        y = pipeline_forward(stage, ws, x, mesh=mesh, axis="pod",
                             microbatches=4)
        ref = x
        for i in range(4):
            ref = jnp.tanh(ref @ ws[i])
        err = float(jnp.max(jnp.abs(y - ref)))
        print("ERR", err)
        assert err < 1e-5, err
    """, n_devices=4)
    assert "ERR" in out


def test_sharded_mgd_step_runs_on_mesh():
    """A small dense model's MGD step executes (not just compiles) on an
    8-device (2,4) mesh with the production sharding rules."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, functools
        from repro.distributed.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        from repro.configs import get_smoke_config
        from repro.core import MGDConfig, build_mgd_step, mgd_init
        from repro.distributed import sharding as shd
        from repro.launch import specs
        from repro.models import model_init, model_loss
        cfg = get_smoke_config("qwen3-14b").replace(
            d_model=64, n_heads=4, n_kv_heads=4, d_head=16, vocab=128)
        mgd_cfg = MGDConfig(dtheta=1e-2, eta=0.1)
        with shd.use_mesh(mesh):
            params = model_init(cfg, jax.random.PRNGKey(0))
            shardings = specs.param_shardings(cfg, mesh)
            params = jax.device_put(params, shardings)
            loss_fn = lambda p, b: model_loss(p, cfg, b)
            step = jax.jit(build_mgd_step(loss_fn, mgd_cfg))
            state = mgd_init(params, mgd_cfg)
            toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                      cfg.vocab)
            batch = {"tokens": toks, "labels": toks}
            costs = []
            for i in range(30):
                params, state, m = step(params, state, batch)
                costs.append(float(m["cost"]))
        print("COSTS", costs[0], costs[-1])
        assert costs[-1] == costs[-1]  # no NaN
    """, n_devices=8)
    assert "COSTS" in out


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint on a (2,4) mesh, restore onto (4,2) and (1-device) —
    elastic scaling."""
    out = _run_subprocess(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.compat import make_mesh
        from repro.training import checkpoint as ckpt
        params = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh1 = make_mesh((2, 4), ("data", "model"))
        sh1 = {{"w": NamedSharding(mesh1, P("data", "model"))}}
        p1 = jax.device_put(params, sh1)
        ckpt.save(r"{tmp_path}", 3, p1)
        mesh2 = make_mesh((4, 2), ("data", "model"))
        sh2 = {{"w": NamedSharding(mesh2, P("model", "data"))}}
        p2, _, step = ckpt.restore(r"{tmp_path}", params, shardings=sh2)
        np.testing.assert_array_equal(np.asarray(p2["w"]),
                                      np.asarray(params["w"]))
        p3, _, _ = ckpt.restore(r"{tmp_path}", params)   # single device
        np.testing.assert_array_equal(np.asarray(p3["w"]),
                                      np.asarray(params["w"]))
        print("ELASTIC OK", step)
    """, n_devices=8)
    assert "ELASTIC OK" in out
