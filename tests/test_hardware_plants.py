"""Hardware plant abstraction: parity, composition, and device models.

The load-bearing contracts:
* ``IdealPlant`` / ``NoisyPlant(σ=0)`` are bit-identical (f32) to the
  implicit in-process path for BOTH optimizer drivers (Algorithm 1
  discrete, Algorithm 2 continuous) — the refactor moved the noise, not
  the numerics.
* ``MGDConfig(fused=True)`` reaches the Pallas kernels through
  ``Plant.apply_perturbed`` and produces the same trajectory as handing
  ``probe_fn`` to the optimizer directly.
* ``ExternalPlant`` drives an opaque host device end-to-end with no
  optimizer-side access to device internals.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalogMGDConfig, MGDConfig, analog_init,
                        build_analog_step, build_mgd_step, mgd_init, mse)
from repro.data import tasks
from repro.hardware import (ExternalPlant, IdealPlant, NoisyPlant, Plant,
                            PlantMeta, QuantizedPlant, SimulatedAnalogChip,
                            noisy_mlp_plant, quantized_mlp_plant)
from repro.models.simple import make_mlp_probe_fn, mlp_apply, mlp_init

X, Y = tasks.xor_dataset()
BATCH = {"x": X, "y": Y}


def _loss(p, b):
    return mse(mlp_apply(p, b["x"]), b["y"])


def _params():
    return mlp_init(jax.random.PRNGKey(0), (2, 2, 1))


def _run_mgd(cfg, plant=None, steps=24, loss_fn=_loss, probe_fn=None):
    p = _params()
    step = jax.jit(build_mgd_step(loss_fn, cfg, probe_fn=probe_fn,
                                 plant=plant))
    s = mgd_init(p, cfg)
    cts = []
    for _ in range(steps):
        p, s, m = step(p, s, BATCH)
        cts.append(float(m["c_tilde"]))
    return p, cts


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# Parity: plants reproduce the in-process path bit-for-bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["forward", "central"])
@pytest.mark.parametrize("replay", [False, True])
def test_ideal_and_sigma0_bit_identical_alg1(mode, replay):
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, mode=mode, replay=replay,
                    tau_theta=4 if replay else 1, seed=3)
    p_implicit, ct_implicit = _run_mgd(cfg)
    p_ideal, ct_ideal = _run_mgd(cfg, plant=IdealPlant(_loss))
    p_noisy0, ct_noisy0 = _run_mgd(cfg, plant=NoisyPlant(
        _loss, cost_noise=0.0, write_noise=0.0, dtheta=cfg.dtheta,
        seed=cfg.seed))
    _assert_trees_equal(p_implicit, p_ideal)
    _assert_trees_equal(p_implicit, p_noisy0)
    assert ct_implicit == ct_ideal == ct_noisy0


def test_ideal_and_sigma0_bit_identical_alg2():
    cfg = AnalogMGDConfig(dtheta=1e-2, eta=1e-3, ptype="sinusoidal")
    target = jnp.array([1.0, -2.0, 3.0])
    loss = lambda p, b: jnp.sum((p["w"] - target) ** 2)    # noqa: E731
    p0 = {"w": jnp.zeros(3)}

    def run(plant):
        step = jax.jit(build_analog_step(loss, cfg, plant=plant))
        p, s = p0, analog_init(p0, cfg)
        for _ in range(100):
            p, s, _ = step(p, s, None)
        return p

    p_implicit = run(None)
    _assert_trees_equal(p_implicit, run(IdealPlant(loss)))
    _assert_trees_equal(p_implicit, run(NoisyPlant(
        loss, cost_noise=0.0, dtheta=cfg.dtheta, seed=cfg.seed)))


def test_cfg_noise_equals_explicit_noisy_plant():
    """MGDConfig.cost_noise/update_noise are the implicit NoisyPlant —
    the historical key derivation is preserved bit-for-bit."""
    cfg_noise = MGDConfig(dtheta=1e-2, eta=1.0, cost_noise=1e-3,
                          update_noise=0.01, seed=5)
    cfg_clean = dataclasses.replace(cfg_noise, cost_noise=0.0,
                                    update_noise=0.0)
    p_cfg, ct_cfg = _run_mgd(cfg_noise)
    p_plant, ct_plant = _run_mgd(cfg_clean, plant=NoisyPlant(
        _loss, cost_noise=1e-3, write_noise=0.01, dtheta=1e-2, seed=5))
    _assert_trees_equal(p_cfg, p_plant)
    assert ct_cfg == ct_plant


@pytest.mark.parametrize("mode", ["forward", "central"])
def test_fused_through_plant_matches_direct_probe_fn(mode):
    """cfg.fused reaches the kernels via Plant.apply_perturbed; handing
    probe_fn to the optimizer or to the plant is the same trajectory."""
    probe_fn = make_mlp_probe_fn()
    # eta=1.0 deliberately: the historically broken corner (XLA folded
    # (-eta)·e to a negation, exposing θ̃·s to FMA contraction) — fixed by
    # the sign-last update forms; test_fused_probe pins both 0.5 and 1.0.
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, mode=mode, fused=True, seed=2,
                    kernel_impl="interpret")
    p_direct, ct_direct = _run_mgd(cfg, probe_fn=probe_fn)
    p_plant, ct_plant = _run_mgd(
        cfg, plant=IdealPlant(_loss, probe_fn=probe_fn))
    _assert_trees_equal(p_direct, p_plant)
    assert ct_direct == ct_plant

    # and the fused trajectory equals the materializing one (the PR-1
    # contract, now routed through the plant)
    p_mat, ct_mat = _run_mgd(dataclasses.replace(cfg, fused=False))
    _assert_trees_equal(p_plant, p_mat)
    assert ct_plant == ct_mat


def test_probe_parallel_accepts_plant():
    from jax.sharding import Mesh
    from repro.core.probe_parallel import build_probe_parallel_step
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("pod",))
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, mode="central", seed=1)
    p0 = _params()
    batch = {"x": X[None], "y": Y[None]}      # [pods, ...] shard layout
    step_a = build_probe_parallel_step(_loss, cfg, mesh)
    step_b = build_probe_parallel_step(None, cfg, mesh,
                                      plant=IdealPlant(_loss))
    pa, _ = step_a(p0, 0, batch)
    pb, _ = step_b(p0, 0, batch)
    _assert_trees_equal(pa, pb)


# ---------------------------------------------------------------------------
# Composition / validation
# ---------------------------------------------------------------------------


def test_explicit_plant_rejects_cfg_noise():
    cfg = MGDConfig(cost_noise=0.1)
    with pytest.raises(ValueError, match="explicit plant"):
        build_mgd_step(_loss, cfg, plant=IdealPlant(_loss))


def test_plant_type_checked():
    with pytest.raises(TypeError):
        build_mgd_step(_loss, MGDConfig(), plant=object())


def test_loss_fn_optional_only_with_plant():
    with pytest.raises(ValueError):
        build_mgd_step(None, MGDConfig())
    build_mgd_step(None, MGDConfig(), plant=IdealPlant(_loss))  # fine


def test_external_requires_cond_free_step():
    """Ordered host callbacks can only ride the central τ_θ=1 step."""
    plant = ExternalPlant(SimulatedAnalogChip((2, 2, 1)))
    for bad in (MGDConfig(mode="forward"),
                MGDConfig(mode="central", tau_theta=4),
                MGDConfig(mode="central", tau_theta=4, replay=True)):
        with pytest.raises(ValueError, match="external plants"):
            build_mgd_step(None, bad, plant=plant)


def test_shared_plant_not_mutated_by_probe_fn():
    """Handing probe_fn to build_mgd_step must not stick it onto a plant
    shared with another optimizer (and conflicting probe_fns error)."""
    plant = IdealPlant(_loss)
    pf = make_mlp_probe_fn()
    build_mgd_step(None, MGDConfig(fused=True), probe_fn=pf, plant=plant)
    assert plant.probe_fn is None
    plant2 = IdealPlant(_loss, probe_fn=pf)
    with pytest.raises(ValueError, match="probe_fn"):
        build_mgd_step(None, MGDConfig(), probe_fn=make_mlp_probe_fn(),
                      plant=plant2)


def test_noisy_write_noise_perturbs_updates():
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, seed=3)
    p_clean, _ = _run_mgd(cfg, plant=IdealPlant(_loss), steps=4)
    p_noisy, _ = _run_mgd(cfg, plant=NoisyPlant(
        _loss, write_noise=0.5, dtheta=1e-2, seed=3), steps=4)
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree_util.tree_leaves(p_clean),
                             jax.tree_util.tree_leaves(p_noisy))]
    assert max(diffs) > 0.0


# ---------------------------------------------------------------------------
# QuantizedPlant: DAC writes, slow-write lag
# ---------------------------------------------------------------------------


def test_quantized_writes_land_on_dac_grid():
    plant = QuantizedPlant(_loss, bits=6, w_clip=2.0)
    p = plant.write_params(_params(), step=0)
    lsb = plant.lsb
    for leaf in jax.tree_util.tree_leaves(p):
        codes = (np.asarray(leaf) + 2.0) / lsb
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)


def test_quantized_high_bits_tracks_ideal():
    """A 14-bit DAC (LSB ≈ 2.4e-4) barely perturbs a Δθ = 1e-2 run."""
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, seed=3)
    p_ideal, _ = _run_mgd(cfg, plant=IdealPlant(_loss), steps=40)
    p_q, _ = _run_mgd(cfg, plant=QuantizedPlant(_loss, bits=14), steps=40)
    for a, b in zip(jax.tree_util.tree_leaves(p_ideal),
                    jax.tree_util.tree_leaves(p_q)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)


def test_slow_write_lags_commanded_target():
    plant = QuantizedPlant(_loss, bits=12, write_tau=4.0)
    prev = {"w": jnp.zeros(3)}
    target = {"w": jnp.ones(3)}
    landed = plant.write_params(target, step=0, prev=prev)
    frac = float(landed["w"][0])
    # one write event moves 1 − e^{−1/τ} ≈ 0.221 of the way
    assert 0.15 < frac < 0.3, frac


def test_sub_lsb_probes_invisible_when_probes_quantized():
    """Δθ below the DAC LSB: a probe that must round-trip the DAC reads
    C̃ = 0 — the scenario the paper motivates (quantization floors the
    trainable Δθ)."""
    plant = QuantizedPlant(_loss, bits=4, quantize_probes=True)
    assert plant.lsb > 4e-2
    cfg = MGDConfig(dtheta=1e-3, eta=1.0, mode="central", seed=0)
    p0 = plant.write_params(_params(), step=0)
    step = jax.jit(build_mgd_step(None, cfg, plant=plant))
    s = mgd_init(p0, cfg)
    _, _, m = step(p0, s, BATCH)
    assert float(m["c_tilde"]) == 0.0


# ---------------------------------------------------------------------------
# ExternalPlant: chip in the loop
# ---------------------------------------------------------------------------


def test_external_plant_trains_through_opaque_interface():
    chip = SimulatedAnalogChip((2, 2, 1), seed=0, sigma_a=0.1,
                               sigma_theta=0.005, sigma_c=1e-4)
    plant = ExternalPlant(chip)
    cfg = MGDConfig(dtheta=2e-2, eta=0.5, mode="central", seed=0)
    p = _params()
    s = mgd_init(p, cfg)
    step = jax.jit(build_mgd_step(None, cfg, plant=plant))
    costs = []
    for _ in range(60):
        p, s, m = step(p, s, BATCH)
        costs.append(float(m["cost"]))
    assert np.isfinite(costs).all()
    # 1 base-θ pair write (the chip has a differential probe line) +
    # 1 update write per step went to the instrument
    assert chip.writes == 2 * 60
    # the trainer moved the needle on the *chip's* cost readout
    assert np.mean(costs[-10:]) < np.mean(costs[:10])


def test_external_plant_rejects_non_device():
    with pytest.raises(TypeError, match="set_params"):
        ExternalPlant(object())


def test_plant_meta_latency_projection():
    meta = PlantMeta(name="hw1", read_latency_s=1e-3, write_latency_s=1e-4)
    assert meta.step_latency_s(reads_per_step=2, writes_per_step=1) == \
        pytest.approx(2.1e-3)
