"""The bench-regression gate's comparator (no benchmarks are run).

Load-bearing:
* A gated metric inside its tolerance band passes; outside fails.
* ``direction="min"`` gates only the drop — improvements pass.
* Baseline files whose gated metrics ALL vanished from the fresh run
  fail loudly (renames must update the tolerance table, not un-gate).
* ``--self-test`` proves end-to-end that a perturbed committed baseline
  is caught — the acceptance check CI runs next to the real gate.
"""
import json
import os

import pytest

from benchmarks import check_regression as cr

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "..",
                            "artifacts", "bench")


def rows(**named):
    return [{"bench": "x", "name": k, "value": v} for k, v in named.items()]


def test_spec_matching_first_wins():
    assert cr.spec_for("drift_aging", "retrim_hold_frac")["abs"] == 0.04
    assert cr.spec_for("fused_probe", "mlp_central_wread_ratio") is not None
    # timing rows carry no spec → informational
    assert cr.spec_for("fused_probe", "mlp_central_fused") is None
    assert cr.spec_for("unknown_bench", "anything") is None


def test_within_band_passes_and_beyond_fails():
    base = rows(mlp_central_wread_ratio=4.0)
    ok, checked, _ = cr.compare_file(
        "fused_probe", rows(mlp_central_wread_ratio=4.0005), base)
    assert (ok, checked) == (0, 1)
    bad, checked, findings = cr.compare_file(
        "fused_probe", rows(mlp_central_wread_ratio=2.0), base)
    assert (bad, checked) == (1, 1)
    assert any(status == "FAIL" for status, _, _ in findings)


def test_direction_min_gates_only_drops():
    base = rows(retrim_hold_frac=0.888)
    better, _, _ = cr.compare_file(
        "drift_aging", rows(retrim_hold_frac=0.99), base)
    assert better == 0
    worse, _, _ = cr.compare_file(
        "drift_aging", rows(retrim_hold_frac=0.80), base)
    assert worse == 1


def test_ungated_metric_is_informational():
    base = rows(mlp_central_fused=1000.0)
    violations, checked, findings = cr.compare_file(
        "fused_probe", rows(mlp_central_fused=3.0), base)
    # a 300x slowdown in a timing row never gates
    assert (violations, checked) == (0, 0)
    assert findings[0][0] == "info"


def test_fresh_metric_without_baseline_warns():
    violations, checked, findings = cr.compare_file(
        "drift_aging", rows(retrim_hold_frac=0.9),
        rows(driftfree_accuracy=0.83))
    # the fresh metric is gated but unbaselined → warn; meanwhile the
    # baseline's own gated metric went unmatched → the no-match guard
    # fires because checked == 0
    assert checked == 0
    assert violations == 1
    assert any(status == "warn" for status, _, _ in findings)


def test_all_gated_metrics_vanishing_fails():
    base = rows(retrim_hold_frac=0.888, driftfree_accuracy=0.83)
    violations, checked, findings = cr.compare_file(
        "drift_aging", rows(renamed_hold_metric=0.9), base)
    assert checked == 0
    assert violations == 1
    assert any(name == "<gate>" for _, name, _ in findings)


def test_compare_dirs_identity_passes_on_committed_baselines():
    assert cr.compare_dirs(BASELINE_DIR, BASELINE_DIR, verbose=False) == 0


def test_perturbed_committed_baseline_fails(tmp_path):
    """The acceptance check: perturb one gated metric in a copy of the
    committed artifacts beyond its tolerance — the gate must exit
    non-zero."""
    src = os.path.join(BASELINE_DIR, "drift_aging.json")
    with open(src) as f:
        payload = json.load(f)
    perturbed = [dict(r, value=0.1) if r["name"] == "retrim_hold_frac"
                 else r for r in payload["rows"]]
    assert perturbed != payload["rows"]
    with open(tmp_path / "drift_aging.json", "w") as f:
        json.dump({**payload, "rows": perturbed}, f)
    assert cr.compare_dirs(str(tmp_path), BASELINE_DIR, verbose=False) > 0


def test_self_test_green_on_committed_baselines(capsys):
    assert cr.self_test(BASELINE_DIR) == 0
    assert "self-test OK" in capsys.readouterr().out


def test_empty_fresh_dir_fails(tmp_path):
    assert cr.compare_dirs(str(tmp_path), BASELINE_DIR, verbose=False) == 1


def test_main_cli(tmp_path):
    assert cr.main(["--fresh", BASELINE_DIR, "--baseline", BASELINE_DIR]) == 0
    assert cr.main(["--self-test", "--baseline", BASELINE_DIR]) == 0
    assert cr.main(["--fresh", str(tmp_path), "--baseline", BASELINE_DIR]) == 1
