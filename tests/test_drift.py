"""Drift/aging device contracts.

Load-bearing:
* ``DriftingPlant`` transitions are keyed on the step counter — same
  seed + same step range ⇒ the identical drifted weights after a
  restart, for both the OU-walk and decay-toward-rest modes.
* Every algorithm (discrete, analog, probe_parallel_external) trains
  THROUGH a drifting device with bit-exact checkpoint/resume: a resumed
  run is the uninterrupted run.
* A farm of chips with HETEROGENEOUS drift rates keeps the per-chip
  aging distinguishable across the resume (drift is part of the device,
  keyed on its seed, not of the training state).
* ``train_mgd``'s scheduled-recalibration hook rewrites the device from
  the shadow params on a schedule that is a pure function of the global
  step (resume-safe), and the rewrite lands through the plant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import DriverConfig
from repro.core import AnalogMGDConfig, mse
from repro.data import tasks
from repro.hardware import (DriftingAnalogChip, DriftingPlant, ExternalPlant,
                            IdealPlant, NoisyPlant, SimulatedAnalogChip,
                            simulated_chip_farm)
from repro.models.simple import mlp_apply, mlp_init
from repro.training.train_loop import train_mgd

X, Y = tasks.xor_dataset()
BATCH = {"x": X, "y": Y}


def _loss(params, batch):
    return mse(mlp_apply(params, batch["x"]), batch["y"])


def _params(seed=0, sizes=(2, 2, 1)):
    return mlp_init(jax.random.PRNGKey(seed), sizes)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# The drift transition itself
# ---------------------------------------------------------------------------


def test_walk_replay_deterministic_across_rebuild():
    """Same seed + same step range ⇒ identical drifted weights, from a
    freshly constructed plant (nothing lives in the instance)."""
    p = _params()

    def walk():
        plant = DriftingPlant(IdealPlant(_loss), mode="walk",
                              drift_rate=0.05, seed=7)
        out = p
        for step in range(4, 12):
            out = plant.drift(out, step)
        return out

    _assert_trees_equal(walk(), walk())


def test_walk_steps_draw_distinct_kicks():
    p = _params()
    plant = DriftingPlant(IdealPlant(_loss), mode="walk", drift_rate=0.05,
                          seed=7)
    a = plant.drift(p, 3)
    b = plant.drift(p, 4)
    assert not np.allclose(np.asarray(jax.tree_util.tree_leaves(a)[1]),
                           np.asarray(jax.tree_util.tree_leaves(b)[1]))


def test_decay_relaxes_toward_rest_exactly():
    """Pure decay (no diffusion) is the closed-form exponential toward
    rest — n transitions contract the distance by exp(−n/τ)."""
    p = _params()
    tau, rest, n = 5.0, 0.25, 10
    plant = DriftingPlant(IdealPlant(_loss), mode="decay", drift_tau=tau,
                          rest=rest, seed=0)
    aged = plant.age(p, 0, n)
    factor = np.exp(-n / tau)
    for la, lb in zip(jax.tree_util.tree_leaves(p),
                      jax.tree_util.tree_leaves(aged)):
        np.testing.assert_allclose(
            np.asarray(lb), rest + factor * (np.asarray(la) - rest),
            rtol=1e-5)


def test_age_matches_unrolled_drift():
    """``age`` is the fori_loop of ``drift`` — equal to the eager unroll
    up to XLA's FMA contraction of the decay blend (the jitted training
    path itself is bit-stable; the resume tests below pin that)."""
    p = _params()
    plant = DriftingPlant(IdealPlant(_loss), mode="walk", drift_rate=0.02,
                          drift_tau=50.0, seed=3)
    unrolled = p
    for step in range(5, 9):
        unrolled = plant.drift(unrolled, step)
    for la, lb in zip(jax.tree_util.tree_leaves(plant.age(p, 5, 4)),
                      jax.tree_util.tree_leaves(unrolled)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-8)


def test_write_lands_through_inner_then_drifts():
    """Composition order: the inner device's write imperfections apply
    first, then one aging transition of what landed."""
    p = _params()
    inner = NoisyPlant(_loss, write_noise=0.5, dtheta=1e-2, seed=9)
    plant = DriftingPlant(inner, mode="walk", drift_rate=0.05, seed=9)
    landed = plant.write_params(p, step=6)
    _assert_trees_equal(landed,
                        plant.drift(inner.write_params(p, step=6), 6))


def test_drift_meta_fields():
    plant = DriftingPlant(IdealPlant(_loss), mode="walk", drift_rate=0.01,
                          drift_tau=30.0, rest=0.5)
    assert plant.meta.drift_mode == "walk"
    assert plant.meta.drift_rate == 0.01
    assert plant.meta.drift_tau == 30.0
    assert plant.meta.drift_rest == 0.5
    assert not plant.meta.external


@pytest.mark.parametrize("build,match", [
    (lambda: DriftingPlant(IdealPlant(_loss), mode="brownian",
                           drift_rate=0.1), "walk' or 'decay"),
    (lambda: DriftingPlant(IdealPlant(_loss), mode="walk"), "drift_rate"),
    (lambda: DriftingPlant(IdealPlant(_loss), mode="decay"), "drift_tau"),
    (lambda: DriftingPlant(_loss, mode="walk", drift_rate=0.1),
     "repro.hardware.Plant"),
    (lambda: DriftingPlant(ExternalPlant(SimulatedAnalogChip((2, 2, 1))),
                           mode="walk", drift_rate=0.1),
     "DriftingAnalogChip"),
])
def test_drifting_plant_validation(build, match):
    with pytest.raises((ValueError, TypeError), match=match):
        build()


# ---------------------------------------------------------------------------
# Training through a drifting device, with bit-exact resume
# ---------------------------------------------------------------------------


def _drift_plant(rate=0.01, seed=5):
    return DriftingPlant(IdealPlant(_loss), mode="walk", drift_rate=rate,
                         seed=seed)


def test_discrete_resume_bit_exact_through_drift(tmp_path):
    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=1)
    p0 = _params(2)
    sample_fn = lambda i: BATCH                        # noqa: E731

    cont = train_mgd(_loss, p0, cfg, sample_fn, 16, plant=_drift_plant(),
                     chunk=4, log=None)
    train_mgd(_loss, p0, cfg, sample_fn, 8, plant=_drift_plant(),
              chunk=4, log=None, checkpoint_dir=str(tmp_path),
              checkpoint_every=8)
    res = train_mgd(_loss, p0, cfg, sample_fn, 16, plant=_drift_plant(),
                    chunk=4, log=None, checkpoint_dir=str(tmp_path))
    assert res.steps_done == 16
    _assert_trees_equal(cont.params, res.params)
    _assert_trees_equal(cont.state, res.state)


def test_analog_resume_bit_exact_through_drift(tmp_path):
    cfg = AnalogMGDConfig(dtheta=1e-2, eta=1e-3, seed=2)
    p0 = _params(3)
    sample_fn = lambda i: BATCH                        # noqa: E731

    cont = train_mgd(_loss, p0, cfg, sample_fn, 16,
                     plant=_drift_plant(rate=0.005), chunk=4, log=None)
    train_mgd(_loss, p0, cfg, sample_fn, 8, plant=_drift_plant(rate=0.005),
              chunk=4, log=None, checkpoint_dir=str(tmp_path),
              checkpoint_every=8)
    res = train_mgd(_loss, p0, cfg, sample_fn, 16,
                    plant=_drift_plant(rate=0.005), chunk=4, log=None,
                    checkpoint_dir=str(tmp_path))
    _assert_trees_equal(cont.params, res.params)
    _assert_trees_equal(cont.state, res.state)


def test_probe_averaged_retrim_deterministic():
    """The drift benchmark's re-trim configuration (central, probes=4)
    walks the same f32 trajectory on every fresh run."""
    cfg = DriverConfig(dtheta=1e-2, eta=0.8, mode="central", probes=4,
                       seed=0)

    def run():
        mgd = repro.driver("discrete", cfg, _loss, plant=_drift_plant())
        p, s = _params(1), mgd.init(_params(1))
        for _ in range(8):
            p, s, m = mgd.step(p, s, BATCH)
        return p

    _assert_trees_equal(run(), run())


# ---------------------------------------------------------------------------
# Drifting chips behind the host boundary
# ---------------------------------------------------------------------------


def test_drifting_chip_hold_aging_replays():
    """A held chip (write once, read later) ages deterministically: the
    aged readout is a pure function of (seed, write step, read step)."""
    def build():
        chip = DriftingAnalogChip((2, 2, 1), seed=4, sigma_a=0.1,
                                  sigma_theta=0.0, sigma_c=0.0,
                                  drift_rate=0.05)
        chip.set_params(_params(), step=0)
        return chip

    a, b = build(), build()
    assert a.measure_cost(BATCH, step=20, tag=0) \
        == b.measure_cost(BATCH, step=20, tag=0)
    # aging changed the readout; repeating the same read does not
    assert a.measure_cost(BATCH, step=20, tag=0) \
        != a.measure_cost(BATCH, step=0, tag=0)
    assert a.measure_cost(BATCH, step=20, tag=0) \
        == a.measure_cost(BATCH, step=20, tag=0)


def test_drifting_chip_stepless_write_reads_unaged():
    chip = DriftingAnalogChip((2, 2, 1), seed=4, sigma_a=0.0,
                              sigma_theta=0.0, sigma_c=0.0, drift_rate=0.5)
    stable = SimulatedAnalogChip((2, 2, 1), seed=4, sigma_a=0.0,
                                 sigma_theta=0.0, sigma_c=0.0)
    chip.set_params(_params())          # bench-harness write, no step
    stable.set_params(_params())
    assert chip.measure_cost(BATCH, step=30, tag=0) \
        == stable.measure_cost(BATCH, step=30, tag=0)


def test_external_plant_forwards_write_step():
    """ExternalPlant timestamps persistent writes on step-capable
    devices, so training through the boundary ages deterministically —
    and the aging is NOT a no-op: every read sees at least the
    write-settle transition, so a drifting chip's trajectory departs
    from the stable chip's."""
    def run(drift_rate):
        if drift_rate:
            chip = DriftingAnalogChip((2, 2, 1), seed=1, sigma_a=0.1,
                                      sigma_theta=0.0, sigma_c=1e-3,
                                      drift_rate=drift_rate)
        else:
            chip = SimulatedAnalogChip((2, 2, 1), seed=1, sigma_a=0.1,
                                       sigma_theta=0.0, sigma_c=1e-3)
        plant = ExternalPlant(chip)
        cfg = DriverConfig(dtheta=1e-2, eta=0.2, mode="central", seed=0)
        mgd = repro.driver("discrete", cfg, plant=plant)
        p, s = _params(), mgd.init(_params())
        for _ in range(6):
            p, s, m = mgd.step(p, s, BATCH)
            jax.block_until_ready(p)
        return p, chip

    (p_a, chip_a), (p_b, chip_b) = run(0.05), run(0.05)
    _assert_trees_equal(p_a, p_b)
    assert chip_a.measure_cost(BATCH, step=6, tag=0) \
        == chip_b.measure_cost(BATCH, step=6, tag=0)
    p_stable, _ = run(0.0)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(p_a),
                        jax.tree_util.tree_leaves(p_stable)))


def test_farm_heterogeneous_drift_resume_distinguishable(tmp_path):
    """Two chips with different drift rates, trained through the farm
    driver with a checkpoint/resume in the middle: the trainer's
    trajectory is bit-exact, and the per-chip aging stays distinct and
    replay-identical chip by chip."""
    def farm():
        return simulated_chip_farm(2, (2, 2, 1), base_seed=1, sigma_a=0.1,
                                   sigma_theta=0.0, sigma_c=0.0,
                                   drift_rates=(0.0, 0.05))

    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=4)
    p0 = _params(2)
    sample_fn = lambda i: BATCH                        # noqa: E731

    farm_cont = farm()
    cont = train_mgd(None, p0, cfg, sample_fn, 12,
                     algorithm="probe_parallel_external", plant=farm_cont,
                     chunk=4, log=None)
    train_mgd(None, p0, cfg, sample_fn, 8,
              algorithm="probe_parallel_external", plant=farm(),
              chunk=4, log=None, checkpoint_dir=str(tmp_path),
              checkpoint_every=8)
    farm_res = farm()
    res = train_mgd(None, p0, cfg, sample_fn, 12,
                    algorithm="probe_parallel_external", plant=farm_res,
                    chunk=4, log=None, checkpoint_dir=str(tmp_path))
    _assert_trees_equal(cont.params, res.params)
    _assert_trees_equal(cont.state, res.state)

    # chip-by-chip: the resumed farm's devices read identically to the
    # uninterrupted farm's (same stored weights, same aging)...
    for i in range(2):
        assert farm_cont.devices[i].measure_cost(BATCH, step=12, tag=0) \
            == farm_res.devices[i].measure_cost(BATCH, step=12, tag=0)
    # ...the stable chip reads the same however long it is held, while
    # the drifting chip keeps aging — the rates stay distinguishable
    assert farm_res.devices[0].measure_cost(BATCH, step=12, tag=0) \
        == farm_res.devices[0].measure_cost(BATCH, step=40, tag=0)
    assert farm_res.devices[1].measure_cost(BATCH, step=12, tag=0) \
        != farm_res.devices[1].measure_cost(BATCH, step=40, tag=0)


# ---------------------------------------------------------------------------
# The scheduled-recalibration hook
# ---------------------------------------------------------------------------


def test_recal_hook_rewrites_from_shadow():
    """η = 0 + recal: the device state after the run is exactly the
    shadow pushed through the plant's write path at the last boundary,
    then drifted by the remaining steps — computed by hand here."""
    plant = _drift_plant(rate=0.1, seed=8)
    cfg = DriverConfig(dtheta=1e-2, eta=0.0, mode="central", seed=0)
    p0 = _params(0)
    res = train_mgd(_loss, p0, cfg, lambda i: BATCH, 5, plant=plant,
                    chunk=2, log=None, recal_every=4)

    # steps 0..3 drift the device, then the done=4 boundary rewrites it
    # from the shadow (the initial p0) through the plant, then step 4's
    # η=0 training write drifts once more
    expected = plant.write_params(p0, step=4)
    expected = plant.drift(expected, 4)
    _assert_trees_equal(res.params, expected)


def test_recal_pulls_aged_device_back():
    """With recalibration the device stays near the shadow; without it
    the walk wanders away."""
    cfg = DriverConfig(dtheta=1e-2, eta=0.0, mode="central", seed=0)
    p0 = _params(0)

    def dist(params):
        return float(sum(
            np.sum((np.asarray(a) - np.asarray(b)) ** 2)
            for a, b in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(p0))))

    free = train_mgd(_loss, p0, cfg, lambda i: BATCH, 40,
                     plant=_drift_plant(rate=0.05, seed=8), chunk=10,
                     log=None)
    recal = train_mgd(_loss, p0, cfg, lambda i: BATCH, 40,
                      plant=_drift_plant(rate=0.05, seed=8), chunk=10,
                      log=None, recal_every=5)
    assert dist(recal.params) < dist(free.params)


def test_recal_resume_bit_exact(tmp_path):
    """Recalibration boundaries are a pure function of the global step:
    a resumed recal run is the uninterrupted one."""
    cfg = DriverConfig(dtheta=1e-2, eta=0.3, mode="central", seed=3)
    p0 = _params(1)
    kw = dict(chunk=2, log=None, recal_every=4, recal_params=_params(9))

    cont = train_mgd(_loss, p0, cfg, lambda i: BATCH, 12,
                     **kw, plant=_drift_plant(rate=0.02))
    # checkpoint OFF the recal boundary: a run ENDING on one stops before
    # its recal (no rewrite after the final step), so its device state is
    # legitimately not the mid-run state a longer run has there
    train_mgd(_loss, p0, cfg, lambda i: BATCH, 6,
              **kw, plant=_drift_plant(rate=0.02),
              checkpoint_dir=str(tmp_path), checkpoint_every=6)
    res = train_mgd(_loss, p0, cfg, lambda i: BATCH, 12,
                    **kw, plant=_drift_plant(rate=0.02),
                    checkpoint_dir=str(tmp_path))
    _assert_trees_equal(cont.params, res.params)
    _assert_trees_equal(cont.state, res.state)


def test_recal_validation():
    with pytest.raises(ValueError, match="recal_every"):
        train_mgd(_loss, _params(), DriverConfig(), lambda i: BATCH, 4,
                  recal_every=-1, log=None)
