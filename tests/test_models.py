"""Per-architecture smoke tests + cross-implementation parity checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import (init_cache, model_decode, model_forward,
                          model_init, model_loss, model_prefill)
from repro.models.attention import chunked_causal_attention, decode_attention
from repro.models.linear_attention import (chunked_scalar_decay,
                                           chunked_vector_decay,
                                           step_scalar_decay,
                                           step_vector_decay)
from repro.models.rope import apply_mrope, apply_rope

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg):
    if cfg.family in ("vlm", "audio"):
        batch = {"embeds": jax.random.normal(
            KEY, (B, S, cfg.d_model), cfg.jdtype)}
        if cfg.n_codebooks:
            batch["labels"] = jax.random.randint(
                KEY, (B, S, cfg.n_codebooks), 0, cfg.vocab)
        else:
            batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        if cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3))
        return batch
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss_decode(arch):
    """Assigned-arch smoke test: one forward + loss + decode step on CPU,
    asserting output shapes and no NaNs (deliverable f)."""
    cfg = get_smoke_config(arch)
    params = model_init(cfg, KEY)
    batch = make_batch(cfg)
    logits = model_forward(params, cfg, batch)
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, S, cfg.vocab)
    loss = model_loss(params, cfg, batch)
    assert not bool(jnp.isnan(loss)), arch
    assert float(loss) > 0

    cache = init_cache(cfg, B, 64)
    if cfg.family in ("vlm", "audio"):
        emb1 = jax.random.normal(KEY, (B, 1, cfg.d_model), cfg.jdtype)
        lg, cache2 = model_decode(params, cfg, None, cache, embeds=emb1)
    else:
        tok1 = jax.random.randint(KEY, (B,), 0, cfg.vocab)
        lg, cache2 = model_decode(params, cfg, tok1, cache)
    assert not bool(jnp.any(jnp.isnan(lg.astype(jnp.float32)))), arch
    assert int(cache2["length"]) == 1


@pytest.mark.parametrize("arch", ["qwen3-14b", "rwkv6-7b", "zamba2-7b",
                                  "musicgen-medium"])
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forced decode from a prefixed cache must equal the full
    forward at every position (cache/state correctness)."""
    cfg = get_smoke_config(arch)
    params = model_init(cfg, KEY)
    if cfg.n_codebooks:
        toks = jax.random.randint(jax.random.PRNGKey(3),
                                  (B, cfg.n_codebooks, S), 0, cfg.vocab)
        batch = {"tokens": toks}
        full = model_forward(params, cfg, batch)
        pf, cache = model_prefill(
            params, cfg, {"tokens": toks[:, :, :16]}, 64)
        errs = [float(jnp.max(jnp.abs(pf[:, :16] - full[:, :16])))]
        for t in range(16, S):
            lg, cache = model_decode(params, cfg, toks[:, :, t], cache)
            errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    else:
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                  cfg.vocab)
        full = model_forward(params, cfg, {"tokens": toks})
        pf, cache = model_prefill(params, cfg, {"tokens": toks[:, :16]}, 64)
        errs = [float(jnp.max(jnp.abs(pf[:, :16] - full[:, :16])))]
        for t in range(16, S):
            lg, cache = model_decode(params, cfg, toks[:, t], cache)
            errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 5e-4, (arch, max(errs))


def test_mla_absorbed_decode_parity():
    """DeepSeek MLA: absorbed decode ≡ expand-form forward (dense MLP to
    exclude MoE capacity nondeterminism, tested separately)."""
    cfg = get_smoke_config("deepseek-v3-671b").replace(
        n_experts=0, n_experts_active=0, n_shared_experts=0)
    params = model_init(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab)
    full = model_forward(params, cfg, {"tokens": toks})
    pf, cache = model_prefill(params, cfg, {"tokens": toks[:, :16]}, 64)
    errs = [float(jnp.max(jnp.abs(pf[:, :16] - full[:, :16])))]
    for t in range(16, S):
        lg, cache = model_decode(params, cfg, toks[:, t], cache)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 5e-4, max(errs)


def test_moe_prefill_decode_parity_at_high_capacity():
    """With capacity ≥ E/k the MoE drops nothing and decode parity is
    exact even through the grouped dispatch."""
    cfg = get_smoke_config("deepseek-v3-671b").replace(
        moe_capacity_factor=8.0)
    params = model_init(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab)
    full = model_forward(params, cfg, {"tokens": toks})
    pf, cache = model_prefill(params, cfg, {"tokens": toks[:, :16]}, 64)
    errs = [float(jnp.max(jnp.abs(pf[:, :16] - full[:, :16])))]
    for t in range(16, S):
        lg, cache = model_decode(params, cfg, toks[:, t], cache)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 5e-4, max(errs)


def test_balanced_attention_equals_masked():
    cfg = get_smoke_config("qwen3-14b")
    params = model_init(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab)
    f1 = model_forward(params, cfg, {"tokens": toks})
    f2 = model_forward(params, cfg.replace(attn_impl="balanced"),
                       {"tokens": toks})
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               atol=1e-5)


def test_mrope_reduces_to_rope_for_text():
    """Equal (t,h,w) position ids must reproduce plain 1-D RoPE."""
    x = jax.random.normal(KEY, (2, 16, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    pos3 = jnp.broadcast_to(pos[..., None], (2, 16, 3))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_attention_gqa_grouping():
    """Grouped attention must equal explicit KV-head repetition."""
    q = jax.random.normal(KEY, (2, 64, 8, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))
    out = chunked_causal_attention(q, k, v, q_block=16, kv_block=16)
    kk = jnp.repeat(k, 4, axis=2)
    vv = jnp.repeat(v, 4, axis=2)
    ref = chunked_causal_attention(q, kk, vv, q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_vector_decay_vs_recurrence(chunk):
    b, s, h, dk, dv = 2, 64, 2, 8, 12
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dk)))
    u = jax.random.normal(ks[4], (h, dk))
    y, st = chunked_vector_decay(q, k, v, lw, u, chunk=chunk)
    st_r = jnp.zeros((b, h, dk, dv))
    for t in range(s):
        yr, st_r = step_vector_decay(q[:, t], k[:, t], v[:, t], lw[:, t],
                                     u, st_r)
        np.testing.assert_allclose(np.asarray(y[:, t]), np.asarray(yr),
                                   atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_r), atol=2e-3)


@pytest.mark.parametrize("chunk", [8, 32])
def test_chunked_scalar_decay_vs_recurrence(chunk):
    b, s, h, dk, dv = 2, 64, 2, 8, 12
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    la = -jnp.exp(jax.random.normal(ks[3], (b, s, h))) * 0.5
    y, st = chunked_scalar_decay(q, k, v, la, chunk=chunk)
    st_r = jnp.zeros((b, h, dk, dv))
    for t in range(s):
        yr, st_r = step_scalar_decay(q[:, t], k[:, t], v[:, t], la[:, t],
                                     st_r)
        np.testing.assert_allclose(np.asarray(y[:, t]), np.asarray(yr),
                                   atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_r), atol=2e-3)


def test_strong_decay_no_overflow():
    """Adversarial decay (w → e^-20): the masked-before-exp chunked form
    must stay finite (the naive q·e^A / k·e^-A factorization overflows)."""
    b, s, h, dk, dv = 1, 64, 1, 4, 4
    q = jnp.ones((b, s, h, dk))
    k = jnp.ones((b, s, h, dk))
    v = jnp.ones((b, s, h, dv))
    lw = jnp.full((b, s, h, dk), -20.0)
    y, st = chunked_vector_decay(q, k, v, lw, None, chunk=32)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(st)))
