"""End-to-end system behaviour: the full MGD training stack (data pipeline →
model → MGD optimizer → checkpoint) on an LM-scale smoke config, plus the
backprop baseline on identical substrate."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import MGDConfig
from repro.data.pipeline import lm_sampler
from repro.models import model_init, model_loss
from repro.training.train_loop import train_backprop, train_mgd


def test_mgd_trains_lm_smoke(tmp_path):
    """MGD reduces LM loss on a transformer; checkpoints + resumes."""
    cfg = get_smoke_config("qwen3-14b")
    params = model_init(cfg, jax.random.PRNGKey(0))
    loss_fn = lambda p, b: model_loss(p, cfg, b)    # noqa: E731
    sample_fn = lm_sampler(8, 32, cfg.vocab, seed=1)
    mgd_cfg = MGDConfig(dtheta=1e-2, eta=3e-2, mode="central", seed=0)
    res = train_mgd(loss_fn, params, mgd_cfg, sample_fn, 600, chunk=100,
                    checkpoint_dir=str(tmp_path), checkpoint_every=300,
                    log=None)
    first = res.history[0][1]["cost"]
    last = res.history[-1][1]["cost"]
    assert last < first, (first, last)

    # resume from checkpoint: continues from step 600 without error
    res2 = train_mgd(loss_fn, model_init(cfg, jax.random.PRNGKey(0)),
                     mgd_cfg, sample_fn, 700, chunk=100,
                     checkpoint_dir=str(tmp_path), log=None)
    assert res2.steps_done == 700


def test_backprop_baseline_same_substrate():
    cfg = get_smoke_config("qwen3-14b")
    params = model_init(cfg, jax.random.PRNGKey(0))
    loss_fn = lambda p, b: model_loss(p, cfg, b)    # noqa: E731
    sample_fn = lm_sampler(8, 32, cfg.vocab, seed=1)
    res = train_backprop(loss_fn, params, sample_fn, 200, eta=0.5,
                         chunk=100, log=None)
    assert res.history[-1][1]["cost"] < res.history[0][1]["cost"]


def test_mgd_vs_backprop_direction_agreement():
    """On the same batch, the expected MGD update direction must positively
    correlate with the true gradient (sanity of the whole stack)."""
    from repro.core.forward_grad import true_gradient
    from repro.core import build_mgd_step, mgd_init
    from repro.core.utils import tree_dot

    cfg = get_smoke_config("mistral-nemo-12b")
    params = model_init(cfg, jax.random.PRNGKey(0))
    loss_fn = lambda p, b: model_loss(p, cfg, b)    # noqa: E731
    batch = lm_sampler(4, 16, cfg.vocab, seed=2)(0)
    mgd_cfg = MGDConfig(dtheta=1e-3, eta=0.0, tau_theta=10**9,
                        mode="central", probes=16)
    state = mgd_init(params, mgd_cfg)
    step = jax.jit(build_mgd_step(loss_fn, mgd_cfg))
    _, state, _ = step(params, state, batch)
    g_true = true_gradient(loss_fn, params, batch)
    cos = float(tree_dot(state.g, g_true))
    assert cos > 0, cos
