"""Perturbation family invariants (paper §2.1, §3.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import perturbations as pert


@pytest.mark.parametrize("ptype", pert.PERTURBATION_TYPES)
def test_zero_mean(ptype):
    """Time-average of the ± perturbation families ≈ 0.  Sequential
    (one-at-a-time +Δθ, the FD setting) is NOT mean-zero by construction —
    its mean is Δθ/P; the paper handles it via the C₀ baseline
    subtraction, so we assert that exact offset instead."""
    n_params, n_steps = 8, 512
    dummy = {"w": jax.ShapeDtypeStruct((n_params,), jnp.float32)}
    seq = jnp.stack([
        pert.generate(dummy, ptype=ptype, step=t, seed=3, dtheta=1.0)["w"]
        for t in range(n_steps)])
    mean = jnp.mean(seq, axis=0)
    if ptype == "sequential":
        np.testing.assert_allclose(np.asarray(mean), 1.0 / n_params,
                                   atol=1e-6)
    else:
        tol = 0.01 if ptype == "walsh" else 0.15
        assert float(jnp.max(jnp.abs(mean))) < tol


@pytest.mark.parametrize("ptype,tol_off", [
    ("walsh", 1e-6),          # deterministically orthogonal
    ("sequential", 1e-6),     # trivially orthogonal (disjoint support)
    ("rademacher", 0.2),      # statistically orthogonal, O(1/sqrt(T))
    ("sinusoidal", 0.2),      # orthogonal as T → ∞
])
def test_pairwise_orthogonality(ptype, tol_off):
    """Gram matrix of perturbation sequences ≈ diagonal (paper Eq. 2)."""
    n_params, n_steps = 8, 1024
    gram = np.asarray(pert.orthogonality_check(
        ptype, n_params, n_steps, dtheta=1.0))
    off = gram - np.diag(np.diag(gram))
    diag = np.diag(gram)
    assert np.max(np.abs(off)) < tol_off, gram.round(3)
    # diagonal power: Δθ² (±codes), Δθ²/2 (sin), Δθ²/P (sequential)
    if ptype in ("walsh", "rademacher"):
        np.testing.assert_allclose(diag, 1.0, atol=1e-5)
    elif ptype == "sinusoidal":
        np.testing.assert_allclose(diag, 0.5, atol=0.2)


def test_determinism_across_calls():
    dummy = {"a": jax.ShapeDtypeStruct((16,), jnp.float32),
             "b": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    p1 = pert.generate(dummy, ptype="rademacher", step=7, seed=5, dtheta=0.1)
    p2 = pert.generate(dummy, ptype="rademacher", step=7, seed=5, dtheta=0.1)
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))


def test_tau_p_holds_perturbation():
    """Perturbation pattern advances only every τ_p steps (paper Table 1)."""
    dummy = {"w": jax.ShapeDtypeStruct((32,), jnp.float32)}
    p0 = pert.generate(dummy, ptype="rademacher", step=6, seed=0,
                       dtheta=1.0, tau_p=3)
    p1 = pert.generate(dummy, ptype="rademacher", step=7, seed=0,
                       dtheta=1.0, tau_p=3)
    p2 = pert.generate(dummy, ptype="rademacher", step=9, seed=0,
                       dtheta=1.0, tau_p=3)
    np.testing.assert_array_equal(np.asarray(p0["w"]), np.asarray(p1["w"]))
    assert np.any(np.asarray(p0["w"]) != np.asarray(p2["w"]))


def test_distinct_leaves_distinct_signs():
    dummy = {"a": jax.ShapeDtypeStruct((64,), jnp.float32),
             "b": jax.ShapeDtypeStruct((64,), jnp.float32)}
    p = pert.generate(dummy, ptype="rademacher", step=0, seed=0, dtheta=1.0)
    assert np.any(np.asarray(p["a"]) != np.asarray(p["b"]))


def test_signs_match_generate():
    """generate_signs_only · Δθ == generate (the replay-mode invariant)."""
    dummy = {"w": jax.ShapeDtypeStruct((100,), jnp.float32)}
    full = pert.generate(dummy, ptype="rademacher", step=3, seed=9,
                         dtheta=0.25)
    signs = pert.generate_signs_only(dummy, step=3, seed=9)
    np.testing.assert_allclose(np.asarray(full["w"]),
                               0.25 * np.asarray(signs["w"]), rtol=1e-6)
