"""Chip farm + ExternalPlant read-path contracts.

Load-bearing:
* ``ExternalPlant.read_cost`` forwards the optimizer's (step, tag)
  counters to devices that accept them (the +/− probe reads of a
  central pair are distinguishable; restarts replay deterministically);
  plain 2-method devices keep working.
* Devices with a differential probe line (``measure_pair``) pay ONE
  persistent base-θ write per central pair instead of two full
  perturbed-tree writes.
* ``repro.driver("probe_parallel_external", cfg, plant=ChipFarm(...))``
  trains through k external chips, is bit-deterministic across runs
  (pod_seed-keyed probes + counter-keyed device noise), reduces the
  C̃-estimator variance with k, and checkpoints/resumes through
  ``train_mgd`` onto the uninterrupted trajectory.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import DriverConfig, driver, replace_step
from repro.core import MGDConfig, build_mgd_step, mgd_init
from repro.data import tasks
from repro.hardware import (ChipFarm, ExternalPlant, SimulatedAnalogChip,
                            simulated_chip_farm)
from repro.models.simple import mlp_init
from repro.training.train_loop import train_mgd

X, Y = tasks.xor_dataset()
BATCH = {"x": X, "y": Y}


def _params(seed=0, sizes=(2, 2, 1)):
    return mlp_init(jax.random.PRNGKey(seed), sizes)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# Instrumented fake devices
# ---------------------------------------------------------------------------


class RecordingDevice:
    """Counter-capable 2-method device: records every (step, tag) its
    readout sees and counts persistent writes.  Cost is a deterministic
    function of the stored parameters so the driver math runs."""

    def __init__(self):
        self.writes = 0
        self.calls = []          # (step, tag) per measure_cost
        self._params = None

    def set_params(self, params):
        self.writes += 1
        self._params = jax.tree_util.tree_map(
            lambda w: np.asarray(w, np.float32), params)

    def _cost(self, params):
        return float(sum(np.sum(leaf * leaf) for leaf in
                         jax.tree_util.tree_leaves(params)))

    def measure_cost(self, batch, *, step=None, tag=None):
        self.calls.append((step, tag))
        return self._cost(self._params)


class PairDevice(RecordingDevice):
    """RecordingDevice + differential probe line."""

    def __init__(self):
        super().__init__()
        self.pair_calls = []     # (step, tag) per measure_pair

    def measure_pair(self, theta, batch, *, step=None, tag=None):
        self.pair_calls.append((step, tag))
        plus = jax.tree_util.tree_map(
            lambda w, t: w + np.asarray(t, np.float32), self._params, theta)
        minus = jax.tree_util.tree_map(
            lambda w, t: w - np.asarray(t, np.float32), self._params, theta)
        return self._cost(plus), self._cost(minus)


class LegacyDevice:
    """The historical 1-arg instrument surface — must keep working."""

    def __init__(self):
        self.writes = 0
        self._params = None

    def set_params(self, params):
        self.writes += 1
        self._params = jax.tree_util.tree_map(
            lambda w: np.asarray(w, np.float32), params)

    def measure_cost(self, batch):
        return float(sum(np.sum(np.abs(leaf)) for leaf in
                         jax.tree_util.tree_leaves(self._params)))


def _central_cfg(**kw):
    return MGDConfig(dtheta=1e-2, eta=0.1, mode="central", seed=0, **kw)


# ---------------------------------------------------------------------------
# ExternalPlant read-path bugfixes
# ---------------------------------------------------------------------------


def test_read_cost_forwards_step_and_tag():
    device = RecordingDevice()
    plant = ExternalPlant(device)
    c = plant.read_cost(_params(), BATCH, step=jnp.int32(7), tag=5)
    assert np.isfinite(float(c))
    assert device.calls == [(7, 5)]


def test_pair_reads_get_distinct_tags_and_step():
    """Default (no measure_pair) central pair: the two reads arrive with
    consecutive tags and the true optimizer step — a counter-keyed
    device can tell the +θ̃ read from the −θ̃ read."""
    device = RecordingDevice()
    plant = ExternalPlant(device)
    step = jax.jit(build_mgd_step(None, _central_cfg(), plant=plant))
    p, s = _params(), mgd_init(_params(), _central_cfg())
    for _ in range(3):
        p, s, _ = step(p, s, BATCH)
        jax.block_until_ready(p)
    assert device.calls == [(0, 0), (0, 1), (1, 0), (1, 1), (2, 0), (2, 1)]
    # two perturbed probe writes + one update write per step
    assert device.writes == 3 * 3


def test_pair_capable_device_single_write_per_pair():
    """measure_pair drops the probe writes per central pair from 2 full
    perturbed trees to 1 base-θ write (plus the unchanged update
    write): 3 writes/step → 2 writes/step."""
    device = PairDevice()
    plant = ExternalPlant(device)
    step = jax.jit(build_mgd_step(None, _central_cfg(), plant=plant))
    p, s = _params(), mgd_init(_params(), _central_cfg())
    n = 4
    for _ in range(n):
        p, s, _ = step(p, s, BATCH)
        jax.block_until_ready(p)
    assert device.writes == 2 * n
    assert device.pair_calls == [(t, 0) for t in range(n)]
    assert device.calls == []          # never fell back to single reads


def test_legacy_two_arg_device_still_works():
    device = LegacyDevice()
    plant = ExternalPlant(device)
    step = jax.jit(build_mgd_step(None, _central_cfg(), plant=plant))
    p, s = _params(), mgd_init(_params(), _central_cfg())
    p, s, m = step(p, s, BATCH)
    assert np.isfinite(float(m["cost"]))
    assert device.writes == 3


def test_sim_chip_readout_noise_counter_keyed():
    """Same (step, tag) → the same readout draw (replay-deterministic);
    different tag or step → a different draw; no counters → live RNG."""
    chip = SimulatedAnalogChip((2, 2, 1), seed=3, sigma_a=0.0,
                               sigma_theta=0.0, sigma_c=1.0)
    chip.set_params(_params())
    a = chip.measure_cost(BATCH, step=5, tag=0)
    b = chip.measure_cost(BATCH, step=5, tag=0)
    assert a == b
    assert chip.measure_cost(BATCH, step=5, tag=1) != a
    assert chip.measure_cost(BATCH, step=6, tag=0) != a
    assert chip.measure_cost(BATCH) != chip.measure_cost(BATCH)


def test_sim_chip_measure_pair_rides_probe_line():
    """measure_pair perturbs transiently: no extra persistent write, and
    the ± halves bracket the unperturbed readout."""
    chip = SimulatedAnalogChip((2, 2, 1), seed=0, sigma_a=0.0,
                               sigma_theta=0.0, sigma_c=0.0)
    p = _params()
    chip.set_params(p)
    writes = chip.writes
    theta = jax.tree_util.tree_map(lambda x: 0.01 * np.ones_like(x), p)
    c_plus, c_minus = chip.measure_pair(theta, BATCH, step=0, tag=0)
    assert chip.writes == writes          # no persistent write happened
    assert c_plus != c_minus
    assert np.isfinite([c_plus, c_minus]).all()


# ---------------------------------------------------------------------------
# ChipFarm + the probe_parallel_external driver
# ---------------------------------------------------------------------------


def test_farm_driver_trains_and_counts_writes():
    farm = simulated_chip_farm(4, (2, 2, 1), base_seed=0, sigma_a=0.1,
                               sigma_theta=0.005, sigma_c=1e-4)
    cfg = DriverConfig(dtheta=2e-2, eta=0.5, mode="central", seed=0)
    mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
    p, s = _params(), mgd.init(_params())
    costs = []
    n = 60
    for _ in range(n):
        p, s, m = mgd.step(p, s, BATCH)
        costs.append(float(m["cost"]))
    assert np.isfinite(costs).all()
    assert int(s.step) == n
    # per step: 1 pair write + 1 update write, on each of the 4 chips
    assert farm.total_writes == 2 * n * 4
    assert np.mean(costs[-10:]) < np.mean(costs[:10])


def test_farm_trajectories_bit_identical_across_runs():
    """pod_seed-keyed probes + counter-keyed readout noise: two fresh,
    identically-seeded farm runs walk the same f32 trajectory bit for
    bit — the thread-pool schedule cannot perturb it."""
    def run():
        farm = simulated_chip_farm(3, (2, 2, 1), base_seed=5, sigma_a=0.1,
                                   sigma_theta=0.01, sigma_c=1e-3)
        cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=2)
        mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
        p, s = _params(1), mgd.init(_params(1))
        cts = []
        for _ in range(10):
            p, s, m = mgd.step(p, s, BATCH)
            cts.append(np.asarray(m["c_tilde"]))
        return p, np.array(cts)

    p_a, ct_a = run()
    p_b, ct_b = run()
    np.testing.assert_array_equal(ct_a, ct_b)
    _assert_trees_equal(p_a, p_b)


def test_farm_variance_decreases_with_k():
    """The averaged error signal is k independent probe estimates: its
    variance at frozen params drops ≈1/k (k=4 ≤ 0.55× the k=1 var)."""
    p = _params(3)
    cfg = DriverConfig(dtheta=1e-2, eta=1.0, mode="central", seed=0)

    def ghat_var(k, rounds=48):
        # matched chips (no defects/write noise): the averaged estimator
        # is k iid probe estimates, so the 1/k law is clean
        farm = simulated_chip_farm(k, (2, 2, 1), base_seed=0, sigma_a=0.0,
                                   sigma_theta=0.0, sigma_c=1e-3)
        mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
        s0 = mgd.init(p)
        w0 = np.asarray(jax.tree_util.tree_leaves(p)[1])[0, 0]
        samples = []
        for t in range(rounds):
            p1, _, _ = mgd.step(p, replace_step(s0, t), BATCH)
            samples.append(np.asarray(
                jax.tree_util.tree_leaves(p1)[1])[0, 0] - w0)
        return float(np.var(samples))

    v1, v4 = ghat_var(1), ghat_var(4)
    assert v4 < 0.55 * v1, (v1, v4)


def test_train_mgd_farm_checkpoint_resume(tmp_path):
    """Resume == uninterrupted through the per-step external runner: the
    farm state (ProbeParallelState counter) checkpoints generically and
    counter-keyed chip noise replays (σ_θ = 0 chips: the only live-RNG
    stream is silent)."""
    def farm():
        return simulated_chip_farm(2, (2, 2, 1), base_seed=1, sigma_a=0.1,
                                   sigma_theta=0.0, sigma_c=1e-3)

    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=4)
    p0 = _params(2)
    sample_fn = lambda i: BATCH                       # noqa: E731

    cont = train_mgd(None, p0, cfg, sample_fn, 16,
                     algorithm="probe_parallel_external", plant=farm(),
                     chunk=4, log=None)
    assert int(cont.state.step) == 16

    train_mgd(None, p0, cfg, sample_fn, 8,
              algorithm="probe_parallel_external", plant=farm(),
              chunk=4, log=None, checkpoint_dir=str(tmp_path),
              checkpoint_every=8)
    res = train_mgd(None, p0, cfg, sample_fn, 16,
                    algorithm="probe_parallel_external", plant=farm(),
                    chunk=4, log=None, checkpoint_dir=str(tmp_path))
    assert res.steps_done == 16
    _assert_trees_equal(cont.params, res.params)
    _assert_trees_equal(cont.state, res.state)


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------


def test_farm_has_no_single_chip_read():
    farm = simulated_chip_farm(2, (2, 2, 1))
    with pytest.raises(NotImplementedError, match="probe_parallel_external"):
        farm.read_cost(_params(), BATCH, step=0)


def test_farm_rejects_empty_and_bad_devices():
    with pytest.raises(ValueError, match="at least one"):
        ChipFarm([])
    with pytest.raises(TypeError, match="set_params"):
        ChipFarm([object()])
    with pytest.raises(ValueError, match="at least one chip"):
        simulated_chip_farm(0)


@pytest.mark.parametrize("build,match", [
    (lambda farm: driver("probe_parallel_external",
                         DriverConfig(mode="central")),
     "ChipFarm"),
    (lambda farm: driver("probe_parallel_external",
                         DriverConfig(mode="central"), plant=farm,
                         mesh="mesh"),
     "host-side"),
    (lambda farm: driver("probe_parallel_external",
                         DriverConfig(mode="central"), lambda p, b: 0.0,
                         plant=farm),
     "cost oracle"),
    (lambda farm: driver("probe_parallel_external", DriverConfig(),
                         plant=farm),
     "central"),
    (lambda farm: driver("probe_parallel_external",
                         DriverConfig(mode="central", probes=4), plant=farm),
     "farm size"),
    (lambda farm: driver("probe_parallel_external",
                         DriverConfig(mode="central", tau_theta=4),
                         plant=farm),
     "tau_theta=1"),
    (lambda farm: driver("probe_parallel_external",
                         DriverConfig(mode="central"), plant=farm,
                         probe_fn=lambda *a: None),
     "fused"),
])
def test_farm_driver_validation(build, match):
    farm = simulated_chip_farm(2, (2, 2, 1))
    with pytest.raises((ValueError, TypeError), match=match):
        build(farm)
