"""MGD optimizer semantics: the paper's algorithm equivalences (Fig. 2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MGDConfig, build_mgd_step, mgd_init
from repro.core.forward_grad import (forward_gradient, gradient_angle,
                                     true_gradient)
from repro.core.utils import tree_size

TARGET = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([[0.5, -0.5]])}
P0 = {"w": jnp.zeros(3), "b": jnp.zeros((1, 2))}


def quad_loss(p, batch):
    return sum(jnp.sum((p[k] - TARGET[k]) ** 2) for k in p)


def run(cfg, params, steps, batch=None):
    state = mgd_init(params, cfg)
    step = jax.jit(build_mgd_step(quad_loss, cfg))
    for _ in range(steps):
        params, state, metrics = step(params, state, batch)
    return params, state, metrics


def test_finite_difference_equivalence():
    """Sequential perturbations + τ_θ = P ≡ forward finite difference
    (paper §2.2, Fig. 2a): after P steps G equals the FD gradient."""
    n = tree_size(P0)
    cfg = MGDConfig(ptype="sequential", dtheta=1e-3, eta=0.0,
                    tau_theta=10**9)
    _, state, _ = run(cfg, P0, n)
    g_true = true_gradient(quad_loss, P0, None)
    ang = gradient_angle(state.g, g_true)
    assert float(ang) < 2e-3   # FD bias only
    np.testing.assert_allclose(np.asarray(state.g["w"]),
                               np.asarray(g_true["w"]), rtol=2e-2)


def test_coordinate_descent_converges():
    """Sequential + τ_θ = τ_p = coordinate descent (Fig. 2b)."""
    cfg = MGDConfig(ptype="sequential", dtheta=1e-3, eta=0.3, tau_theta=1)
    params, _, _ = run(cfg, P0, 400)
    assert float(quad_loss(params, None)) < 1e-3


def test_spsa_converges():
    """Rademacher + τ_θ = τ_p = SPSA (Fig. 2c)."""
    cfg = MGDConfig(ptype="rademacher", dtheta=1e-3, eta=0.05, tau_theta=1)
    params, _, _ = run(cfg, P0, 800)
    assert float(quad_loss(params, None)) < 1e-3


@pytest.mark.parametrize("mode", ["forward", "central"])
def test_replay_equals_accumulator(mode):
    """Scalar-replay (O(1) memory) must reproduce the G-buffer trajectory."""
    cfg_g = MGDConfig(dtheta=1e-3, eta=0.02, tau_theta=4, mode=mode)
    cfg_r = dataclasses.replace(cfg_g, replay=True)
    p_g, _, _ = run(cfg_g, P0, 200)
    p_r, _, _ = run(cfg_r, P0, 200)
    for k in p_g:
        np.testing.assert_allclose(np.asarray(p_g[k]), np.asarray(p_r[k]),
                                   atol=5e-5)


def test_central_difference_lower_bias():
    """Central probes have O(Δθ²) bias vs O(Δθ) forward — at large Δθ the
    central G must align better with the true gradient."""
    g_true = true_gradient(quad_loss, P0, None)
    angles = {}
    for mode in ["forward", "central"]:
        cfg = MGDConfig(dtheta=0.5, eta=0.0, tau_theta=10**9, mode=mode)
        _, state, _ = run(cfg, P0, 400)
        angles[mode] = float(gradient_angle(state.g, g_true))
    assert angles["central"] < angles["forward"]


def test_probe_averaging_reduces_variance():
    g_true = true_gradient(quad_loss, P0, None)
    angles = {}
    for k in [1, 8]:
        cfg = MGDConfig(dtheta=1e-3, eta=0.0, tau_theta=10**9, probes=k)
        _, state, _ = run(cfg, P0, 40)
        angles[k] = float(gradient_angle(state.g, g_true))
    assert angles[8] < angles[1]


def test_gradient_angle_convergence():
    """Paper Fig. 5: G → true gradient as integration time grows."""
    g_true = true_gradient(quad_loss, P0, None)
    cfg = MGDConfig(dtheta=1e-4, eta=0.0, tau_theta=10**9)
    state = mgd_init(P0, cfg)
    step = jax.jit(build_mgd_step(quad_loss, cfg))
    p = P0
    angles = []
    for t in range(2000):
        p, state, _ = step(p, state, None)
        if t in (2, 49, 1999):
            angles.append(float(gradient_angle(state.g, g_true)))
    # short integration is clearly worse; converged angle is small.  The
    # curve saturates near its Δθ-bias floor, so only assert the large-
    # scale monotonicity the paper's Fig. 5 shows.
    assert angles[0] > angles[2]
    assert angles[2] < 0.15


def test_forward_gradient_oracle_is_dtheta_limit():
    """jvp forward gradient == MGD single central probe as Δθ → 0."""
    fg = forward_gradient(quad_loss, P0, None, step=5, seed=0)
    cfg = MGDConfig(dtheta=1e-5, eta=0.0, tau_theta=10**9, mode="central")
    state = mgd_init(P0, cfg)
    state = state._replace(step=jnp.asarray(5, jnp.int32))
    step = jax.jit(build_mgd_step(quad_loss, cfg))
    _, state, _ = step(P0, state, None)
    for k in fg:
        np.testing.assert_allclose(np.asarray(state.g[k]),
                                   np.asarray(fg[k]), rtol=1e-2, atol=1e-3)


def test_temporal_batching_equals_spatial():
    """Paper Fig. 3: integrating G over τ_θ/τ_x sample changes ≡ summing
    per-sample gradients (exact in FD mode on a linear-regression loss)."""
    xs = jnp.array([[1.0, 0.0], [0.0, 1.0], [1.0, 1.0], [2.0, -1.0]])
    ys = jnp.array([2.0, -1.0, 1.0, 5.0])

    def loss(p, batch):
        x, y = batch
        return jnp.sum((x @ p["w"] - y) ** 2)

    params = {"w": jnp.zeros(2)}
    n = 2
    # batch-of-4 gradient via backprop
    g_batch = true_gradient(
        loss, params, (xs, ys))
    # MGD: τ_x = P (FD per sample), τ_θ = 4·P → integrates all 4 samples
    cfg = MGDConfig(ptype="sequential", dtheta=1e-4, eta=0.0,
                    tau_theta=10**9)
    state = mgd_init(params, cfg)
    step = jax.jit(build_mgd_step(loss, cfg))
    p = params
    for i in range(4 * n):
        batch = (xs[i // n][None], ys[i // n][None])
        p, state, _ = step(p, state, batch)
    np.testing.assert_allclose(np.asarray(state.g["w"]),
                               np.asarray(g_batch["w"]), rtol=1e-2)


def test_momentum_accelerates_quadratic():
    """Heavy-ball ≈ 1/(1−β)× effective rate on a quadratic: at a small
    base η, momentum 0.9 must be well ahead at a fixed step budget."""
    cfg0 = MGDConfig(dtheta=1e-3, eta=0.002, tau_theta=1)
    cfg1 = MGDConfig(dtheta=1e-3, eta=0.002, tau_theta=1, momentum=0.9)
    p0, _, _ = run(cfg0, P0, 400)
    p1, _, _ = run(cfg1, P0, 400)
    assert float(quad_loss(p1, None)) < float(quad_loss(p0, None))


def test_update_only_every_tau_theta():
    cfg = MGDConfig(dtheta=1e-3, eta=0.1, tau_theta=5)
    state = mgd_init(P0, cfg)
    step = jax.jit(build_mgd_step(quad_loss, cfg))
    p = P0
    for i in range(5):
        p_prev = p
        p, state, m = step(p, state, None)
        changed = any(np.any(np.asarray(p[k]) != np.asarray(p_prev[k]))
                      for k in p)
        assert changed == (i == 4), f"step {i}: changed={changed}"


def test_replay_tau1_keeps_replay_branch_and_state_structure():
    """replay=True composes with tau_theta=1 (the bounded-staleness
    configuration staleness>0 requires replay): the step must take the
    replay branch, not the τ_θ=1 fast path — the fast path would drop
    replay_c from the returned state pytree (breaking the lax.scan
    carry) and consume the staleness-delayed C̃ at the wrong step."""
    cfg = MGDConfig(dtheta=1e-2, eta=0.5, mode="central", replay=True,
                    staleness=1, seed=0)
    state = mgd_init(P0, cfg)
    assert state.replay_c is not None
    step = jax.jit(build_mgd_step(quad_loss, cfg))
    params, new_state, _ = step(P0, state, None)
    # same pytree structure in and out — scan-compatible
    assert jax.tree_util.tree_structure((P0, state)) == \
        jax.tree_util.tree_structure((params, new_state))
    assert new_state.replay_c.shape == (cfg.tau_theta + cfg.staleness,)

    def body(carry, _):
        p, s = carry
        p, s, m = step(p, s, None)
        return (p, s), m

    (params, _), _ = jax.lax.scan(body, (P0, state), None, length=4)
    assert all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(params))
