"""Pallas kernel correctness: interpret-mode vs pure-jnp oracle, swept over
shapes and dtypes, plus bit-exactness against the host perturbation
generator (the contract that lets the kernel regenerate θ̃ in VMEM)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import perturbations as pert
from repro.kernels import ops, ref

SHAPES_MM = [
    (64, 128, 256), (16, 48, 80), (1, 256, 256), (130, 384, 96),
    (8, 8, 8), (256, 512, 128),
]


@pytest.mark.parametrize("m,k,n", SHAPES_MM)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_perturbed_matmul_matches_ref(m, k, n, dtype):
    kx = jax.random.PRNGKey(0)
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype) * 0.1
    lseed = pert.leaf_seed(7, 3, 2)
    y_ref = ref.perturbed_matmul_ref(x, w, lseed, dtheta=0.01)
    y_pal = ops.perturbed_matmul(x, w, lseed, dtheta=0.01, impl="interpret")
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    err = float(jnp.max(jnp.abs(
        y_ref.astype(jnp.float32) - y_pal.astype(jnp.float32))))
    assert err < tol, (m, k, n, dtype, err)


@pytest.mark.parametrize("sign", [1.0, -1.0])
def test_antithetic_probe_sign(sign):
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    ls = pert.leaf_seed(1, 5, 0)
    a = ref.perturbed_matmul_ref(x, w, ls, dtheta=0.05, sign=sign)
    b = ops.perturbed_matmul(x, w, ls, dtheta=0.05, sign=sign,
                             impl="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_kernel_signs_match_host_generator():
    """The in-kernel hash must reproduce perturbations.generate exactly —
    this is what makes regeneration (not storage) of θ̃ sound."""
    x = jnp.eye(96, dtype=jnp.float32)          # identity: y = W + Δθ·signs
    w = jnp.zeros((96, 128), jnp.float32)
    step, seed = 11, 42
    th = pert.generate({"w": w}, ptype="rademacher", step=step, seed=seed,
                       dtheta=1.0)["w"]
    lseed = pert.leaf_seed(seed, step, 0)
    y = ops.perturbed_matmul(x, w, lseed, dtheta=1.0, impl="interpret")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(th))


@pytest.mark.parametrize("k,n,j", [(128, 256, 4), (96, 80, 7), (256, 512, 1),
                                   (8, 8, 3)])
def test_mgd_update_matches_ref(k, n, j):
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n), jnp.float32)
    lseeds = jnp.array([pert.leaf_seed(7, t, 0) for t in range(j)],
                       jnp.uint32)
    coefs = jax.random.normal(jax.random.PRNGKey(2), (j,), jnp.float32)
    u_ref = ref.mgd_update_ref(w, lseeds, coefs, eta=0.1, dtheta=0.01)
    u_pal = ops.mgd_update(w, lseeds, coefs, eta=0.1, dtheta=0.01,
                           impl="interpret")
    np.testing.assert_allclose(np.asarray(u_ref), np.asarray(u_pal),
                               rtol=1e-4, atol=1e-3)


def test_mgd_update_equals_sequential_sgd_steps():
    """One fused window update == applying each scalar step separately."""
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 64), jnp.float32)
    steps = [5, 6, 7]
    lseeds = jnp.array([pert.leaf_seed(0, t, 0) for t in steps], jnp.uint32)
    coefs = jnp.array([0.3, -0.2, 0.05], jnp.float32)
    fused = ops.mgd_update(w, lseeds, coefs, eta=0.01, dtheta=0.1,
                           impl="interpret")
    w_seq = w
    for t, c in zip(steps, coefs):
        th = pert.generate({"w": w}, ptype="rademacher", step=t, seed=0,
                           dtheta=0.1)["w"]
        w_seq = w_seq - 0.01 * float(c) * th / (0.1 * 0.1)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(w_seq),
                               rtol=1e-4, atol=1e-4)


def test_batched_leading_dims():
    """ops wrapper flattens leading batch dims."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    ls = pert.leaf_seed(0, 0, 0)
    y = ops.perturbed_matmul(x, w, ls, dtheta=0.01, impl="interpret")
    assert y.shape == (2, 5, 32)
    y_ref = ref.perturbed_matmul_ref(x.reshape(10, 64), w, ls, dtheta=0.01)
    np.testing.assert_allclose(np.asarray(y.reshape(10, 32)),
                               np.asarray(y_ref), rtol=1e-4, atol=1e-4)
