"""Probe-parallel mesh driver on REAL multi-device topology.

The default tier-1 run sees one CPU device and skips these (the
single-device mesh path is covered by test_driver_api); CI runs this
file in a dedicated step with

    XLA_FLAGS=--xla_force_host_platform_device_count=8

so shard_map's manual "pod" axis, the k-scalar all-gather, and the
replicated parameter update are exercised on an actual 4-wide mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

import repro
from repro.core import mse
from repro.core import perturbations as pert
from repro.core.utils import tree_add, tree_axpy
from repro.data import tasks
from repro.models.simple import mlp_apply, mlp_init

needs_pods = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices — run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

X, Y = tasks.xor_dataset()


def _loss(p, b):
    return mse(mlp_apply(p, b["x"]), b["y"])


def _mesh4():
    return Mesh(np.array(jax.devices()[:4]).reshape(4), ("pod",))


def _sharded_batch():
    # 4 pods, each with its own single-example shard of the xor table
    return {"x": X.reshape(4, 1, 2), "y": Y.reshape(4, 1, 1)}


def _pod_seed(cfg, k):
    return (jnp.uint32(cfg.seed)
            + jnp.asarray(k, jnp.uint32) * jnp.uint32(0x9E3779B9))


@needs_pods
def test_k4_matches_manual_probe_average():
    """One mesh step == the hand-computed k-probe averaged update:
    per-pod central difference on the pod's shard, then the sequential
    −η/(kΔθ²)·C̃_k·θ̃_k axpy chain, k = 0..3 in order."""
    cfg = repro.DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=3)
    drv = repro.driver("probe_parallel", cfg, _loss, mesh=_mesh4())
    p0 = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
    batch = _sharded_batch()
    p1, _, aux = drv.step(p0, drv.init(p0), batch)

    mcfg = drv.config
    inv_d2 = 1.0 / (mcfg.dtheta * mcfg.dtheta)
    all_c, p_ref = [], p0
    for k in range(4):
        theta = pert.generate(p0, ptype=mcfg.ptype, step=jnp.int32(0),
                              seed=_pod_seed(mcfg, k), dtheta=mcfg.dtheta)
        shard = {"x": batch["x"][k], "y": batch["y"][k]}
        c_plus = _loss(tree_add(p0, theta), shard)
        c_minus = _loss(tree_axpy(-1.0, theta, p0), shard)
        all_c.append(jnp.float32(0.5 * (c_plus - c_minus)))
    for k in range(4):
        theta = pert.generate(p_ref, ptype=mcfg.ptype, step=jnp.int32(0),
                              seed=_pod_seed(mcfg, k), dtheta=mcfg.dtheta)
        coef = -mcfg.eta * inv_d2 * all_c[k] / 4
        p_ref = tree_axpy(coef, theta, p_ref)

    np.testing.assert_allclose(
        float(aux["c_tilde"]),
        float(np.mean(np.abs(np.asarray(all_c)))), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


@needs_pods
def test_k4_deterministic_across_runs():
    """pod_seed-keyed probe streams: two fresh 4-pod drivers walk a bit
    identical trajectory."""
    def run():
        cfg = repro.DriverConfig(dtheta=1e-2, eta=1.0, mode="central",
                                 seed=7)
        drv = repro.driver("probe_parallel", cfg, _loss, mesh=_mesh4())
        p = mlp_init(jax.random.PRNGKey(1), (2, 2, 1))
        s = drv.init(p)
        cts = []
        for _ in range(5):
            p, s, aux = drv.step(p, s, _sharded_batch())
            cts.append(np.asarray(aux["c_tilde"]))
        return p, np.array(cts)

    p_a, ct_a = run()
    p_b, ct_b = run()
    np.testing.assert_array_equal(ct_a, ct_b)
    for a, b in zip(jax.tree_util.tree_leaves(p_a),
                    jax.tree_util.tree_leaves(p_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_pods
def test_k4_cost_drops_on_xor():
    """The 4-pod probe average actually trains on a real mesh."""
    cfg = repro.DriverConfig(dtheta=1e-2, eta=2.0, mode="central", seed=0)
    drv = repro.driver("probe_parallel", cfg, _loss, mesh=_mesh4())
    p = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
    s = drv.init(p)
    costs = []
    for _ in range(300):
        p, s, aux = drv.step(p, s, _sharded_batch())
        costs.append(float(aux["cost"]))
    assert np.mean(costs[-30:]) < np.mean(costs[:30])
