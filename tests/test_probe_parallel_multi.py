"""Probe-parallel mesh driver on REAL multi-device topology.

The default tier-1 run sees one CPU device and skips these (the
single-device mesh path is covered by test_driver_api); CI runs this
file in a dedicated step with

    XLA_FLAGS=--xla_force_host_platform_device_count=8

so shard_map's manual "pod" axis, the k-scalar all-gather, and the
replicated parameter update are exercised on an actual 4-wide mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

import repro
from repro.core import mse
from repro.core import perturbations as pert
from repro.core.utils import tree_add, tree_axpy
from repro.data import tasks
from repro.models.simple import mlp_apply, mlp_init

needs_pods = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices — run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

X, Y = tasks.xor_dataset()


def _loss(p, b):
    return mse(mlp_apply(p, b["x"]), b["y"])


def _mesh4():
    return Mesh(np.array(jax.devices()[:4]).reshape(4), ("pod",))


def _sharded_batch():
    # 4 pods, each with its own single-example shard of the xor table
    return {"x": X.reshape(4, 1, 2), "y": Y.reshape(4, 1, 1)}


def _pod_seed(cfg, k):
    return (jnp.uint32(cfg.seed)
            + jnp.asarray(k, jnp.uint32) * jnp.uint32(0x9E3779B9))


@needs_pods
def test_k4_matches_manual_probe_average():
    """One mesh step == the hand-computed k-probe averaged update:
    per-pod central difference on the pod's shard, then the sequential
    −η/(kΔθ²)·C̃_k·θ̃_k axpy chain, k = 0..3 in order."""
    cfg = repro.DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=3)
    drv = repro.driver("probe_parallel", cfg, _loss, mesh=_mesh4())
    p0 = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
    batch = _sharded_batch()
    p1, _, aux = drv.step(p0, drv.init(p0), batch)

    mcfg = drv.config
    inv_d2 = 1.0 / (mcfg.dtheta * mcfg.dtheta)
    all_c, p_ref = [], p0
    for k in range(4):
        theta = pert.generate(p0, ptype=mcfg.ptype, step=jnp.int32(0),
                              seed=_pod_seed(mcfg, k), dtheta=mcfg.dtheta)
        shard = {"x": batch["x"][k], "y": batch["y"][k]}
        c_plus = _loss(tree_add(p0, theta), shard)
        c_minus = _loss(tree_axpy(-1.0, theta, p0), shard)
        all_c.append(jnp.float32(0.5 * (c_plus - c_minus)))
    for k in range(4):
        theta = pert.generate(p_ref, ptype=mcfg.ptype, step=jnp.int32(0),
                              seed=_pod_seed(mcfg, k), dtheta=mcfg.dtheta)
        coef = -mcfg.eta * inv_d2 * all_c[k] / 4
        p_ref = tree_axpy(coef, theta, p_ref)

    np.testing.assert_allclose(
        float(aux["c_tilde"]),
        float(np.mean(np.abs(np.asarray(all_c)))), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


@needs_pods
def test_k4_deterministic_across_runs():
    """pod_seed-keyed probe streams: two fresh 4-pod drivers walk a bit
    identical trajectory."""
    def run():
        cfg = repro.DriverConfig(dtheta=1e-2, eta=1.0, mode="central",
                                 seed=7)
        drv = repro.driver("probe_parallel", cfg, _loss, mesh=_mesh4())
        p = mlp_init(jax.random.PRNGKey(1), (2, 2, 1))
        s = drv.init(p)
        cts = []
        for _ in range(5):
            p, s, aux = drv.step(p, s, _sharded_batch())
            cts.append(np.asarray(aux["c_tilde"]))
        return p, np.array(cts)

    p_a, ct_a = run()
    p_b, ct_b = run()
    np.testing.assert_array_equal(ct_a, ct_b)
    for a, b in zip(jax.tree_util.tree_leaves(p_a),
                    jax.tree_util.tree_leaves(p_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_pods
def test_k4_cost_drops_on_xor():
    """The 4-pod probe average actually trains on a real mesh."""
    cfg = repro.DriverConfig(dtheta=1e-2, eta=2.0, mode="central", seed=0)
    drv = repro.driver("probe_parallel", cfg, _loss, mesh=_mesh4())
    p = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
    s = drv.init(p)
    costs = []
    for _ in range(300):
        p, s, aux = drv.step(p, s, _sharded_batch())
        costs.append(float(aux["cost"]))
    assert np.mean(costs[-30:]) < np.mean(costs[:30])


# ---------------------------------------------------------------------------
# Batch sharding: k-pod mesh ≡ k-chip farm, bit for bit
# ---------------------------------------------------------------------------

from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import mae  # noqa: E402
from repro.hardware import ChipFarm, LinearLaneChip  # noqa: E402
from repro.models.simple import linear_apply, make_mlp_probe_fn  # noqa: E402

needs_8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs >= 8 devices — run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _l1_loss(p, b):
    return mae(b["y"], linear_apply(p, b["x"]))


def _dyadic_params():
    # multiples of 1/4: with dtheta/eta = 1/2 and k = 4 every value the
    # trajectory produces stays exactly representable in f32 for the
    # horizon below (granularity shrinks ~4 bits/step from a 2^-2 start)
    return [{"w": jnp.array([[0.5], [-0.25]], jnp.float32),
             "b": jnp.array([0.25], jnp.float32)}]


def _dyadic_batch():
    # 8 rows = 4 contiguous 2-row shards; {0,1} inputs keep every product
    # exact.  Mesh P("pod") blocks ≡ farm shard_chip_batch slices.
    x = np.tile(np.array([[0, 0], [0, 1], [1, 0], [1, 1]], np.float32),
                (2, 1))
    y = np.tile(np.array([[0], [1], [1], [0]], np.float32), (2, 1))
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def _dyadic_cfg():
    return repro.DriverConfig(dtheta=0.5, eta=0.5, mode="central", seed=5)


@needs_pods
def test_sharded_mesh_bit_matches_sharded_farm():
    """THE bit-equality law under batch sharding: a 4-pod mesh whose pods
    see P("pod") batch blocks walks the identical f32 trajectory to a
    4-chip LinearLaneChip farm fed the same contiguous per-chip shards.
    Dyadic data/params make every intermediate exact, so numpy-chip vs
    XLA-mesh association differences cannot round."""
    batch = _dyadic_batch()

    drv = repro.driver("probe_parallel", _dyadic_cfg(), _l1_loss,
                       mesh=_mesh4())
    p_m = _dyadic_params()
    s_m = drv.init(p_m)

    farm = ChipFarm([LinearLaneChip() for _ in range(4)], shard_batch=True)
    ext = repro.driver("probe_parallel_external", _dyadic_cfg(), plant=farm)
    p_f = _dyadic_params()
    s_f = ext.init(p_f)

    for step in range(5):
        p_m, s_m, aux_m = drv.step(p_m, s_m, batch)
        p_f, s_f, aux_f = ext.step(p_f, s_f, batch)
        np.testing.assert_array_equal(
            np.asarray(aux_m["c_tilde"]), np.asarray(aux_f["c_tilde"]),
            err_msg=f"c_tilde diverged at step {step}")
        for a, b in zip(jax.tree_util.tree_leaves(p_m),
                        jax.tree_util.tree_leaves(p_f)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"params diverged at step {step}")


@needs_pods
def test_sharded_resume_bit_exact():
    """Stopping a batch-sharded run at step 3 and resuming through a
    FRESH driver + FRESH farm (chips re-written from the checkpointed
    params on the next probe) lands bit-identical to the straight run,
    on both sides of the law."""
    batch = _dyadic_batch()

    def mesh_run(n, carry=None):
        drv = repro.driver("probe_parallel", _dyadic_cfg(), _l1_loss,
                           mesh=_mesh4())
        p, s = carry if carry else (_dyadic_params(), None)
        s = drv.init(p) if s is None else s
        for _ in range(n):
            p, s, _ = drv.step(p, s, batch)
        return p, s

    def farm_run(n, carry=None):
        farm = ChipFarm([LinearLaneChip() for _ in range(4)],
                        shard_batch=True)
        ext = repro.driver("probe_parallel_external", _dyadic_cfg(),
                          plant=farm)
        p, s = carry if carry else (_dyadic_params(), None)
        s = ext.init(p) if s is None else s
        for _ in range(n):
            p, s, _ = ext.step(p, s, batch)
        return p, s

    p_straight, _ = mesh_run(5)
    p_resumed, _ = mesh_run(2, carry=mesh_run(3))
    for a, b in zip(jax.tree_util.tree_leaves(p_straight),
                    jax.tree_util.tree_leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    f_straight, _ = farm_run(5)
    f_resumed, _ = farm_run(2, carry=farm_run(3))
    for a, b in zip(jax.tree_util.tree_leaves(f_straight),
                    jax.tree_util.tree_leaves(f_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the two sides of the law still agree after resume
    for a, b in zip(jax.tree_util.tree_leaves(p_resumed),
                    jax.tree_util.tree_leaves(f_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Multi-axis meshes: model sharding + data sharding inside the pod step
# ---------------------------------------------------------------------------


@needs_8
def test_multi_axis_model_sharded_params():
    """(pod=4, model=2) mesh with w sharded over "model" via the logical
    rules: the loss is shard-aware (psum over "model"), the step runs,
    trains, and two fresh runs are bit-identical."""
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2),
                ("pod", "model"))
    xk = jax.random.PRNGKey(2)
    x = jax.random.bernoulli(xk, 0.5, (8, 4)).astype(jnp.float32)
    w_true = jnp.arange(16, dtype=jnp.float32).reshape(4, 4) / 8.0
    y = x @ w_true
    batch = {"x": x, "y": y}

    def sharded_loss(p, b):
        z = b["x"] @ p["w"]                       # local [B, 4/TP]
        m = jax.lax.axis_index("model")
        yloc = jax.lax.dynamic_slice_in_dim(
            b["y"], m * z.shape[1], z.shape[1], 1)
        err = (z - yloc) ** 2
        return jax.lax.psum(jnp.sum(err), "model") / jnp.float32(
            b["y"].shape[0] * b["y"].shape[1])

    def run():
        cfg = repro.DriverConfig(dtheta=1e-2, eta=0.3, mode="central",
                                 seed=11)
        drv = repro.driver("probe_parallel", cfg, sharded_loss, mesh=mesh,
                           param_specs=[("w", ["model"])])
        p = {"w": jnp.zeros((4, 4), jnp.float32)}
        s = drv.init(p)
        costs = []
        for _ in range(60):
            p, s, aux = drv.step(p, s, batch)
            costs.append(float(aux["cost"]))
        return p, costs

    p_a, costs_a = run()
    p_b, costs_b = run()
    assert np.isfinite(costs_a).all()
    assert np.mean(costs_a[-10:]) < np.mean(costs_a[:10])
    np.testing.assert_array_equal(np.asarray(costs_a), np.asarray(costs_b))
    np.testing.assert_array_equal(np.asarray(p_a["w"]), np.asarray(p_b["w"]))


@needs_8
def test_data_axis_pmean_agrees_with_pod_only():
    """(pod=4, data=2) with data_axis="data": each pod's cost pair is the
    pmean of its two data sub-shards.  Equal sub-shard sizes make that
    the same mean up to association, so the trajectory tracks the
    pod-only mesh run closely (not bitwise — a documented new mode)."""
    batch = {"x": jnp.tile(X, (2, 1)), "y": jnp.tile(Y, (2, 1))}

    def run(mesh, **kw):
        cfg = repro.DriverConfig(dtheta=1e-2, eta=0.5, mode="central",
                                 seed=4)
        drv = repro.driver("probe_parallel", cfg, _loss, mesh=mesh, **kw)
        p = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
        s = drv.init(p)
        for _ in range(20):
            p, s, aux = drv.step(p, s, batch)
        return p, float(aux["cost"])

    mesh2d = Mesh(np.array(jax.devices()[:8]).reshape(4, 2),
                  ("pod", "data"))
    p_2d, cost_2d = run(mesh2d, data_axis="data")
    p_1d, cost_1d = run(_mesh4())
    assert np.isfinite(cost_2d)
    np.testing.assert_allclose(cost_2d, cost_1d, rtol=1e-3)
    for a, b in zip(jax.tree_util.tree_leaves(p_2d),
                    jax.tree_util.tree_leaves(p_1d)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


@needs_pods
def test_fused_mesh_bit_matches_materializing():
    """DriverConfig(fused=True) sends every pod through the Pallas
    perturbed-forward kernels + mgd_update_window; the pinned coefficient
    association keeps it bit-identical to the materializing mesh path."""
    batch = _sharded_batch()

    def run(fused):
        cfg = repro.DriverConfig(dtheta=1e-2, eta=0.5, mode="central",
                                 seed=3, fused=fused)
        kw = {"probe_fn": make_mlp_probe_fn()} if fused else {}
        drv = repro.driver("probe_parallel", cfg, _loss, mesh=_mesh4(),
                           **kw)
        p = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
        s = drv.init(p)
        cs = []
        for _ in range(4):
            p, s, aux = drv.step(p, s, batch)
            cs.append(np.asarray(aux["c_tilde"]))
        return p, np.array(cs)

    p_mat, ct_mat = run(False)
    p_fus, ct_fus = run(True)
    np.testing.assert_array_equal(ct_mat, ct_fus)
    for a, b in zip(jax.tree_util.tree_leaves(p_mat),
                    jax.tree_util.tree_leaves(p_fus)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_pods
def test_ghat_variance_falls_with_k():
    """The scaling-laws acceptance axis: at frozen params with a
    replicated batch (batch_specs=P()), the k-averaged estimator's
    across-step variance falls ≈ 1/k — var(k=1)/var(k=4) lands near 4."""
    from repro.api import replace_step

    batch = {"x": X, "y": Y}
    params = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))

    def variance(k, rounds=48):
        cfg = repro.DriverConfig(dtheta=1e-2, eta=1.0, mode="central",
                                 seed=0)
        mesh = Mesh(np.array(jax.devices()[:k]).reshape(k), ("pod",))
        drv = repro.driver("probe_parallel", cfg, _loss, mesh=mesh,
                           batch_specs=P())
        state = drv.init(params)
        w0 = np.asarray(jax.tree_util.tree_leaves(params)[1])[0, 0]
        samples = []
        for t in range(rounds):
            p1, _, _ = drv.step(params, replace_step(state, t), batch)
            samples.append(
                np.asarray(jax.tree_util.tree_leaves(p1)[1])[0, 0] - w0)
        return float(np.var(samples))

    ratio = variance(1) / variance(4)
    assert 2.0 < ratio < 8.0, f"var(k=1)/var(k=4) = {ratio}"
