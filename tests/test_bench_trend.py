"""bench_trend: slow-drift detection over accumulated nightly artifacts.

Synthetic histories only — no benchmarks run here.  The drifting metric
moves a little each night (inside the per-run check_regression band) but
walks out of the band across the window; the flat metric stays put; the
ungated metric is reported informationally and never flagged.
"""
import json

import pytest

bench_trend = pytest.importorskip(
    "bench_trend", reason="tools/ not on sys.path (see tests/conftest.py)")


def _write_history(root, values_by_night):
    """values_by_night: [{bench: {name: value}}] → one subdir per night."""
    for i, metrics in enumerate(values_by_night):
        d = root / f"2026-08-{i + 1:02d}_{100 + i}"
        d.mkdir(parents=True)
        for bench, names in metrics.items():
            payload = {"rows": [{"bench": bench, "name": n, "value": v}
                                for n, v in names.items()]}
            (d / f"{bench}.json").write_text(json.dumps(payload))


def _nights(n):
    """ghat_variance_matched_k1 is gated at rel=0.75; drift +12%/night
    stays inside the band per-step but compounds past it over n nights.
    steps_per_s has no tolerance entry (machine-dependent, ungated)."""
    return [{"farm_scaling": {
        "ghat_variance_matched_k1": 1.0 * (1.12 ** i),
        "nist7x7_k1_accuracy": 0.9,
        "steps_per_s_thread_k1": 100.0 + i,
    }} for i in range(n)]


def test_slow_drift_flagged_flat_ok(tmp_path):
    _write_history(tmp_path, _nights(8))
    entries = bench_trend.load_history(tmp_path)
    assert len(entries) == 8
    lines, flagged = bench_trend.trend_report(entries, window=8)
    statuses = {ln.split(",")[1]: ln.split(",")[-1]
                for ln in lines[1:]}
    assert statuses["ghat_variance_matched_k1"] == "DRIFT"
    assert statuses["nist7x7_k1_accuracy"] == "ok"
    assert statuses["steps_per_s_thread_k1"] == "info"
    assert [f[1] for f in flagged] == ["ghat_variance_matched_k1"]


def test_short_window_sees_no_drift(tmp_path):
    # over 2 trailing nights the +12% step is inside the 75% band
    _write_history(tmp_path, _nights(8))
    entries = bench_trend.load_history(tmp_path)
    _, flagged = bench_trend.trend_report(entries, window=2)
    assert flagged == []


def test_cli_informational_vs_strict(tmp_path, capsys):
    _write_history(tmp_path, _nights(8))
    out = tmp_path / "report" / "trend.csv"
    assert bench_trend.main(["--history", str(tmp_path), "--window", "8",
                             "--out", str(out)]) == 0
    report = out.read_text()
    assert "DRIFT" in report and report.startswith("bench,name,")
    assert bench_trend.main(["--history", str(tmp_path), "--window", "8",
                             "--strict"]) == 1
    capsys.readouterr()


def test_corrupt_artifact_skipped(tmp_path):
    _write_history(tmp_path, _nights(3))
    (tmp_path / "2026-08-02_101" / "broken.json").write_text("{not json")
    entries = bench_trend.load_history(tmp_path)
    assert len(entries) == 3
    _, flagged = bench_trend.trend_report(entries, window=3)
    assert flagged == []
