"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import MGDConfig, build_mgd_step, mgd_init
from repro.core import perturbations as pert
from repro.core.forward_grad import true_gradient
from repro.core.utils import (tree_axpy, tree_dot, tree_norm, tree_scale,
                              tree_size)
from repro.distributed.compression import quantize_int8, dequantize_int8
from repro.distributed.sharding import logical_spec

SETTINGS = settings(max_examples=25, deadline=None)


@SETTINGS
@given(n=st.integers(2, 64), seed=st.integers(0, 2**31 - 1),
       step=st.integers(0, 10**6))
def test_rademacher_signs_are_pm_one(n, seed, step):
    dummy = {"w": jax.ShapeDtypeStruct((n,), jnp.float32)}
    p = pert.generate(dummy, ptype="rademacher", step=step, seed=seed,
                      dtheta=1.0)["w"]
    assert set(np.unique(np.asarray(p))) <= {-1.0, 1.0}


@SETTINGS
@given(n=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_sequential_perturbs_exactly_one(n, seed):
    dummy = {"w": jax.ShapeDtypeStruct((n,), jnp.float32)}
    for step in (0, 1, n - 1, n, 2 * n + 1):
        p = np.asarray(pert.generate(dummy, ptype="sequential", step=step,
                                     seed=seed, dtheta=0.5)["w"])
        assert (p != 0).sum() == 1
        assert p.sum() == np.float32(0.5)


@SETTINGS
@given(w=st.lists(st.floats(-3, 3, allow_nan=False), min_size=2,
                  max_size=8))
def test_fd_mode_recovers_linear_gradient_exactly(w):
    """For a LINEAR cost, the FD estimate has zero truncation error: after
    P sequential steps, G == ∇C for any weights (homodyne correctness)."""
    wv = jnp.asarray(w, jnp.float32)

    def loss(p, batch):
        return jnp.sum(p["w"] * wv)

    params = {"w": jnp.zeros(len(w))}
    cfg = MGDConfig(ptype="sequential", dtheta=0.25, eta=0.0,
                    tau_theta=10**9)
    state = mgd_init(params, cfg)
    step = jax.jit(build_mgd_step(loss, cfg))
    p = params
    for _ in range(len(w)):
        p, state, _ = step(p, state, None)
    np.testing.assert_allclose(np.asarray(state.g["w"]), np.asarray(wv),
                               rtol=1e-4, atol=1e-4)


@SETTINGS
@given(seed=st.integers(0, 1000))
def test_rademacher_estimator_unbiased_linear(seed):
    """E[C̃·θ̃/Δθ²] = ∇C for linear costs: the mean over many probes of the
    single-step G converges to the gradient."""
    g_true = jnp.asarray([1.5, -2.0, 0.5, 3.0])

    def loss(p, batch):
        return jnp.sum(p["w"] * g_true)

    params = {"w": jnp.zeros(4)}
    cfg = MGDConfig(dtheta=0.1, eta=0.0, tau_theta=10**9, seed=seed,
                    probes=64, mode="central")
    state = mgd_init(params, cfg)
    step = jax.jit(build_mgd_step(loss, cfg))
    _, state, _ = step(params, state, None)
    err = float(jnp.max(jnp.abs(state.g["w"] - g_true)))
    # 64 probes → s.e. ≈ |g|·√(P−1)/√64 ≈ 0.8; generous bound
    assert err < 3.0


@SETTINGS
@given(data=st.lists(st.floats(-100, 100, allow_nan=False,
                               allow_infinity=False, width=32),
                     min_size=1, max_size=64),
       seed=st.integers(0, 2**31 - 1))
def test_int8_quantization_bounded_error(data, seed):
    g = jnp.asarray(data, jnp.float32)
    residual = jnp.zeros_like(g)
    q, scale, new_res = quantize_int8(g, residual, jax.random.PRNGKey(seed))
    deq = dequantize_int8(q, scale)
    # error per element ≤ 1 quantum (stochastic rounding)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) + 1e-6
    # error feedback exactly carries the residual
    np.testing.assert_allclose(np.asarray(g - deq), np.asarray(new_res),
                               rtol=1e-5, atol=1e-5)


@SETTINGS
@given(dims=st.lists(st.integers(1, 512), min_size=1, max_size=4))
def test_logical_spec_always_divides(dims):
    class M:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    names = ["batch", "kvseq", "model", None][:len(dims)]
    spec = logical_spec(tuple(dims), names, M())
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= M.shape[a]
        assert dim % total == 0


@SETTINGS
@given(x=st.lists(st.floats(-10, 10, allow_nan=False, width=32),
                  min_size=1, max_size=32),
       a=st.floats(-5, 5, allow_nan=False, width=32))
def test_tree_axpy_linearity(x, a):
    t = {"w": jnp.asarray(x, jnp.float32)}
    z = {"w": jnp.zeros(len(x))}
    out = tree_axpy(a, t, z)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               a * np.asarray(t["w"]), rtol=1e-5,
                               atol=1e-5)
    # dot/norm consistency
    assert abs(float(tree_dot(t, t)) - float(tree_norm(t)) ** 2) < 1e-2
