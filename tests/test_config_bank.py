"""Config-bank rot guard.

The ten ``src/repro/configs/*`` modules carry the assigned architecture
bank; nothing in tier-1 imported them before this test, so they could rot
silently.  For every arch id this guard checks, at smoke scale, that the
config (1) builds real parameters, (2) shards cleanly under an 8-virtual-
CPU-device (pod, data, model) mesh through ``launch.specs.param_rules``,
and (3) takes one bit-deterministic MGD step through the public driver.

Multi-device: runs in CI's dedicated 8-virtual-device step
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

import repro
from repro.configs import ARCH_IDS, get_smoke_config
from repro.launch.specs import param_shardings, train_input_specs
from repro.models import model_init, model_loss

needs_8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs >= 8 devices — run with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh222():
    return Mesh(np.array(jax.devices()[:8]).reshape(2, 2, 2),
                ("pod", "data", "model"))


class _TinyShape:
    """Minimal stand-in for ShapeSpec at rot-guard scale."""
    global_batch = 2
    seq_len = 8
    kind = "train"
    name = "rot_guard"


def _tiny_batch(cfg):
    """Concrete deterministic batch matching the arch's train input specs.

    Non-degenerate values (an all-zeros batch can leave the probe's cost
    difference below f32 resolution, which reads as a no-op step)."""
    specs = train_input_specs(cfg, _TinyShape())

    def fill(s):
        n = int(np.prod(s.shape)) if s.shape else 1
        if jnp.issubdtype(s.dtype, jnp.integer):
            return (jnp.arange(n, dtype=s.dtype) % jnp.asarray(
                max(2, cfg.vocab // 2), s.dtype)).reshape(s.shape)
        return (0.25 * jnp.sin(jnp.arange(n, dtype=jnp.float32))
                ).reshape(s.shape).astype(s.dtype)

    return jax.tree_util.tree_map(fill, specs)


@pytest.mark.parametrize("arch", ARCH_IDS)
@needs_8
def test_smoke_config_builds_shards_and_steps(arch):
    cfg = get_smoke_config(arch)
    params = model_init(cfg, jax.random.PRNGKey(0))
    n_leaves = len(jax.tree_util.tree_leaves(params))
    assert n_leaves > 0

    # shards cleanly: every leaf placeable under the rule table's spec
    mesh = _mesh222()
    shardings = param_shardings(cfg, mesh)
    placed = jax.device_put(params, shardings)
    assert len(jax.tree_util.tree_leaves(placed)) == n_leaves
    del placed

    # one bit-deterministic MGD step through the public driver
    batch = _tiny_batch(cfg)

    def loss(p, b):
        return model_loss(p, cfg, b)

    def one_step():
        dcfg = repro.DriverConfig(dtheta=1e-3, eta=1e-2, mode="central",
                                  seed=7)
        drv = repro.driver("discrete", dcfg, loss)
        p1, _, aux = drv.step(params, drv.init(params), batch)
        return p1, float(aux["cost"])

    p_a, cost_a = one_step()
    p_b, cost_b = one_step()
    assert np.isfinite(cost_a)
    assert cost_a == cost_b
    moved = 0
    for a, b, p0 in zip(jax.tree_util.tree_leaves(p_a),
                        jax.tree_util.tree_leaves(p_b),
                        jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        moved += int(not np.array_equal(np.asarray(a), np.asarray(p0)))
    assert moved > 0, "MGD step left every parameter untouched"
