"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only the dry-run
(and subprocess-based distributed tests) use virtual device counts."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
