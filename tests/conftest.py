"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only the dry-run
(and subprocess-based distributed tests) use virtual device counts."""
import gc
import multiprocessing
import pathlib
import sys
import threading
import time

import jax
import pytest

# tests import the linter directly (test_mgdlint, test_hygiene);
# tools/ is not a package root on the runtime path otherwise
_TOOLS = pathlib.Path(__file__).resolve().parent.parent / "tools"
if str(_TOOLS) not in sys.path:
    sys.path.insert(0, str(_TOOLS))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def _live_worker_threads():
    """Non-daemon threads beyond the main thread.  Daemon threads are
    excluded: backend runners/supervisors are daemonic by design (an
    unclean exit must not hang on them), so a leaked daemon shows up
    as a leaked *child process* or a failed MGD005 invariant instead."""
    return {t for t in threading.enumerate()
            if t is not threading.main_thread()
            and t.is_alive() and not t.daemon}


@pytest.fixture(scope="session", autouse=True)
def _leak_sentinel():
    """Fail the suite if backend tests leak workers.

    Complements MGD003/MGD005 dynamically: the static rules prove every
    gather is bounded and teardown paths exist; this fixture proves the
    teardowns actually RAN.  Farms lean on GC finalizers for cleanup,
    so collect first, then give stragglers a short grace window (a
    ThreadBackend join is bounded at ~2s per worker) before failing.
    """
    threads_before = _live_worker_threads()
    procs_before = set(multiprocessing.active_children())

    yield

    gc.collect()          # run farm/backend weakref finalizers
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        leaked_threads = _live_worker_threads() - threads_before
        leaked_procs = {p for p in multiprocessing.active_children()
                        if p not in procs_before and p.is_alive()}
        if not leaked_threads and not leaked_procs:
            return
        time.sleep(0.2)

    lines = [f"  thread {t.name!r} (non-daemon, still alive)"
             for t in sorted(leaked_threads, key=lambda t: t.name)]
    lines += [f"  process {p.name!r} pid={p.pid}"
              for p in sorted(leaked_procs, key=lambda p: p.name)]
    pytest.fail(
        "leaked workers after the test session — some backend was not "
        "shut down (ChipFarm.close() / backend.shutdown() missing or "
        "unreachable):\n" + "\n".join(lines), pytrace=False)
