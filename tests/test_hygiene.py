"""Source-hygiene guards (grep-based, no imports of the checked code).

The deadlock class this PR removed — a ``concurrent.futures`` gather
with no timeout inside an ordered ``io_callback``, where one hung
instrument freezes training forever and Ctrl-C barely works — must not
silently reappear: every ``.result(...)`` in ``src/repro/hardware/``
has to pass an explicit timeout.
"""
import pathlib
import re

HARDWARE_DIR = (pathlib.Path(__file__).resolve().parent.parent
                / "src" / "repro" / "hardware")


def test_every_future_gather_in_hardware_has_a_timeout():
    offenders = []
    for path in sorted(HARDWARE_DIR.rglob("*.py")):
        src = path.read_text()
        for match in re.finditer(r"\.result\(([^)]*)\)", src):
            if "timeout" not in match.group(1):
                line = src[:match.start()].count("\n") + 1
                offenders.append(f"{path.name}:{line}: {match.group(0)}")
    assert not offenders, (
        "concurrent.futures result-gathers without an explicit timeout "
        "(a hung instrument would deadlock the ordered io_callback):\n"
        + "\n".join(offenders))


def test_hardware_sources_exist():
    # the guard above must actually be scanning something
    assert (HARDWARE_DIR / "farm.py").is_file()
    assert (HARDWARE_DIR / "external.py").is_file()
    assert (HARDWARE_DIR / "faults.py").is_file()
    assert (HARDWARE_DIR / "backend" / "base.py").is_file()


def test_every_backend_defines_shutdown():
    """Every farm backend must own its teardown: sweeps build many farms
    per process, and a backend without a shutdown path leaks its workers
    (threads or processes) until interpreter exit."""
    backend_dir = HARDWARE_DIR / "backend"
    # subclassing a CONCRETE backend inherits its teardown; FarmBackend
    # itself only raises NotImplementedError, so it does not count
    inherits = re.compile(
        r"class\s+\w+\((SerialBackend|ThreadBackend|ProcessBackend)\)")
    for path in sorted(backend_dir.glob("*.py")):
        if path.name == "__init__.py":
            continue
        src = path.read_text()
        assert "def shutdown" in src or inherits.search(src), (
            f"{path.name}: no shutdown() and no concrete-backend base — "
            "every backend module needs a worker teardown path")


def test_process_backend_actually_kills_workers():
    """The process backend's whole point is REAL kills: hung workers are
    terminated (not politely joined forever), joins are bounded, and
    workers are daemonic so an unclean interpreter exit cannot hang on
    them."""
    src = (HARDWARE_DIR / "backend" / "process.py").read_text()
    assert ".terminate()" in src, "no process terminate() — hangs survive"
    assert re.search(r"\.join\(\s*(timeout\s*=)?\s*[\d.]", src), \
        "unbounded process join — a hung worker would hang teardown"
    assert "daemon=True" in src, "non-daemon workers outlive the host"


def test_farm_close_tears_down_backend():
    """ChipFarm.close() must route through the backend's shutdown (via
    the GC finalizer) — a farm that only shuts its own pools leaks the
    backend's workers."""
    src = (HARDWARE_DIR / "farm.py").read_text()
    assert "backend.shutdown" in src
