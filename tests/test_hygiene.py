"""Source-hygiene guards, now riding on the mgdlint AST walker.

History: these started as four regex greps guarding the PR 2/6 deadlock
class (a ``concurrent.futures`` gather with no timeout inside an
ordered ``io_callback`` freezes training forever).  The ``.result(``
grep is subsumed by mgdlint rule MGD003, which is AST-level and also
catches the multi-line and aliased calls regex misses; the teardown
checks are now structural AST asserts built on the same walker, so a
refactor that merely re-spells a call cannot dodge them.
"""
import ast
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
HARDWARE_DIR = REPO / "src" / "repro" / "hardware"

mgdlint = pytest.importorskip(
    "mgdlint", reason="tools/ not on sys.path (see tests/conftest.py)")
from mgdlint.walker import SourceFile, dotted_name  # noqa: E402


def _source(path: pathlib.Path) -> SourceFile:
    return SourceFile(path, REPO)


def test_hardware_sources_exist():
    # the guards below must actually be scanning something
    assert (HARDWARE_DIR / "farm.py").is_file()
    assert (HARDWARE_DIR / "external.py").is_file()
    assert (HARDWARE_DIR / "faults.py").is_file()
    assert (HARDWARE_DIR / "backend" / "base.py").is_file()


def test_every_blocking_gather_in_hardware_has_a_timeout():
    """MGD003 subsumes the old ``.result(`` regex: every Future.result,
    wait, queue get, join and acquire in hardware/ needs an explicit
    timeout (or a reasoned waiver).  Running the rule here keeps the
    protection even if the CI lint job is skipped."""
    result = mgdlint.run_lint([HARDWARE_DIR], REPO, select=["MGD003"])
    assert not result.parse_errors, result.parse_errors
    offenders = [f.format() for f in result.findings]
    assert not offenders, (
        "blocking gathers without an explicit timeout (a hung "
        "instrument would deadlock the ordered io_callback):\n"
        + "\n".join(offenders))
    # every hardware waiver must carry a reason — no silent escapes
    for path in sorted(HARDWARE_DIR.rglob("*.py")):
        for w in _source(path).waivers:
            assert not w.malformed, f"{path.name}:{w.line}: {w.malformed}"


def _module_classes(source: SourceFile):
    return [n for n in source.tree.body if isinstance(n, ast.ClassDef)]


def _class_methods(cls: ast.ClassDef):
    return {n.name for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


CONCRETE_BACKENDS = {"SerialBackend", "ThreadBackend", "ProcessBackend"}


def test_every_backend_defines_shutdown():
    """Every farm backend must own its teardown: sweeps build many farms
    per process, and a backend without a shutdown path leaks its workers
    (threads or processes) until interpreter exit."""
    backend_dir = HARDWARE_DIR / "backend"
    for path in sorted(backend_dir.glob("*.py")):
        if path.name == "__init__.py":
            continue
        source = _source(path)
        ok = False
        for cls in _module_classes(source):
            bases = {dotted_name(b) for b in cls.bases}
            if "shutdown" in _class_methods(cls) \
                    or bases & CONCRETE_BACKENDS:
                ok = True
        assert ok, (
            f"{path.name}: no class defines shutdown() and none "
            "subclasses a concrete backend — every backend module "
            "needs a worker teardown path")


def test_process_backend_actually_kills_workers():
    """The process backend's whole point is REAL kills: hung workers are
    terminated (not politely joined forever), joins are bounded, and
    workers are daemonic so an unclean interpreter exit cannot hang on
    them."""
    source = _source(HARDWARE_DIR / "backend" / "process.py")
    terminates, daemons, unbounded_joins = 0, 0, []
    for node in ast.walk(source.tree):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute):
                if node.func.attr == "terminate":
                    terminates += 1
                elif node.func.attr == "join":
                    bounded = bool(node.args) or any(
                        k.arg == "timeout" and not (
                            isinstance(k.value, ast.Constant)
                            and k.value.value is None)
                        for k in node.keywords)
                    if not bounded:
                        unbounded_joins.append(node.lineno)
            for k in node.keywords:
                if k.arg == "daemon" and isinstance(k.value, ast.Constant) \
                        and k.value.value is True:
                    daemons += 1
    assert terminates, "no process terminate() — hangs survive"
    assert not unbounded_joins, (
        f"unbounded join() at line(s) {unbounded_joins} — a hung "
        "worker would hang teardown")
    assert daemons, "non-daemon workers outlive the host"


def test_farm_close_tears_down_backend():
    """ChipFarm.close() must route through the backend's shutdown (via
    the GC finalizer) — a farm that only shuts its own pools leaks the
    backend's workers."""
    source = _source(HARDWARE_DIR / "farm.py")
    calls = []
    for node in ast.walk(source.tree):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "shutdown":
            base = dotted_name(node.func.value) or ""
            if "backend" in base:
                calls.append(node.lineno)
    assert calls, "farm.py never calls <backend>.shutdown(...)"


def test_repo_tree_is_mgdlint_clean():
    """The full lint gate, as CI runs it: src/tests/benchmarks must be
    clean against the committed baseline — and hardware/ and
    distributed/ must carry ZERO baseline entries (their invariants
    deadlock training or silently retrace when violated; they get
    fixed or waived-with-reason, never grandfathered)."""
    result = mgdlint.run_lint(
        [REPO / "src", REPO / "tests", REPO / "benchmarks"], REPO)
    assert not result.parse_errors, result.parse_errors
    entries = mgdlint.load_baseline(REPO / "tools/mgdlint/baseline.json")
    new, _, _ = mgdlint.split_baseline(result.findings, entries)
    assert not new, "new mgdlint findings:\n" + "\n".join(
        f.format() for f in new)
    clean_trees = ("src/repro/hardware/", "src/repro/distributed/")
    frozen = [e for e in entries
              if e["path"].startswith(clean_trees)]
    assert not frozen, (
        f"baseline entries under {clean_trees} are forbidden: {frozen}")
