"""Source-hygiene guards (grep-based, no imports of the checked code).

The deadlock class this PR removed — a ``concurrent.futures`` gather
with no timeout inside an ordered ``io_callback``, where one hung
instrument freezes training forever and Ctrl-C barely works — must not
silently reappear: every ``.result(...)`` in ``src/repro/hardware/``
has to pass an explicit timeout.
"""
import pathlib
import re

HARDWARE_DIR = (pathlib.Path(__file__).resolve().parent.parent
                / "src" / "repro" / "hardware")


def test_every_future_gather_in_hardware_has_a_timeout():
    offenders = []
    for path in sorted(HARDWARE_DIR.glob("*.py")):
        src = path.read_text()
        for match in re.finditer(r"\.result\(([^)]*)\)", src):
            if "timeout" not in match.group(1):
                line = src[:match.start()].count("\n") + 1
                offenders.append(f"{path.name}:{line}: {match.group(0)}")
    assert not offenders, (
        "concurrent.futures result-gathers without an explicit timeout "
        "(a hung instrument would deadlock the ordered io_callback):\n"
        + "\n".join(offenders))


def test_hardware_sources_exist():
    # the guard above must actually be scanning something
    assert (HARDWARE_DIR / "farm.py").is_file()
    assert (HARDWARE_DIR / "external.py").is_file()
    assert (HARDWARE_DIR / "faults.py").is_file()
