"""Online serving tier: snapshot-consistent swaps, serve→trim→resume
bit-exactness, the uniform lifecycle contract, and the consolidated
``TrainLoopConfig`` front door.

Load-bearing contracts:
* **Torn-swap regression**: a decode in flight during a trainer publish
  sees either the old or the new parameter tree IN FULL, never a mix —
  the dispatcher takes one ``ParamStore`` snapshot per slot batch.
* **Bit-exact resume**: interrupt a serve→trim run at a checkpoint,
  restore (params + driver state + replay ring sidecar), continue —
  f32-identical to the uninterrupted trajectory.
* **Uniform lifecycle**: ``ExternalPlant``, ``ChipFarm`` and
  ``OnlineService`` share ``__enter__/__exit__`` + idempotent
  ``close()`` + ``fence()``.
* **TrainLoopConfig**: the consolidated loop config is f32-bit-identical
  to the flat-kwarg path, which fires ONE PendingDeprecationWarning.
"""
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api.driver import DriverConfig
from repro.serving.online import (OnlineService, ParamStore, ReplayBuffer,
                                  ServiceConfig, TrimConfig)

W_TRUE = np.arange(6, dtype=np.float32).reshape(3, 2)


def _predict(p, batch):
    return batch["x"] @ p["w"]


def _loss(p, b):
    return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)


def _params():
    return {"w": jnp.zeros((3, 2), jnp.float32)}


def _svc(cfg=None, trim=True, **kw):
    if cfg is None:
        base = dict(slots=4, min_fill=4, trim_batch=4, publish_every=5,
                    batch_window_s=0.001)
        base.update(kw)
        cfg = ServiceConfig(**base)
    tc = TrimConfig(DriverConfig(dtheta=5e-2, eta=0.2), _loss) if trim \
        else None
    return repro.serve(cfg, _predict, _params(), trim=tc, start=False)


def _traffic(svc, n=16, seed=0):
    rng = np.random.default_rng(seed)
    futs = []
    for _ in range(n):
        x = rng.normal(size=(3,)).astype(np.float32)
        futs.append(svc.submit({"x": x}, feedback={"y": x @ W_TRUE}))
    return [f.result(30) for f in futs]


# ---------------------------------------------------------------------------
# Snapshot consistency — the torn-swap regression test
# ---------------------------------------------------------------------------


def test_param_swap_never_tears_mid_decode():
    """Two leaves are always published with EQUAL fill values; any
    response whose leaves disagree, or whose output doesn't match its
    stamped version, caught a torn swap."""
    def paired_predict(p, batch):
        # per-slot [a-b, a]: a-b != 0 would mean a mixed tree
        a = jnp.sum(batch["x"] * 0) + p["a"][0]
        b = p["b"][0]
        return jnp.stack([jnp.broadcast_to(a - b, batch["x"].shape[:1]),
                          jnp.broadcast_to(a, batch["x"].shape[:1])], -1)

    params = {"a": jnp.zeros((64,)), "b": jnp.zeros((64,))}
    svc = OnlineService(paired_predict, params,
                        ServiceConfig(slots=4, batch_window_s=0.0005))
    svc.start()
    stop = threading.Event()

    def publisher():
        v = 0
        while not stop.is_set():
            v += 1
            fill = jnp.full((64,), float(v))
            svc.store.publish({"a": fill, "b": fill})

    pub = threading.Thread(target=publisher, daemon=True)
    pub.start()
    try:
        futs = [svc.submit({"x": np.zeros(3, np.float32)})
                for _ in range(200)]
        for f in futs:
            r = f.result(30)
            assert float(r.output[0]) == 0.0, "torn swap: leaves disagree"
            # the value decoded must be the version the snapshot stamped
            assert float(r.output[1]) == float(r.version)
    finally:
        stop.set()
        pub.join(timeout=10)
        svc.close()


def test_store_snapshot_is_atomic_reference():
    store = ParamStore({"w": jnp.zeros(3)})
    assert store.version == 0
    v = store.publish({"w": jnp.ones(3)})
    snap = store.snapshot()
    assert v == 1 and snap.version == 1
    store.publish({"w": jnp.full((3,), 2.0)})
    # a held snapshot is immutable — later publishes don't touch it
    np.testing.assert_array_equal(np.asarray(snap.params["w"]), np.ones(3))


# ---------------------------------------------------------------------------
# Serve → trim → resume bit-exactness (f32)
# ---------------------------------------------------------------------------


def test_serve_trim_resume_bit_exact(tmp_path):
    def make(d=None):
        cfg = ServiceConfig(slots=4, min_fill=4, trim_batch=4,
                            publish_every=5, checkpoint_dir=d,
                            checkpoint_every=5, batch_window_s=0.001)
        return repro.serve(cfg, _predict, _params(),
                           trim=TrimConfig(DriverConfig(dtheta=5e-2,
                                                        eta=0.2), _loss),
                           start=False)

    d = str(tmp_path / "ck")
    a = make(d).start(background_trim=False)
    _traffic(a)
    assert a.trim(10) == 10
    a.close()

    b = make(d).start(background_trim=False)
    assert b.resumed_step == 10
    assert len(b.replay) == 16          # the ring came back via sidecar
    b.trim(5)
    w_resumed = np.asarray(b.trimmer.params["w"])
    assert b.trimmer.global_step == 15
    b.close()

    c = make(None).start(background_trim=False)
    _traffic(c)
    c.trim(15)
    w_straight = np.asarray(c.trimmer.params["w"])
    c.close()
    np.testing.assert_array_equal(w_resumed, w_straight)


def test_trim_improves_served_cost():
    svc = _svc().start(background_trim=False)
    try:
        _traffic(svc)
        x = np.ones(3, np.float32)
        before = float(np.abs(svc.serve({"x": x}).output - x @ W_TRUE).sum())
        svc.trim(200)
        after = float(np.abs(svc.serve({"x": x}).output - x @ W_TRUE).sum())
        assert after < before * 0.5, (before, after)
        assert svc.version == 40        # 200 steps / publish_every=5
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Replay buffer
# ---------------------------------------------------------------------------


def test_replay_buffer_bounded_and_counter_keyed():
    buf = ReplayBuffer(capacity=8)
    for i in range(12):
        buf.add({"x": np.full(3, float(i), np.float32)})
    assert len(buf) == 8 and buf.total_added == 12
    # oldest entries evicted: fills 4..11 remain
    s = buf.sample(64, step=3, seed=7)
    assert set(np.unique(s["x"])) <= set(float(i) for i in range(4, 12))
    # counter-keyed: same (seed, step) → same batch; different step differs
    np.testing.assert_array_equal(buf.sample(16, step=3, seed=7)["x"],
                                  buf.sample(16, step=3, seed=7)["x"])
    assert not np.array_equal(buf.sample(16, step=3, seed=7)["x"],
                              buf.sample(16, step=4, seed=7)["x"])


def test_replay_buffer_rejects_bad_shapes():
    buf = ReplayBuffer(capacity=4)
    buf.add({"x": np.zeros(3, np.float32)})
    with pytest.raises(ValueError, match="keys"):
        buf.add({"y": np.zeros(3, np.float32)})
    with pytest.raises(ValueError, match="empty"):
        ReplayBuffer(capacity=4).sample(1, step=0)


def test_feedback_flows_into_replay_only_when_given():
    svc = _svc(trim=False).start()
    try:
        svc.serve({"x": np.zeros(3, np.float32)})
        assert len(svc.replay) == 0     # no feedback, no logging
        svc.serve({"x": np.zeros(3, np.float32)},
                  feedback={"y": np.zeros(2, np.float32)})
        assert len(svc.replay) == 1
        with pytest.raises(RuntimeError, match="no trimmer"):
            svc.trim(1)
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Uniform lifecycle contract
# ---------------------------------------------------------------------------


def _lifecycle_objects():
    from repro.hardware import ExternalPlant, SimulatedAnalogChip
    from repro.hardware.farm import ChipFarm
    yield ExternalPlant(SimulatedAnalogChip((2, 2, 1)))
    yield ChipFarm([SimulatedAnalogChip((2, 2, 1), seed=s)
                    for s in range(2)])
    yield _svc(trim=False)


@pytest.mark.parametrize("obj_factory", [_lifecycle_objects],
                         ids=["plants_and_service"])
def test_uniform_lifecycle_contract(obj_factory):
    for obj in obj_factory():
        name = type(obj).__name__
        assert callable(getattr(obj, "fence", None)), name
        assert callable(getattr(obj, "close", None)), name
        with obj as entered:
            assert entered is obj, name
            entered.fence()
        obj.close()                      # second close: idempotent
        obj.close()


def test_service_rejects_use_after_close():
    svc = _svc(trim=False).start()
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit({"x": np.zeros(3, np.float32)})
    with pytest.raises(RuntimeError, match="closed"):
        svc.start()


def test_service_requires_start_before_submit():
    svc = _svc(trim=False)
    with pytest.raises(RuntimeError, match="start"):
        svc.submit({"x": np.zeros(3, np.float32)})
    svc.close()


def test_fence_drains_inflight_requests():
    svc = _svc(trim=False).start()
    try:
        futs = [svc.submit({"x": np.zeros(3, np.float32)})
                for _ in range(32)]
        svc.fence()
        assert all(f.done() for f in futs)
    finally:
        svc.close()


def test_ragged_request_shape_is_loud():
    svc = _svc(trim=False, slots=4, batch_window_s=0.05).start()
    try:
        f1 = svc.submit({"x": np.zeros(3, np.float32)})
        f2 = svc.submit({"x": np.zeros(5, np.float32)})
        with pytest.raises(ValueError, match="fixed-shape"):
            f2.result(30)
        with pytest.raises(ValueError):
            f1.result(30)               # whole batch fails loudly
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# TrainLoopConfig — consolidated loop front door
# ---------------------------------------------------------------------------


BATCH_W = jnp.asarray(W_TRUE)


def _train_loss(p, b):
    return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)


def _sample_fn(step):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 3)) + step * 0.01
    return {"x": x, "y": x @ BATCH_W}


def test_trainloopconfig_bit_identical_to_flat_kwargs():
    cfg = DriverConfig(dtheta=1e-2, eta=0.5)
    p0 = {"w": jnp.zeros((3, 2), jnp.float32)}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PendingDeprecationWarning)
        r_flat = repro.train(_train_loss, p0, cfg, _sample_fn, 20,
                             chunk=10, log=None)
    r_loop = repro.train(_train_loss, p0, cfg, _sample_fn, 20,
                         loop=repro.TrainLoopConfig(chunk=10, log=None))
    for a, b in zip(jax.tree_util.tree_leaves(r_flat.params),
                    jax.tree_util.tree_leaves(r_loop.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_kwargs_fire_single_pending_deprecation():
    from repro.api.driver import _WARNED
    _WARNED.discard("train_mgd's flat loop keywords")
    cfg = DriverConfig(dtheta=1e-2, eta=0.5)
    p0 = {"w": jnp.zeros((3, 2), jnp.float32)}
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        repro.train(_train_loss, p0, cfg, _sample_fn, 2, chunk=1, log=None)
        repro.train(_train_loss, p0, cfg, _sample_fn, 2, chunk=1, log=None)
    pend = [w for w in rec
            if issubclass(w.category, PendingDeprecationWarning)
            and "TrainLoopConfig" in str(w.message)]
    assert len(pend) == 1, [str(w.message) for w in rec]


def test_trainloopconfig_rejects_mixes_and_unknowns():
    cfg = DriverConfig(dtheta=1e-2, eta=0.5)
    p0 = {"w": jnp.zeros((3, 2), jnp.float32)}
    with pytest.raises(TypeError, match="TrainLoopConfig"):
        repro.train(_train_loss, p0, cfg, _sample_fn, 1, bogus=1)
    with pytest.raises(ValueError, match="one place"):
        repro.train(_train_loss, p0, cfg, _sample_fn, 1,
                    loop=repro.TrainLoopConfig(), chunk=5)


def test_lazy_front_door_exports():
    import importlib
    import sys
    for name in ("train", "serve", "driver", "TrainLoopConfig",
                 "ServiceConfig", "TrimConfig", "OnlineService"):
        assert name in repro.__all__, name
        assert getattr(repro, name) is not None
    # a fresh import of repro must not drag jax in
    saved = {k: sys.modules.pop(k) for k in list(sys.modules)
             if k == "repro" or k.startswith("repro.")}
    jax_mods = {k: sys.modules.pop(k) for k in list(sys.modules)
                if k == "jax" or k.startswith("jax.")}
    try:
        importlib.import_module("repro")
        assert "jax" not in sys.modules
    finally:
        sys.modules.update(saved)
        sys.modules.update(jax_mods)
