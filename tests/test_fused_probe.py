"""Fused probe execution path: the optimizer hot loop routed through the
Pallas kernels must be *bit-identical* (f32) to the materializing path —
same murmur3 hash, same float association, θ̃ never in HBM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MGDConfig, build_mgd_step, mgd_init, mse
from repro.core import perturbations as pert
from repro.core.utils import tree_add, tree_axpy
from repro.kernels import ops, ref
from repro.models.simple import make_mlp_probe_fn, mlp_apply, mlp_init

XOR_X = jnp.array([[0., 0.], [1., 0.], [0., 1.], [1., 1.]], jnp.float32)
XOR_Y = jnp.array([[0.], [1.], [1.], [0.]], jnp.float32)
BATCH = {"x": XOR_X, "y": XOR_Y}


def _mlp_loss(p, b):
    return mse(mlp_apply(p, b["x"]), b["y"])


def _run(cfg, steps=36):
    params = mlp_init(jax.random.PRNGKey(0), (2, 2, 1))
    step = jax.jit(build_mgd_step(
        _mlp_loss, cfg,
        probe_fn=make_mlp_probe_fn() if cfg.fused else None))
    state = mgd_init(params, cfg)
    cts = []
    for _ in range(steps):
        params, state, m = step(params, state, BATCH)
        cts.append(np.asarray(m["c_tilde"]))
    return np.array(cts), params


@pytest.mark.parametrize("eta", [0.5, 1.0])
@pytest.mark.parametrize("mode", ["forward", "central"])
@pytest.mark.parametrize("window", [{}, {"replay": True, "tau_theta": 4}])
def test_fused_bit_identical_mlp(mode, window, eta):
    """≥32 MGD steps: C̃ sequence AND parameter trajectory bitwise equal
    between fused=True (interpret kernels) and the materializing path.
    η = 1 is the historically broken corner: XLA folds the (−η)·
    multiply to a negation, exposing θ̃·s to mul+add FMA contraction —
    both update paths now multiply by the exact ±1 sign LAST, which no
    contraction can re-round (core/mgd.py sign_exact_update,
    kernels/mgd_update.py)."""
    base = dict(mode=mode, dtheta=1e-2, eta=eta, seed=3, **window)
    c_mat, p_mat = _run(MGDConfig(**base))
    c_fus, p_fus = _run(MGDConfig(fused=True, kernel_impl="interpret",
                                  **base))
    np.testing.assert_array_equal(c_mat, c_fus)
    for a, b in zip(jax.tree_util.tree_leaves(p_mat),
                    jax.tree_util.tree_leaves(p_fus)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_requires_probe_fn_and_valid_config():
    with pytest.raises(ValueError):
        build_mgd_step(_mlp_loss, MGDConfig(fused=True))
    with pytest.raises(ValueError):
        MGDConfig(fused=True, ptype="walsh")
    with pytest.raises(ValueError):
        MGDConfig(fused=True, tau_theta=4)          # needs replay
    with pytest.raises(ValueError):
        MGDConfig(fused=True, momentum=0.9)


# --- pair kernel ------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(16, 48, 80), (8, 8, 8), (64, 128, 256),
                                   (5, 127, 257)])
def test_perturbed_matmul_pair_matches_two_singles(m, k, n):
    """One pair-kernel pass == two independent perturbed_matmul calls."""
    xp = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    xm = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n), jnp.float32) * 0.1
    ls = pert.leaf_seed(7, 3, 2)
    yp, ym = ops.perturbed_matmul_pair(xp, xm, w, ls, dtheta=0.01,
                                       impl="interpret")
    y1 = ops.perturbed_matmul(xp, w, ls, dtheta=0.01, sign=1.0,
                              impl="interpret")
    y2 = ops.perturbed_matmul(xm, w, ls, dtheta=0.01, sign=-1.0,
                              impl="interpret")
    np.testing.assert_array_equal(np.asarray(yp), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(ym), np.asarray(y2))


def test_perturbed_matmul_pair_matches_ref():
    xp = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    xm = jax.random.normal(jax.random.PRNGKey(1), (32, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 96), jnp.float32)
    ls = pert.leaf_seed(1, 5, 0)
    yp, ym = ops.perturbed_matmul_pair(xp, xm, w, ls, dtheta=0.05,
                                       impl="interpret")
    rp, rm = ref.perturbed_matmul_pair_ref(xp, xm, w, ls, dtheta=0.05)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(rp),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(rm),
                               rtol=1e-5, atol=1e-5)


# --- tiling / padding (the _largest_tile fix) -------------------------------


@pytest.mark.parametrize("k,n", [(127, 257), (257, 127), (130, 254)])
def test_prime_dims_pad_not_degenerate(k, n):
    """Prime/awkward dims must zero-pad to healthy tiles (the old divisor
    search degraded K=127 → bk=1), and the signs of the real elements must
    stay anchored to the unpadded leaf."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32) * 0.1
    ls = pert.leaf_seed(9, 2, 1)
    y_ref = ref.perturbed_matmul_ref(x, w, ls, dtheta=0.01)
    y_pal = ops.perturbed_matmul(x, w, ls, dtheta=0.01, impl="interpret")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-4, atol=1e-4)
    coefs = jnp.array([0.3, -0.2], jnp.float32)
    lseeds = jnp.array([pert.leaf_seed(9, t, 1) for t in (0, 1)], jnp.uint32)
    u_ref = ref.mgd_update_ref(w, lseeds, coefs, eta=0.1, dtheta=0.01)
    u_pal = ops.mgd_update(w, lseeds, coefs, eta=0.1, dtheta=0.01,
                           impl="interpret")
    np.testing.assert_allclose(np.asarray(u_ref), np.asarray(u_pal),
                               rtol=1e-4, atol=1e-3)


# --- exact-order window update ----------------------------------------------


def test_mgd_update_window_matches_sequential_axpy():
    """mgd_update_window == the optimizer's per-step axpy chain, bitwise,
    including on a stacked 3-D bank (row-major slice indexing)."""
    w = jax.random.normal(jax.random.PRNGKey(3), (3, 40, 17), jnp.float32)
    steps = [5, 6, 7]
    seed = jnp.uint32(0)
    lseeds = jnp.array([pert.leaf_seed(seed, t, 0) for t in steps],
                       jnp.uint32)
    raw = jnp.array([0.37, -0.21, 0.05], jnp.float32)
    coefs = jnp.float32(-0.01 / (0.1 * 0.1)) * raw     # replay's a_j
    fused = ops.mgd_update_window(w, lseeds, coefs, alpha=1.0, dtheta=0.1,
                                  impl="interpret")
    w_seq = w
    for t, c in zip(steps, raw):
        theta = pert.generate({"w": w}, ptype="rademacher", step=t,
                              seed=seed, dtheta=0.1)["w"]
        a = jnp.float32(-0.01 / (0.1 * 0.1)) * c
        w_seq = tree_axpy(a, {"w": theta}, {"w": w_seq})["w"]
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(w_seq))


# --- transformer fused probe -------------------------------------------------


def test_transformer_fused_probe_bit_identical():
    from repro.configs import get_smoke_config
    from repro.models import (make_transformer_probe_fn, model_init,
                              model_loss, supports_fused_probe)
    cfg = get_smoke_config("qwen3-14b").replace(dtype="float32")
    assert supports_fused_probe(cfg)
    params = model_init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    step, seed = jnp.int32(3), jnp.uint32(7)
    theta = pert.generate(params, ptype="rademacher", step=step, seed=seed,
                          dtheta=1e-3)
    c_plus = model_loss(tree_add(params, theta), cfg, batch)
    c_minus = model_loss(tree_axpy(-1.0, theta, params), cfg, batch)
    probe_fn = make_transformer_probe_fn(cfg)
    ctx = pert.ProbeCtx(signs=(1.0, -1.0), dtheta=1e-3, impl="interpret")
    costs = probe_fn(params, batch, pert.Probe(step, seed, ctx))
    np.testing.assert_array_equal(np.asarray(costs[0]), np.asarray(c_plus))
    np.testing.assert_array_equal(np.asarray(costs[1]), np.asarray(c_minus))
