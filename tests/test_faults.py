"""Fault-tolerant host boundary: injection, retries, masking, quarantine.

Load-bearing:
* ``FaultyChip`` injects counter-keyed, bit-reproducible faults and
  mirrors the wrapped device's capability surface, so the plant drivers
  see the same instrument.
* Retries under a ``FaultPolicy`` never reorder or duplicate the
  (step, tag) counter stream the inner device sees: a transient fault
  that clears on retry leaves the trajectory BIT-IDENTICAL to the
  fault-free run (readouts are counter-keyed, not stream-keyed).
* A chip that exhausts its retries is masked (``valid[k]=False``, NaN
  costs) instead of unwinding the jitted step, and the masked average
  applies the η-rescaling rule exactly (fixed −η/(k·Δθ²) per survivor).
* Quarantine gates the probe path only; readmission leaves the chip's
  counter-keyed noise stream untouched.
* A hung chip stalls a step by at most the configured timeout — no
  deadlock — and farm checkpoint/resume stays bit-exact through
  injected faults.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import DriverConfig
from repro.core import probe_parallel as pp
from repro.core import perturbations as pert
from repro.data import tasks
from repro.hardware import (ChipFaultError, ChipFarm, ExternalPlant,
                            FaultLog, FaultPolicy, FaultSpec, FaultyChip,
                            SimulatedAnalogChip, simulated_chip_farm)
from repro.training.train_loop import train_mgd

X, Y = tasks.xor_dataset()
BATCH = {"x": X, "y": Y}


def _params(seed=0, sizes=(2, 2, 1)):
    from repro.models.simple import mlp_init
    return mlp_init(jax.random.PRNGKey(seed), sizes)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.fixture(autouse=True)
def _close_plants(monkeypatch):
    """Close every farm/plant a test builds.  These tests lean on GC
    finalizers for teardown, but jitted steps pin their io_callback
    closures (and so the farm) in jax's compilation cache — the
    finalizer never runs and supervisor-pool threads outlive the test,
    which the conftest leak sentinel now fails the suite for.  close()
    is idempotent, so tests that already close explicitly are fine."""
    created = []
    for cls in (ChipFarm, ExternalPlant):
        orig = cls.__init__

        def tracked(self, *a, _orig=orig, **kw):
            _orig(self, *a, **kw)
            created.append(self)

        monkeypatch.setattr(cls, "__init__", tracked)
    yield
    for plant in created:
        plant.close()


#: Fast-failing policy for tests — real backoffs would slow the suite.
def _policy(**kw):
    base = dict(timeout_s=10.0, retries=2, backoff_s=0.001,
                backoff_factor=1.0, backoff_max_s=0.001)
    base.update(kw)
    return FaultPolicy(**base)


class PairDevice:
    """Counter-capable device with a differential probe line; cost is a
    deterministic function of the stored parameters."""

    def __init__(self):
        self.writes = 0
        self.calls = []          # (step, tag) per measure_cost
        self.pair_calls = []     # (step, tag) per measure_pair
        self._params = None

    def set_params(self, params):
        self.writes += 1
        self._params = jax.tree_util.tree_map(
            lambda w: np.asarray(w, np.float32), params)

    def _cost(self, params):
        return float(sum(np.sum(leaf * leaf) for leaf in
                         jax.tree_util.tree_leaves(params)))

    def measure_cost(self, batch, *, step=None, tag=None):
        self.calls.append((step, tag))
        return self._cost(self._params)

    def measure_pair(self, theta, batch, *, step=None, tag=None):
        self.pair_calls.append((step, tag))
        plus = jax.tree_util.tree_map(
            lambda w, t: w + np.asarray(t, np.float32), self._params, theta)
        minus = jax.tree_util.tree_map(
            lambda w, t: w - np.asarray(t, np.float32), self._params, theta)
        return self._cost(plus), self._cost(minus)


class CrashingDevice(PairDevice):
    """Raises from every counter-carrying readout."""

    def measure_cost(self, batch, *, step=None, tag=None):
        raise ValueError("instrument driver crashed")

    def measure_pair(self, theta, batch, *, step=None, tag=None):
        raise ValueError("instrument driver crashed")


def _theta_and_c(device, params, cfg, k):
    """Chip k's perturbation tree and deterministic C̃_k, host-side."""
    theta = jax.tree_util.tree_map(
        np.asarray, pert.generate(
            params, ptype=cfg.ptype, step=jnp.int32(0),
            seed=pp.pod_seed(cfg.seed, k), dtheta=cfg.dtheta,
            tau_p=cfg.tau_p))
    c_plus, c_minus = device.measure_pair(theta, BATCH)
    device.pair_calls.pop()      # undo the bookkeeping of this probe
    return theta, 0.5 * (c_plus - c_minus)


# ---------------------------------------------------------------------------
# Validation + injection determinism
# ---------------------------------------------------------------------------


def test_faultspec_validation():
    with pytest.raises(ValueError, match="sum"):
        FaultSpec(transient=0.7, nan=0.6)
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        FaultSpec(hang=1.5)
    with pytest.raises(ValueError, match="fail_attempts"):
        FaultSpec(fail_attempts=-1)


def test_faultpolicy_validation():
    with pytest.raises(ValueError, match="timeout_s"):
        FaultPolicy(timeout_s=0.0)
    with pytest.raises(ValueError, match="retries"):
        FaultPolicy(retries=-1)
    with pytest.raises(ValueError, match="aggregate"):
        FaultPolicy(aggregate="median")
    with pytest.raises(ValueError, match="trim_frac"):
        FaultPolicy(trim_frac=0.5)


def test_faulty_chip_requires_device_surface():
    with pytest.raises(TypeError, match="set_params"):
        FaultyChip(object())


def test_faulty_chip_zero_spec_passthrough_and_mirroring():
    """An empty FaultSpec is a transparent wrapper: identical readouts,
    identical capability surface (pair line, counters, accuracy)."""
    inner = SimulatedAnalogChip((2, 2, 1), seed=7, sigma_a=0.1,
                                sigma_theta=0.0, sigma_c=1e-3)
    twin = SimulatedAnalogChip((2, 2, 1), seed=7, sigma_a=0.1,
                               sigma_theta=0.0, sigma_c=1e-3)
    chip = FaultyChip(inner, FaultSpec(), seed=1)
    p = _params()
    chip.set_params(p, step=0)
    twin.set_params(p)
    assert chip.measure_cost(BATCH, step=3, tag=1) == \
        twin.measure_cost(BATCH, step=3, tag=1)
    assert callable(getattr(chip, "measure_pair", None))
    assert callable(getattr(chip, "measure_accuracy", None))
    theta = jax.tree_util.tree_map(lambda x: 0.01 * np.ones_like(x), p)
    assert chip.measure_pair(theta, BATCH, step=0, tag=0) == \
        twin.measure_pair(theta, BATCH, step=0, tag=0)
    # a bare 2-method device must NOT grow a pair line through the wrapper
    class TwoMethod:
        def set_params(self, p):
            pass

        def measure_cost(self, b):
            return 0.0
    assert not callable(getattr(FaultyChip(TwoMethod()), "measure_pair",
                                None))


def test_fault_injection_counter_keyed():
    """Two identically-seeded FaultyChips inject the identical fault
    schedule; a different fault seed draws a different one."""
    def schedule(fault_seed):
        log = FaultLog()
        chip = FaultyChip(PairDevice(), FaultSpec(transient=0.3, nan=0.2),
                          seed=fault_seed, log=log)
        chip.set_params(_params())
        out = []
        for step in range(40):
            try:
                c = chip.measure_cost(BATCH, step=step, tag=0)
                out.append("nan" if np.isnan(c) else "ok")
            except Exception:
                out.append("raise")
        return out

    a, b = schedule(11), schedule(11)
    assert a == b
    assert "raise" in a and "nan" in a
    assert schedule(12) != a


# ---------------------------------------------------------------------------
# Retries: counter-stream and trajectory invariance
# ---------------------------------------------------------------------------


def test_retry_preserves_counter_stream_and_trajectory():
    """fail_attempts=1 fails every first attempt; the retry succeeds.
    The inner device must see EXACTLY the clean run's (step, tag)
    stream — no reorders, no duplicates — and the trajectory must be
    bit-identical to the fault-free farm's."""
    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=2)

    def run(faulty):
        inner = [PairDevice(), PairDevice()]
        devices = list(inner)
        if faulty:
            devices[1] = FaultyChip(inner[1], FaultSpec(fail_attempts=1),
                                    seed=0)
        farm = ChipFarm(devices, fault_policy=_policy())
        mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
        p, s = _params(1), mgd.init(_params(1))
        for _ in range(6):
            p, s, m = mgd.step(p, s, BATCH)
            jax.block_until_ready(p)
            assert int(m["n_valid"]) == 2
        return p, inner

    p_clean, inner_clean = run(faulty=False)
    p_fault, inner_fault = run(faulty=True)
    _assert_trees_equal(p_clean, p_fault)
    assert inner_fault[1].pair_calls == inner_clean[1].pair_calls
    assert inner_fault[0].pair_calls == inner_clean[0].pair_calls


def test_exhausted_chip_masked_not_raised():
    """A chip that fails every attempt is masked: fixed-shape NaN costs
    + valid=False, no exception through the callback."""
    devices = [PairDevice(), CrashingDevice(), PairDevice()]
    farm = ChipFarm(devices, fault_policy=_policy(retries=1))
    p = _params()
    thetas = [jax.tree_util.tree_map(lambda x: 0.01 * np.ones_like(x), p)
              for _ in range(3)]
    costs, valid = jax.block_until_ready(
        farm.read_cost_pairs(p, thetas, BATCH, step=0))
    assert list(np.asarray(valid)) == [True, False, True]
    assert np.isnan(np.asarray(costs)[1]).all()
    assert np.isfinite(np.asarray(costs)[[0, 2]]).all()
    assert farm.fault_summary()["events"] > 0
    assert farm.health.chips[1].failures == 1
    assert farm.health.chips[1].attempts_failed == 2


def test_masked_average_is_eta_rescale():
    """With chip 1 dead, the update must be exactly the surviving chip's
    term at the UNCHANGED per-chip coefficient −η/(k·Δθ²) — i.e. the
    η·k_live/k-rescaled masked average."""
    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=3)
    healthy = PairDevice()
    farm = ChipFarm([healthy, CrashingDevice()],
                    fault_policy=_policy(retries=0))
    mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
    p0 = _params(4)
    # expected: θ̃_0 and C̃_0 computed host-side from the deterministic
    # device, applied with coef = −η/(k·Δθ²)·C̃_0
    probe = PairDevice()
    probe.set_params(p0)
    theta0, c0 = _theta_and_c(probe, p0, mgd.config, 0)
    coef = -cfg.eta / (cfg.dtheta ** 2) * c0 / 2
    expected = jax.tree_util.tree_map(
        lambda w, t: np.asarray(w, np.float32)
        + np.float32(coef) * np.asarray(t, np.float32), p0, theta0)
    p1, _, m = mgd.step(p0, mgd.init(p0), BATCH)
    assert int(m["n_valid"]) == 1 and int(m["n_used"]) == 1
    for got, want in zip(jax.tree_util.tree_leaves(p1),
                         jax.tree_util.tree_leaves(expected)):
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


# ---------------------------------------------------------------------------
# Quarantine / readmission
# ---------------------------------------------------------------------------


def test_quarantine_skips_io_then_readmits_with_noise_stream_intact():
    """Chip 1 fails hard for steps 0–5: three exhausted rounds quarantine
    it (steps 3–5 cost NO device I/O), the step-6 re-probe readmits it,
    and its counter-keyed readouts after readmission are identical to a
    never-quarantined twin's."""
    def chips():
        return [SimulatedAnalogChip((2, 2, 1), seed=s, sigma_a=0.1,
                                    sigma_theta=0.0, sigma_c=1e-2)
                for s in (0, 1)]

    inner = chips()
    sick = FaultyChip(inner[1], FaultSpec(transient=1.0, only_steps=(0, 6)),
                      seed=0)
    farm = ChipFarm([inner[0], sick],
                    fault_policy=_policy(retries=0, quarantine_after=3,
                                         reprobe_every=4))
    twin = ChipFarm(chips())
    p = _params()
    thetas = [jax.tree_util.tree_map(lambda x: 0.01 * np.ones_like(x), p)
              for _ in range(2)]

    h = farm.health.chips[1]
    valid_log = []
    for step in range(8):
        _, valid = jax.block_until_ready(
            farm.read_cost_pairs(p, thetas, BATCH, step=step))
        valid_log.append(bool(np.asarray(valid)[1]))
        if step == 2:
            assert h.quarantined and h.next_reprobe == 6
            readouts_at_quarantine = sick.readouts
        if step in (3, 4, 5):    # fast path: no I/O on the sick chip
            assert sick.readouts == readouts_at_quarantine
    assert valid_log == [False] * 6 + [True, True]
    assert not h.quarantined and h.readmissions == 1
    assert farm.fault_summary()["by_kind"]["quarantine"] == 1
    assert farm.fault_summary()["by_kind"]["readmit"] == 1
    # the noise stream is (step, tag)-keyed, not read-count-keyed: the
    # readmitted chip reads exactly what the never-quarantined twin reads
    costs_a, _ = jax.block_until_ready(
        farm.read_cost_pairs(p, thetas, BATCH, step=9))
    costs_b, _ = jax.block_until_ready(
        twin.read_cost_pairs(p, thetas, BATCH, step=9))
    np.testing.assert_array_equal(np.asarray(costs_a)[1],
                                  np.asarray(costs_b)[1])


# ---------------------------------------------------------------------------
# Hangs + default-timeout error context
# ---------------------------------------------------------------------------


def test_hung_chip_stalls_at_most_timeout():
    """A hang at step 1 costs ≤ timeout_s (plus slack), not hang_s, and
    the hung chip is masked while the others answer."""
    inner = [PairDevice(), PairDevice(), PairDevice()]
    hung = FaultyChip(inner[0], FaultSpec(hang=1.0, hang_s=0.6,
                                          only_steps=(1, 2)), seed=0)
    farm = ChipFarm([hung, inner[1], inner[2]],
                    fault_policy=_policy(timeout_s=0.1, retries=0))
    p = _params()
    thetas = [jax.tree_util.tree_map(lambda x: 0.01 * np.ones_like(x), p)
              for _ in range(3)]
    jax.block_until_ready(farm.read_cost_pairs(p, thetas, BATCH, step=0))
    t0 = time.monotonic()
    _, valid = jax.block_until_ready(
        farm.read_cost_pairs(p, thetas, BATCH, step=1))
    stall = time.monotonic() - t0
    assert stall < 0.5, f"hung chip stalled the step {stall:.2f}s"
    assert list(np.asarray(valid)) == [False, True, True]
    assert farm.health.chips[0].timeouts == 1


def test_no_policy_gather_names_the_failing_chip():
    farm = ChipFarm([PairDevice(), CrashingDevice()])
    p = _params()
    thetas = [jax.tree_util.tree_map(lambda x: 0.01 * np.ones_like(x), p)
              for _ in range(2)]
    with pytest.raises(Exception, match="chip 1.*CrashingDevice"):
        jax.block_until_ready(
            farm.read_cost_pairs(p, thetas, BATCH, step=0))


def test_no_policy_write_names_the_failing_chip():
    class BadWriter(PairDevice):
        def set_params(self, params):
            raise OSError("bus error")
    farm = ChipFarm([PairDevice(), BadWriter()])
    with pytest.raises(Exception, match="chip 1.*BadWriter"):
        jax.block_until_ready(
            farm.write_params(_params(), step=jnp.int32(0)))


# ---------------------------------------------------------------------------
# Robust aggregation
# ---------------------------------------------------------------------------


def test_mad_rejects_silent_outlier():
    """A stuck-at chip raises no exception — only the MAD gate over the
    gathered scalars can reject it (n_valid=4, n_used=3)."""
    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=0)
    inner = [PairDevice() for _ in range(4)]
    devices = list(inner)
    devices[2] = FaultyChip(inner[2],
                            FaultSpec(stuck=1.0, stuck_value=1000.0), seed=0)
    farm = ChipFarm(devices, fault_policy=_policy(aggregate="mad",
                                                  mad_threshold=6.0))
    mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
    p, s = _params(), mgd.init(_params())
    p, s, m = mgd.step(p, s, BATCH)
    assert int(m["n_valid"]) == 4
    assert int(m["n_used"]) == 3


def test_trimmed_chip_mask_unit():
    c = jnp.asarray([0.0, 1.0, 2.0, 3.0, 100.0], jnp.float32)
    valid = jnp.ones(5, bool)
    mask = jax.jit(pp._trimmed_chip_mask, static_argnums=2)(
        c, valid, 0.2)
    assert list(np.asarray(mask)) == [False, True, True, True, False]
    # an invalid chip counts as neither kept nor trimmed
    valid = valid.at[1].set(False)
    mask = jax.jit(pp._trimmed_chip_mask, static_argnums=2)(
        c, valid, 0.26)                 # ⌊0.26·4⌋ = 1 trim per side
    assert list(np.asarray(mask)) == [False, False, True, True, False]


# ---------------------------------------------------------------------------
# ExternalPlant (single chip)
# ---------------------------------------------------------------------------


def test_external_plant_retries_transparent():
    """fail_attempts under a retry policy: the read succeeds and equals
    the clean device's counter-keyed readout exactly."""
    inner = SimulatedAnalogChip((2, 2, 1), seed=5, sigma_a=0.1,
                                sigma_theta=0.0, sigma_c=1e-2)
    twin = SimulatedAnalogChip((2, 2, 1), seed=5, sigma_a=0.1,
                               sigma_theta=0.0, sigma_c=1e-2)
    plant = ExternalPlant(FaultyChip(inner, FaultSpec(fail_attempts=1)),
                          fault_policy=_policy())
    clean = ExternalPlant(twin)
    p = _params()
    a = jax.block_until_ready(plant.read_cost(p, BATCH, step=4, tag=1))
    b = jax.block_until_ready(clean.read_cost(p, BATCH, step=4, tag=1))
    assert float(a) == float(b)
    assert plant.fault_summary()["events"] > 0
    assert plant.meta.fault_tolerant


def test_external_plant_exhaustion_and_no_policy_context():
    sick = FaultyChip(PairDevice(), FaultSpec(transient=1.0), seed=0,
                      name="flaky-dut")
    sick_plant = ExternalPlant(sick, fault_policy=_policy(retries=1))
    p = _params()
    with pytest.raises(Exception, match="flaky-dut.*2 attempts"):
        jax.block_until_ready(sick_plant.read_cost(p, BATCH, step=0, tag=0))
    bare = ExternalPlant(CrashingDevice())
    with pytest.raises(Exception, match="CrashingDevice"):
        jax.block_until_ready(bare.read_cost(p, BATCH, step=0, tag=0))


def test_bad_fault_policy_type_rejected():
    with pytest.raises(TypeError, match="FaultPolicy"):
        ExternalPlant(PairDevice(), fault_policy="retry")
    with pytest.raises(TypeError, match="FaultPolicy"):
        ChipFarm([PairDevice()], fault_policy=3)


# ---------------------------------------------------------------------------
# measure_accuracy step forwarding (eval writes on drifting chips)
# ---------------------------------------------------------------------------


def test_measure_accuracy_forwards_step():
    class EvalDevice(PairDevice):
        def __init__(self):
            super().__init__()
            self.write_steps = []
            self.acc_steps = []

        def set_params(self, params, *, step=None):
            self.write_steps.append(step)
            super().set_params(params)

        def measure_accuracy(self, batch, *, step=None):
            self.acc_steps.append(step)
            return 0.5

    devices = [EvalDevice(), EvalDevice()]
    farm = ChipFarm(devices)
    acc = farm.measure_accuracy(_params(), BATCH, step=17)
    assert acc == 0.5
    for d in devices:
        assert d.write_steps[-1] == 17
        assert d.acc_steps == [17]
    # default step=None keeps the historical behaviour (no timestamp)
    farm.measure_accuracy(_params(), BATCH)
    for d in devices:
        assert d.write_steps[-1] is None
        assert d.acc_steps[-1] is None


def test_measure_accuracy_skips_quarantined_chips():
    inner = [SimulatedAnalogChip((2, 2, 1), seed=s, sigma_a=0.1,
                                 sigma_theta=0.0, sigma_c=1e-3)
             for s in (0, 1)]
    sick = FaultyChip(inner[1], FaultSpec(transient=1.0), seed=0)
    farm = ChipFarm([inner[0], sick],
                    fault_policy=_policy(retries=0, quarantine_after=1))
    solo = ChipFarm([SimulatedAnalogChip((2, 2, 1), seed=0, sigma_a=0.1,
                                         sigma_theta=0.0, sigma_c=1e-3)])
    p = _params()
    thetas = [jax.tree_util.tree_map(lambda x: 0.01 * np.ones_like(x), p)
              for _ in range(2)]
    jax.block_until_ready(farm.read_cost_pairs(p, thetas, BATCH, step=0))
    assert farm.health.chips[1].quarantined
    assert farm.measure_accuracy(p, BATCH) == solo.measure_accuracy(p, BATCH)


# ---------------------------------------------------------------------------
# Checkpoint/resume bit-exactness through faults
# ---------------------------------------------------------------------------


def test_farm_resume_bitexact_through_faults(tmp_path):
    """Resume == uninterrupted with transient faults injected at the
    same counter-keyed steps and healed by retries (σ_θ = 0: the only
    live-RNG stream is silent)."""
    def farm():
        return simulated_chip_farm(
            2, (2, 2, 1), base_seed=1, sigma_a=0.1, sigma_theta=0.0,
            sigma_c=1e-3, faults=FaultSpec(transient=0.15), fault_seed=42,
            fault_policy=_policy(retries=3))

    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=4)
    p0 = _params(2)
    sample_fn = lambda i: BATCH                       # noqa: E731

    cont = train_mgd(None, p0, cfg, sample_fn, 16,
                     algorithm="probe_parallel_external", plant=farm(),
                     chunk=4, log=None)
    train_mgd(None, p0, cfg, sample_fn, 8,
              algorithm="probe_parallel_external", plant=farm(),
              chunk=4, log=None, checkpoint_dir=str(tmp_path),
              checkpoint_every=8)
    res = train_mgd(None, p0, cfg, sample_fn, 16,
                    algorithm="probe_parallel_external", plant=farm(),
                    chunk=4, log=None, checkpoint_dir=str(tmp_path))
    assert res.steps_done == 16
    _assert_trees_equal(cont.params, res.params)
    _assert_trees_equal(cont.state, res.state)


def test_clean_path_bit_identical_with_and_without_policy():
    """Arming a policy over healthy chips must not move the trajectory:
    where(True, C̃, 0) ≡ C̃ bitwise and the fori body is unchanged."""
    cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=2)

    def run(policy):
        farm = simulated_chip_farm(3, (2, 2, 1), base_seed=5, sigma_a=0.1,
                                   sigma_theta=0.01, sigma_c=1e-3,
                                   fault_policy=policy)
        mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
        p, s = _params(1), mgd.init(_params(1))
        for _ in range(8):
            p, s, _ = mgd.step(p, s, BATCH)
        return jax.block_until_ready(p)

    _assert_trees_equal(run(None), run(_policy()))
