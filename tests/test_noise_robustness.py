"""Paper §3.5: robustness to cost noise, update noise, activation defects."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalogMGDConfig, MGDConfig, analog_init,
                        build_analog_step, build_mgd_step, make_mgd_epoch,
                        mgd_init, mse)
from repro.core.noise import (defective_sigmoid, ideal_defects,
                              sample_defects)
from repro.data import tasks
from repro.data.pipeline import dataset_sampler
from repro.models.simple import mlp_apply, mlp_init


def _xor_run(cfg, steps=30000, seeds=(1, 2, 3)):
    """Median final cost over param seeds.

    Tolerance rationale: XOR has stuck inits (sigmoid-saturation
    plateaus at cost 0.125) and whether a seed escapes within budget is
    threshold-sensitive; the paper reports medians over 100–1000 inits
    for this reason (§3.1).  Three seeds with a median assert is the
    cheapest flake-resistant version: one stuck init cannot fail the
    test, and one lucky init cannot pass the expected-divergence
    cases."""
    x, y = tasks.xor_dataset()
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])   # noqa: E731
    finals = []
    for seed in seeds:
        params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
        run = make_mgd_epoch(loss_fn, cfg, 2000, dataset_sampler(x, y, 1))
        state = mgd_init(params, cfg)
        for _ in range(steps // 2000):
            params, state, _ = run(params, state)
        finals.append(float(mse(mlp_apply(params, x), y)))
    return sorted(finals)[len(finals) // 2]


def test_cost_noise_below_threshold_still_trains():
    """Fig. 8a: cost noise below the perturbation response (C̃ ≈ |g|·Δθ ≈
    1e-3 here) barely changes training; σ_C = 1e-4 is sub-threshold."""
    base = MGDConfig(dtheta=1e-2, eta=1.0, seed=4)
    noisy = MGDConfig(dtheta=1e-2, eta=1.0, seed=4, cost_noise=1e-4)
    assert _xor_run(base, seeds=(2, 3, 5)) < 0.04
    assert _xor_run(noisy, seeds=(2, 3, 5)) < 0.04


def test_large_cost_noise_breaks_training():
    """Fig. 8a's other end: cost noise ≫ perturbation response stalls it.

    Expected-divergence tolerance: σ_C = 1.0 is ~1000× the C̃ response
    (≈ |g|·Δθ ≈ 1e-3), so the error signal is pure noise and the MEDIAN
    seed must sit far above the 0.04 solved threshold — a single seed
    random-walking below it would be a false pass, which the median over
    (1, 2, 3, 5) absorbs."""
    very_noisy = MGDConfig(dtheta=1e-2, eta=1.0, seed=4, cost_noise=1.0)
    assert _xor_run(very_noisy, steps=20000, seeds=(1, 2, 3, 5)) > 0.04


def test_update_noise_tolerated():
    """Fig. 9: moderate σ_θ update noise still converges."""
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, seed=4, update_noise=0.01)
    assert _xor_run(cfg, seeds=(2, 3, 5)) < 0.05


def test_longer_tau_theta_suppresses_update_noise():
    """Fig. 9b/d mechanism: G accumulates (not averages) over τ_θ, so at
    fixed η the applied update ‖ηG‖ grows ∝ τ_θ while σ_θ·Δθ noise per
    write is constant — the relative noise shrinks ∝ 1/τ_θ.  (The paper's
    end-to-end XOR demonstration of this is plateau-dominated at small
    scale; we assert the magnitude mechanism directly.)"""
    import jax as _jax
    from repro.core import build_mgd_step as _mk, mgd_init as _init
    from repro.core.utils import tree_norm, tree_sub
    x, y = tasks.xor_dataset()
    batch = {"x": x, "y": y}
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])  # noqa: E731
    params = mlp_init(jax.random.PRNGKey(2), (2, 2, 1))

    def update_norm(tau):
        cfg = MGDConfig(dtheta=1e-2, eta=0.05, tau_theta=tau, seed=4)
        step = _jax.jit(_mk(loss_fn, cfg))
        st = _init(params, cfg)
        p = params
        norms = []
        for i in range(tau * 3):
            p_prev, (p, st, m) = p, step(p, st, batch)
            if float(m["updated"]):
                norms.append(float(tree_norm(tree_sub(p, p_prev))))
        return sum(norms) / len(norms)

    u1, u100 = update_norm(1), update_norm(100)
    assert u100 > 10 * u1, (u1, u100)


def test_activation_defects():
    """Fig. 10: σ_a = 0 is exactly sigmoid; moderate defects still train."""
    a = jnp.linspace(-3, 3, 64)
    np.testing.assert_allclose(
        np.asarray(defective_sigmoid(a, ideal_defects(1))),
        np.asarray(jax.nn.sigmoid(a)), rtol=1e-6)

    defects = [sample_defects(0, 2, 0.15), sample_defects(1, 1, 0.15)]
    x, y = tasks.xor_dataset()
    params = mlp_init(jax.random.PRNGKey(1), (2, 2, 1))
    loss_fn = lambda p, b: mse(                                # noqa: E731
        mlp_apply(p, b["x"], defects=defects), b["y"])
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, seed=4)
    run = make_mgd_epoch(loss_fn, cfg, 2000, dataset_sampler(x, y, 1))
    state = mgd_init(params, cfg)
    for _ in range(20):
        params, state, _ = run(params, state)
    final = float(mse(mlp_apply(params, x, defects=defects), y))
    assert final < 0.06, final


def test_analog_algorithm_trains_quadratic():
    """Algorithm 2 (continuous): converges inside its stability regime."""
    target = {"w": jnp.array([1.0, -2.0, 3.0])}

    def loss(p, batch):
        return jnp.sum((p["w"] - target["w"]) ** 2)

    params = {"w": jnp.zeros(3)}
    cfg = AnalogMGDConfig(dtheta=1e-2, eta=1e-3, tau_theta=10.0,
                          tau_hp=100.0)
    state = analog_init(params, cfg)
    step = jax.jit(build_analog_step(loss, cfg))
    for _ in range(20000):
        params, state, m = step(params, state, None)
    assert float(loss(params, None)) < 0.5
