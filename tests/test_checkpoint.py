"""Fault tolerance: atomic checkpointing, deterministic resume, retention."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MGDConfig, build_mgd_step, mgd_init, mse
from repro.data import tasks
from repro.models.simple import mlp_apply, mlp_init
from repro.training import checkpoint as ckpt


def _steps(params, state, step_fn, batch, n):
    for _ in range(n):
        params, state, _ = step_fn(params, state, batch)
    return params, state


def test_save_restore_roundtrip(tmp_path):
    params = mlp_init(jax.random.PRNGKey(0), (4, 3, 2))
    path = ckpt.save(str(tmp_path), 7, params, extra={"c0": 1.5})
    assert os.path.isdir(path)
    restored, extra, step = ckpt.restore(str(tmp_path), params)
    assert step == 7 and extra["c0"] == 1.5
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_deterministic_resume(tmp_path):
    """Train 10+10 steps vs train 10, checkpoint, restore, train 10 —
    identical parameters (counter-keyed perturbations make the trajectory
    a pure function of the global step)."""
    x, y = tasks.xor_dataset()
    batch = {"x": x, "y": y}
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])   # noqa: E731
    cfg = MGDConfig(dtheta=1e-2, eta=1.0, seed=9)
    step_fn = jax.jit(build_mgd_step(loss_fn, cfg))
    p0 = mlp_init(jax.random.PRNGKey(3), (2, 2, 1))

    # continuous run
    p_cont, s_cont = _steps(p0, mgd_init(p0, cfg), step_fn, batch, 20)

    # interrupted run
    p_half, s_half = _steps(p0, mgd_init(p0, cfg), step_fn, batch, 10)
    ckpt.save(str(tmp_path), 10, p_half, extra={"c0": float(s_half.c0)})
    p_rest, extra, step = ckpt.restore(str(tmp_path), p_half)
    state = mgd_init(p_rest, cfg)._replace(
        step=jnp.asarray(step, jnp.int32),
        c0=jnp.asarray(extra["c0"], jnp.float32))
    p_resumed, _ = _steps(p_rest, state, step_fn, batch, 10)

    for a, b in zip(jax.tree_util.tree_leaves(p_cont),
                    jax.tree_util.tree_leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_full_state_resume_matches_uninterrupted(tmp_path):
    """Resume must restore the FULL MGDState — gradient accumulator G and
    momentum — not just step/C₀.  Checkpoint mid-τ_θ-window (step 10 with
    τ_θ = 4 → two probes already accumulated, momentum warm) so a resume
    that dropped the buffers would visibly diverge."""
    from repro.training.train_loop import train_mgd

    x, y = tasks.xor_dataset()
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])   # noqa: E731
    sample_fn = lambda i: {"x": x, "y": y}                     # noqa: E731
    cfg = MGDConfig(dtheta=1e-2, eta=0.5, tau_theta=4, momentum=0.9,
                    seed=2)
    p0 = mlp_init(jax.random.PRNGKey(3), (2, 2, 1))

    cont = train_mgd(loss_fn, p0, cfg, sample_fn, 40, chunk=10, log=None)

    train_mgd(loss_fn, p0, cfg, sample_fn, 10, chunk=10, log=None,
              checkpoint_dir=str(tmp_path), checkpoint_every=10)
    assert ckpt.latest_step(str(tmp_path)) == 10
    resumed = train_mgd(loss_fn, p0, cfg, sample_fn, 40, chunk=10,
                        log=None, checkpoint_dir=str(tmp_path),
                        checkpoint_every=0)
    assert resumed.steps_done == 40

    for a, b in zip(jax.tree_util.tree_leaves(cont.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    # the restored state buffers keep evolving identically too
    for a, b in zip(jax.tree_util.tree_leaves(cont.state.g),
                    jax.tree_util.tree_leaves(resumed.state.g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_legacy_params_only_checkpoint_still_resumes(tmp_path):
    """Pre-full-state checkpoints (params-only leaf set) restore with a
    buffer reset instead of crashing."""
    from repro.training.train_loop import train_mgd

    x, y = tasks.xor_dataset()
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])   # noqa: E731
    sample_fn = lambda i: {"x": x, "y": y}                     # noqa: E731
    cfg = MGDConfig(dtheta=1e-2, eta=0.5, tau_theta=4, seed=2)
    p0 = mlp_init(jax.random.PRNGKey(3), (2, 2, 1))
    ckpt.save(str(tmp_path), 8, p0, extra={"c0": 0.25})

    logs = []
    res = train_mgd(loss_fn, p0, cfg, sample_fn, 16, chunk=8,
                    log=logs.append, checkpoint_dir=str(tmp_path))
    assert res.steps_done == 16
    assert any("legacy" in str(m) for m in logs)


def test_retention_keeps_latest(tmp_path):
    params = {"w": jnp.ones(3)}
    for s in range(6):
        ckpt.save(str(tmp_path), s, params, keep=3)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_no_tmp_dirs_left(tmp_path):
    params = {"w": jnp.ones(3)}
    ckpt.save(str(tmp_path), 0, params)
    leftovers = [n for n in os.listdir(tmp_path) if n.startswith(".tmp")]
    assert not leftovers


def test_restore_rejects_structure_mismatch(tmp_path):
    params = mlp_init(jax.random.PRNGKey(0), (4, 3, 2))
    ckpt.save(str(tmp_path), 0, params)
    other = mlp_init(jax.random.PRNGKey(0), (4, 3))  # fewer leaves
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), other)
