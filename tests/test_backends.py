"""Farm execution backends: parity, pipelining, lifecycle, faults.

The backend contract (``hardware/backend/base.py``) promises that a
backend only moves WHERE a chip transaction runs — never what it
computes.  These tests hold every backend to that:

* serial / thread / process farms walk the bit-identical trajectory
  (σ_θ = 0 so the only RNG streams are counter-keyed), pipelined or not;
* checkpoint/resume stays bit-exact through a double-buffered boundary
  (the fence drains in-flight writes; values never depended on the
  schedule in the first place);
* the PR-6 fault suite holds under the process backend: retry-healed
  runs are bit-exact vs fault-free ones, quarantine/readmission works
  with worker-local fault events shipped back host-side, and a hung
  worker is KILLED and respawned within the policy timeout;
* farms are context managers with idempotent ``close()`` and leak
  neither threads nor worker processes across many builds;
* ``DeviceSpec`` / the cluster wire protocol round-trip devices
  faithfully.
"""
import multiprocessing
import threading
import time

import jax
import numpy as np
import pytest

import repro
from repro.api import DriverConfig
from repro.data import tasks
from repro.hardware import (ChipFarm, DeviceSpec, FaultPolicy, FaultSpec,
                            SimulatedAnalogChip, simulated_chip_farm)
from repro.hardware.backend import (ClusterStubBackend, ProcessBackend,
                                    SerialBackend, loopback_transport,
                                    make_backend)
from repro.models.simple import mlp_init
from repro.training.train_loop import train_mgd

X, Y = tasks.xor_dataset()
BATCH = {"x": X, "y": Y}
SIZES = (2, 2, 1)


def _params(seed=1):
    return mlp_init(jax.random.PRNGKey(seed), SIZES)


def _policy(**kw):
    base = dict(timeout_s=10.0, retries=2, backoff_s=0.001,
                backoff_factor=1.0, backoff_max_s=0.001)
    base.update(kw)
    return FaultPolicy(**base)


def _assert_trees_equal(a, b, msg=""):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


def _thetas(p, k):
    return [jax.tree_util.tree_map(lambda x: 0.01 * np.ones_like(x), p)
            for _ in range(k)]


def _trajectory(backend, pipeline=False, n=6, **farm_kw):
    kw = dict(base_seed=5, sigma_a=0.1, sigma_theta=0.0, sigma_c=1e-3)
    kw.update(farm_kw)
    with simulated_chip_farm(3, SIZES, backend=backend,
                             pipeline=pipeline, **kw) as farm:
        cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=2)
        mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
        p, s = _params(), mgd.init(_params())
        cts = []
        for _ in range(n):
            p, s, m = mgd.step(p, s, BATCH)
            cts.append(np.asarray(m["c_tilde"]))
        jax.block_until_ready((p, s))
        farm.fence()
        writes = farm.total_writes
    return p, np.array(cts), writes


# ---------------------------------------------------------------------------
# Cross-backend bit-exactness
# ---------------------------------------------------------------------------


def test_backends_bit_exact_parity():
    """serial, thread and process farms (pipelined or not) produce the
    bit-identical cost stream, trajectory AND device write counts — a
    backend moves execution, nothing else."""
    ref_p, ref_ct, ref_w = _trajectory("serial")
    for backend in ("thread", "process"):
        for pipeline in (False, True):
            p, ct, w = _trajectory(backend, pipeline)
            tag = f"{backend} pipeline={pipeline}"
            np.testing.assert_array_equal(ref_ct, ct, err_msg=tag)
            _assert_trees_equal(ref_p, p, tag)
            assert w == ref_w, tag


def test_backend_instance_passthrough_and_unknown_name():
    be = SerialBackend()
    assert make_backend(be) is be
    with pytest.raises(ValueError, match="unknown farm backend"):
        make_backend("quantum")
    with pytest.raises(TypeError, match="name or FarmBackend"):
        make_backend(42)


# ---------------------------------------------------------------------------
# Double-buffered pipeline: fence + resume
# ---------------------------------------------------------------------------


def test_pipeline_resume_bitexact(tmp_path):
    """Checkpoint/resume through a double-buffered farm == the
    uninterrupted non-pipelined run: the fence drains in-flight writes
    at the boundary, and counter-keyed noise makes the overlap schedule
    value-invisible."""
    def run(steps, pipeline, ckpt_dir=None, ckpt_every=0):
        farm = simulated_chip_farm(2, SIZES, base_seed=1, sigma_a=0.1,
                                   sigma_theta=0.0, sigma_c=1e-3,
                                   backend="thread", pipeline=pipeline)
        cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=4)
        res = train_mgd(None, _params(2), cfg, lambda i: BATCH, steps,
                        algorithm="probe_parallel_external", plant=farm,
                        chunk=4, log=None,
                        checkpoint_dir=ckpt_dir, checkpoint_every=ckpt_every)
        farm.close()
        return res

    cont = run(16, pipeline=False)
    run(8, pipeline=True, ckpt_dir=str(tmp_path), ckpt_every=8)
    res = run(16, pipeline=True, ckpt_dir=str(tmp_path))
    assert res.steps_done == 16
    _assert_trees_equal(cont.params, res.params)
    _assert_trees_equal(cont.state, res.state)


def test_pipeline_stats_reports_utilization():
    with simulated_chip_farm(2, SIZES, base_seed=0, py_busy_ms=2.0,
                             backend="thread", pipeline=True) as farm:
        cfg = DriverConfig(dtheta=1e-2, eta=0.5, mode="central", seed=0)
        mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
        p, s = _params(), mgd.init(_params())
        for _ in range(4):
            p, s, _ = mgd.step(p, s, BATCH)
        jax.block_until_ready((p, s))
        farm.fence()
        stats = farm.pipeline_stats()
    assert stats["pipeline"] is True and stats["chips"] == 2
    assert stats["busy_s"] > 0 and stats["wall_s"] > 0
    assert 0.0 < stats["utilization"] <= 1.2   # clock-skew slack


# ---------------------------------------------------------------------------
# PR-6 fault suite under the process backend
# ---------------------------------------------------------------------------


def test_process_retry_heal_bitexact():
    """fail_attempts=1 fails every first attempt in the WORKER process;
    the host retry re-runs the transaction against the respawn-free
    worker and the trajectory stays bit-identical to the fault-free
    farm's (the PR-6 abs=0.0 gate, now across a process boundary)."""
    def run(faults):
        p, ct, _ = _trajectory("process", faults=faults, fault_seed=42,
                               fault_policy=_policy())
        return p, ct

    p_clean, ct_clean = run(None)
    p_fault, ct_fault = run(FaultSpec(fail_attempts=1))
    np.testing.assert_array_equal(ct_clean, ct_fault)
    _assert_trees_equal(p_clean, p_fault)


def test_process_quarantine_readmits_and_ships_events():
    """Chip 1 fails hard for steps 0–5 inside its worker process: the
    host-side health registry quarantines it after 3 exhausted rounds,
    the step-6 re-probe readmits it, and the injected-fault events
    recorded worker-side arrive in the host FaultLog."""
    farm = simulated_chip_farm(
        2, SIZES, base_seed=0, sigma_theta=0.0, sigma_c=1e-2,
        faults=[None, FaultSpec(transient=1.0, only_steps=(0, 6))],
        fault_seed=7, backend="process",
        fault_policy=_policy(retries=0, quarantine_after=3,
                             reprobe_every=4))
    twin = simulated_chip_farm(2, SIZES, base_seed=0, sigma_theta=0.0,
                               sigma_c=1e-2, backend="serial")
    p = _params()
    h = farm.health.chips[1]
    valid_log = []
    for step in range(8):
        _, valid = jax.block_until_ready(
            farm.read_cost_pairs(p, _thetas(p, 2), BATCH, step=step))
        valid_log.append(bool(np.asarray(valid)[1]))
        if step == 2:
            assert h.quarantined and h.next_reprobe == 6
    assert valid_log == [False] * 6 + [True, True]
    assert not h.quarantined and h.readmissions == 1
    by_kind = farm.fault_summary()["by_kind"]
    assert by_kind["quarantine"] == 1 and by_kind["readmit"] == 1
    # worker-local injected-fault events shipped back with the replies:
    # steps 0-2 probe and fail; 3-5 are quarantine-skipped (no I/O)
    assert by_kind.get("inject-transient", 0) == 3
    # the readmitted chip's counter-keyed stream is untouched: it reads
    # exactly what a never-faulted serial twin reads
    costs_a, _ = jax.block_until_ready(
        farm.read_cost_pairs(p, _thetas(p, 2), BATCH, step=9))
    costs_b, _ = jax.block_until_ready(
        twin.read_cost_pairs(p, _thetas(p, 2), BATCH, step=9))
    np.testing.assert_array_equal(np.asarray(costs_a)[1],
                                  np.asarray(costs_b)[1])
    farm.close()
    twin.close()


def test_process_hang_is_killed_and_respawned():
    """A hang inside a worker process stalls the step by ~timeout_s, not
    hang_s: the worker is KILLED (not parked like the thread backend's
    zombie), and the next round runs against a respawned worker."""
    farm = simulated_chip_farm(
        2, SIZES, base_seed=0, sigma_theta=0.0, sigma_c=1e-3,
        faults=[FaultSpec(hang=1.0, hang_s=30.0, only_steps=(1, 2)), None],
        fault_seed=3, backend="process",
        fault_policy=_policy(timeout_s=0.3, retries=0))
    p = _params()
    _, valid = jax.block_until_ready(
        farm.read_cost_pairs(p, _thetas(p, 2), BATCH, step=0))
    assert list(np.asarray(valid)) == [True, True]
    t0 = time.monotonic()
    _, valid = jax.block_until_ready(
        farm.read_cost_pairs(p, _thetas(p, 2), BATCH, step=1))
    stall = time.monotonic() - t0
    assert stall < 5.0, f"hung worker stalled the step {stall:.2f}s"
    assert list(np.asarray(valid)) == [False, True]
    assert farm.health.chips[0].timeouts == 1
    # step 3 is outside the hang window: the respawned worker answers
    _, valid = jax.block_until_ready(
        farm.read_cost_pairs(p, _thetas(p, 2), BATCH, step=3))
    assert list(np.asarray(valid)) == [True, True]
    farm.close()


# ---------------------------------------------------------------------------
# DeviceSpec + spec-only backends
# ---------------------------------------------------------------------------


def test_device_spec_builds_and_validates():
    spec = DeviceSpec(SimulatedAnalogChip, (SIZES,), dict(seed=3))
    device = spec.build()
    assert isinstance(device, SimulatedAnalogChip)
    assert spec.display_name == "SimulatedAnalogChip"
    faulty = DeviceSpec(SimulatedAnalogChip, (SIZES,), dict(seed=3),
                        fault=FaultSpec(transient=0.5), fault_seed=9)
    assert faulty.display_name == "faulty:SimulatedAnalogChip:9"
    assert faulty.build().name == faulty.display_name
    with pytest.raises(TypeError, match="set_params"):
        DeviceSpec(dict)
    with pytest.raises(TypeError, match="FaultSpec"):
        DeviceSpec(SimulatedAnalogChip, (SIZES,), fault="flaky")


def test_process_backend_rejects_live_instances():
    device = SimulatedAnalogChip(SIZES, seed=0)
    with pytest.raises(TypeError, match="backend='thread'"):
        ChipFarm([device], backend="process")


# ---------------------------------------------------------------------------
# Cluster stub: wire protocol
# ---------------------------------------------------------------------------


def test_cluster_stub_refuses_to_start_without_transport():
    specs = [DeviceSpec(SimulatedAnalogChip, (SIZES,), dict(seed=0))]
    with pytest.raises(NotImplementedError, match="transport"):
        ChipFarm(specs, backend="cluster")


def test_cluster_loopback_matches_serial():
    """The full wire round trip (pickle request → node dispatch → pickle
    reply) reproduces the serial farm's costs bit-for-bit."""
    def specs():
        return [DeviceSpec(SimulatedAnalogChip, (SIZES,),
                           dict(seed=s, sigma_theta=0.0, sigma_c=1e-3))
                for s in (0, 1)]

    be = ClusterStubBackend(transport=loopback_transport(specs()))
    remote = ChipFarm(specs(), backend=be)
    local = ChipFarm(specs(), backend="serial")
    p = _params()
    for step in range(3):
        ca, _ = jax.block_until_ready(
            remote.read_cost_pairs(p, _thetas(p, 2), BATCH, step=step))
        cb, _ = jax.block_until_ready(
            local.read_cost_pairs(p, _thetas(p, 2), BATCH, step=step))
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    remote.close()
    local.close()


def test_cluster_backend_rejects_live_instances():
    be = ClusterStubBackend(transport=lambda i, req: req)
    with pytest.raises(TypeError, match="DeviceSpec"):
        ChipFarm([SimulatedAnalogChip(SIZES, seed=0)], backend=be)


# ---------------------------------------------------------------------------
# Lifecycle hygiene: context manager, idempotent close, no leaks
# ---------------------------------------------------------------------------


def test_farm_context_manager_and_idempotent_close():
    with simulated_chip_farm(2, SIZES, base_seed=0,
                             backend="thread") as farm:
        p = _params()
        jax.block_until_ready(
            farm.read_cost_pairs(p, _thetas(p, 2), BATCH, step=0))
        assert not farm.closed
    assert farm.closed
    farm.close()                                     # second close: no-op
    with pytest.raises(Exception, match="shut down"):
        jax.block_until_ready(
            farm.read_cost_pairs(p, _thetas(p, 2), BATCH, step=1))


def _settled(predicate, timeout_s=10.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(0.05)
    return predicate()


def test_many_farms_leak_no_threads_or_processes():
    """Sweeps build many farms per process; every close() must reclaim
    the backend's runner threads and worker processes."""
    # settle anything a previous test left draining
    assert _settled(lambda: not multiprocessing.active_children())
    before = threading.active_count()
    p = _params()
    for backend in ("thread", "process", "thread"):
        for _ in range(3):
            with simulated_chip_farm(2, SIZES, base_seed=0,
                                     backend=backend) as farm:
                jax.block_until_ready(farm.read_cost_pairs(
                    p, _thetas(p, 2), BATCH, step=0))
    assert _settled(lambda: not multiprocessing.active_children()), \
        f"leaked worker processes: {multiprocessing.active_children()}"
    assert _settled(lambda: threading.active_count() <= before + 1), \
        f"leaked threads: {threading.active_count()} vs {before} before"


# ---------------------------------------------------------------------------
# py_busy_ms: the GIL-holding demonstration device
# ---------------------------------------------------------------------------


def test_py_busy_ms_holds_for_at_least_the_budget():
    chip = SimulatedAnalogChip(SIZES, seed=0, py_busy_ms=20.0)
    chip.set_params(_params())
    t0 = time.perf_counter()
    chip.measure_cost(BATCH)
    elapsed = time.perf_counter() - t0
    assert elapsed >= 0.015, f"busy-loop returned in {elapsed * 1e3:.1f}ms"
