"""mgdlint rule suite: every rule must fire on its bad fixture, pass
its good fixture, be silenced by a reasoned waiver, and round-trip
through the baseline.  Plus engine-level checks (waiver parsing,
MGD000, alias resolution, CLI exit codes) and targeted cases for the
trickier analyses (MGD001 reachability, MGD004 taint laundering).
"""
import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

mgdlint = pytest.importorskip(
    "mgdlint", reason="tools/ not on sys.path (see tests/conftest.py)")
from mgdlint import baseline as baseline_mod  # noqa: E402
from mgdlint.cli import self_test  # noqa: E402
from mgdlint.registry import RULES, all_rules, run_lint  # noqa: E402
from mgdlint.walker import SourceFile  # noqa: E402

REPO = pathlib.Path(__file__).resolve().parent.parent
ALL_CODES = sorted(RULES)


def lint_snippet(tmp_path, rel, text, select=None):
    target = tmp_path / rel
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(textwrap.dedent(text))
    return run_lint([target], tmp_path, select=select)


# ---------------------------------------------------------------------------
# every rule: fixture pairs, waiver, baseline
# ---------------------------------------------------------------------------


def test_six_rules_registered():
    assert ALL_CODES == ["MGD001", "MGD002", "MGD003",
                         "MGD004", "MGD005", "MGD006"]
    for rule in all_rules():
        assert rule.fixture_path and rule.fixture_bad \
            and rule.fixture_good, f"{rule.code}: missing fixtures"
        assert rule.rationale, f"{rule.code}: missing rationale"


@pytest.mark.parametrize("code", ALL_CODES)
def test_rule_fires_on_bad_fixture(tmp_path, code):
    rule = RULES[code]()
    res = lint_snippet(tmp_path, rule.fixture_path, rule.fixture_bad,
                       select=[code])
    assert any(f.code == code for f in res.findings), \
        f"{code} did not fire on its bad fixture"


@pytest.mark.parametrize("code", ALL_CODES)
def test_rule_passes_good_fixture(tmp_path, code):
    rule = RULES[code]()
    res = lint_snippet(tmp_path, rule.fixture_path, rule.fixture_good,
                       select=[code])
    assert not res.findings, [f.format() for f in res.findings]
    assert not res.parse_errors


@pytest.mark.parametrize("code", ALL_CODES)
def test_rule_out_of_scope_path_is_ignored(tmp_path, code):
    rule = RULES[code]()
    res = lint_snippet(tmp_path, "scripts/elsewhere.py",
                       rule.fixture_bad, select=[code])
    assert not res.findings, \
        f"{code} fired outside its path scope"


@pytest.mark.parametrize("code", ALL_CODES)
def test_waiver_suppresses_rule(tmp_path, code):
    rule = RULES[code]()
    res = lint_snippet(tmp_path, rule.fixture_path, rule.fixture_bad,
                       select=[code])
    lines = textwrap.dedent(rule.fixture_bad).splitlines()
    for idx in sorted({f.line - 1 for f in res.findings}):
        lines[idx] += (f"  # mgdlint: disable={code} "
                       f"(fixture waiver for the test suite)")
    res2 = lint_snippet(tmp_path, rule.fixture_path,
                        "\n".join(lines) + "\n", select=[code])
    assert not res2.findings, [f.format() for f in res2.findings]
    assert res2.waived, f"{code}: waiver not recorded as waived"


@pytest.mark.parametrize("code", ALL_CODES)
def test_baseline_roundtrip_grandfathers(tmp_path, code):
    rule = RULES[code]()
    res = lint_snippet(tmp_path, rule.fixture_path, rule.fixture_bad,
                       select=[code])
    bl = tmp_path / "baseline.json"
    baseline_mod.save(bl, res.findings)
    entries = baseline_mod.load(bl)
    new, grandfathered, stale = baseline_mod.split(res.findings, entries)
    assert not new and not stale
    assert len(grandfathered) == len(res.findings)


def test_baseline_is_multiset_not_set(tmp_path):
    """Two identical offending lines need two entries — a fix cannot
    hide behind a sibling's grandfathering."""
    res = lint_snippet(
        tmp_path, "src/repro/core/m.py",
        """\
        import numpy as np
        def f():
            a = np.random.rand(3)
            b = np.random.rand(3)
        """, select=["MGD002"])
    assert len(res.findings) == 2
    # baseline only one of them: the twin must still be NEW
    entries = [dict(zip(baseline_mod.KEYS,
                        res.findings[0].fingerprint()))]
    new, grandfathered, _ = baseline_mod.split(res.findings, entries)
    assert len(new) == 1 and len(grandfathered) == 1


def test_stale_baseline_entries_are_reported(tmp_path):
    entries = [{"rule": "MGD002", "path": "src/repro/core/gone.py",
                "symbol": "f", "snippet": "x = np.random.rand(3)"}]
    new, grandfathered, stale = baseline_mod.split([], entries)
    assert not new and not grandfathered and stale == entries


# ---------------------------------------------------------------------------
# waiver syntax / MGD000
# ---------------------------------------------------------------------------


def test_reasonless_waiver_is_mgd000(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/core/m.py",
        "import numpy as np\n"
        "x = np.random.rand(3)  # mgdlint: disable=MGD002\n")
    assert any(f.code == "MGD000" for f in res.findings)
    # and the reason-less waiver does NOT suppress the finding
    assert any(f.code == "MGD002" for f in res.findings)


def test_unknown_code_waiver_is_mgd000(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/core/m.py",
        "x = 1  # mgdlint: " + "disable=BOGUS99 (nope)\n")
    assert any(f.code == "MGD000" for f in res.findings)


def test_preceding_comment_line_waiver(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/core/m.py",
        """\
        import numpy as np
        # mgdlint: disable=MGD002 (legacy notebook parity check)
        x = np.random.rand(3)
        """)
    assert not [f for f in res.findings if f.code == "MGD002"]
    assert res.waived


def test_waiver_for_other_code_does_not_suppress(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/core/m.py",
        "import numpy as np\n"
        "x = np.random.rand(3)  # mgdlint: disable=MGD003 (wrong rule)\n")
    assert any(f.code == "MGD002" for f in res.findings)


# ---------------------------------------------------------------------------
# targeted rule semantics
# ---------------------------------------------------------------------------


def test_mgd001_alias_resolution(tmp_path):
    """``from jax import numpy as xnp`` must still be caught."""
    res = lint_snippet(
        tmp_path, "src/repro/hardware/m.py",
        """\
        from jax import numpy as xnp

        def _host_read(params):
            return xnp.mean(params)
        """, select=["MGD001"])
    assert len(res.findings) == 1


def test_mgd001_function_as_value_reachability(tmp_path):
    """external.py idiom: the host fn passes ``self._read_txn`` as a
    VALUE into a guard wrapper — the txn body is still host-side."""
    res = lint_snippet(
        tmp_path, "src/repro/hardware/m.py",
        """\
        import jax.numpy as jnp

        class P:
            def _host_read(self, p):
                return self._guarded(self._read_txn, (p,))

            def _read_txn(self, p):
                return jnp.mean(p)

            def traced_helper(self, p):
                return jnp.mean(p)   # NOT reachable from the callback
        """, select=["MGD001"])
    assert len(res.findings) == 1
    assert res.findings[0].symbol == "P._read_txn"


def test_mgd001_tree_util_allowed(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/hardware/backend/m.py",
        """\
        import jax
        import numpy as np

        def pack(tree):
            return jax.tree_util.tree_map(np.asarray, tree)
        """, select=["MGD001"])
    assert not res.findings


def test_mgd002_counter_keyed_generators_allowed(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/core/m.py",
        """\
        import numpy as np

        def noise(seed, step, tag, shape):
            rng = np.random.default_rng((seed, step, tag))
            return rng.normal(size=shape)
        """, select=["MGD002"])
    assert not res.findings


def test_mgd002_wall_clock_seed_flagged(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/core/m.py",
        """\
        import time
        import numpy as np

        def make_rng():
            return np.random.default_rng(int(time.time()))
        """, select=["MGD002"])
    assert len(res.findings) == 1
    assert "wall-clock" in res.findings[0].message


def test_mgd003_multiline_result_call_caught(tmp_path):
    """The case the old regex missed: the closing paren on another
    line, or the future aliased first."""
    res = lint_snippet(
        tmp_path, "src/repro/hardware/m.py",
        """\
        def gather(futures):
            fut = futures[0]
            return fut.result(
            )
        """, select=["MGD003"])
    assert len(res.findings) == 1


def test_mgd004_dtype_access_is_not_tainted(tmp_path):
    """The real mgd.py idiom: branching on leaf DTYPES is static and
    legal; branching on leaf VALUES is not."""
    res = lint_snippet(
        tmp_path, "src/repro/core/m.py",
        """\
        import jax
        import jax.numpy as jnp

        def build_step(cfg):
            def step(params, batch):
                leaves = jax.tree_util.tree_leaves(params)
                if all(leaf.dtype == jnp.float32 for leaf in leaves):
                    out = jnp.zeros(())
                else:
                    out = jnp.ones(())
                return out
            return step
        """, select=["MGD004"])
    assert not res.findings


def test_mgd004_builder_level_config_math_allowed(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/core/m.py",
        """\
        def build_step(cfg):
            eta = float(cfg.eta)

            def step(params, batch):
                return params

            return step
        """, select=["MGD004"])
    assert not res.findings


def test_mgd005_locked_mutation_passes_unlocked_fails(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/hardware/backend/m.py",
        """\
        import threading

        class B:
            def __init__(self):
                self._lock = threading.Lock()
                self._busy = 0.0
                self._n = 0

            def good(self, d):
                with self._lock:
                    self._busy += d

            def bad(self, d):
                self._n += 1
        """, select=["MGD005"])
    assert len(res.findings) == 1
    assert res.findings[0].symbol == "B.bad"


def test_mgd005_faultlog_bypass_flagged(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/hardware/backend/m.py",
        """\
        def leak(fault_log, ev):
            fault_log.events.append(ev)
        """, select=["MGD005"])
    assert len(res.findings) == 1


def test_mgd006_only_fence_binding_functions_checked(tmp_path):
    """train_backprop never touches a plant: eval with no fence is fine
    there, but a fence-binding loop must fence first."""
    res = lint_snippet(
        tmp_path, "src/repro/training/m.py",
        """\
        def train_backprop(params, eval_fn):
            return eval_fn(params)

        def train_mgd(plant, params, eval_fn):
            fence = getattr(plant, "fence", lambda: None)
            return eval_fn(params)
        """, select=["MGD006"])
    assert len(res.findings) == 1
    assert res.findings[0].symbol == "train_mgd"


def test_mgd006_fence_in_outer_block_counts(tmp_path):
    res = lint_snippet(
        tmp_path, "src/repro/training/m.py",
        """\
        def train_mgd(plant, params, eval_fn, steps):
            fence = getattr(plant, "fence", lambda: None)
            for step in range(steps):
                fence()
                if step % 10 == 0:
                    metric = eval_fn(params)
            return params
        """, select=["MGD006"])
    assert not res.findings


def test_mgd006_unfenced_param_swap_flagged(tmp_path):
    """The PR 10 extension: a serving-tier store.publish in
    fence-binding code is a sync boundary — publishing with plant
    writes in flight serves a tree the device never held."""
    res = lint_snippet(
        tmp_path, "src/repro/serving/m.py",
        """\
        class Trimmer:
            def publish_bad(self):
                self.fence
                return self._store.publish(self._params)

            def publish_good(self):
                self.fence()
                return self._store.publish(self._params)
        """, select=["MGD006"])
    assert len(res.findings) == 1
    assert res.findings[0].symbol == "Trimmer.publish_bad"
    assert "parameter swap" in res.findings[0].message


def test_mgd006_non_store_publish_not_flagged(tmp_path):
    """publish on something that is not a parameter store (e.g. a
    message bus) is not a swap boundary."""
    res = lint_snippet(
        tmp_path, "src/repro/serving/m.py",
        """\
        def announce(bus, fence, msg):
            bus.publish(msg)
        """, select=["MGD006"])
    assert not res.findings


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _cli(args, cwd):
    env = {"PYTHONPATH": str(REPO / "tools"),
           "PATH": "/usr/bin:/bin:/usr/local/bin"}
    return subprocess.run([sys.executable, "-m", "mgdlint"] + args,
                          cwd=cwd, env=env, capture_output=True,
                          text=True, timeout=120)


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "src/repro/core/m.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import numpy as np\nx = np.random.rand(3)\n")
    r = _cli(["src", "--root", "."], cwd=tmp_path)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "MGD002" in r.stdout
    # grandfather it, then the same tree passes
    r = _cli(["src", "--root", ".", "--baseline", "bl.json",
              "--write-baseline"], cwd=tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    r = _cli(["src", "--root", ".", "--baseline", "bl.json"], cwd=tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "grandfathered" in r.stdout
    # stale entries only fail under --strict
    bad.write_text("x = 1\n")
    r = _cli(["src", "--root", ".", "--baseline", "bl.json"], cwd=tmp_path)
    assert r.returncode == 0
    r = _cli(["src", "--root", ".", "--baseline", "bl.json", "--strict"],
             cwd=tmp_path)
    assert r.returncode == 1
    # usage errors are distinct from lint failures
    r = _cli(["src", "--root", ".", "--select", "MGD999"], cwd=tmp_path)
    assert r.returncode == 2


def test_cli_list_rules(tmp_path):
    r = _cli(["--list-rules"], cwd=tmp_path)
    assert r.returncode == 0
    for code in ALL_CODES:
        assert code in r.stdout


def test_self_test_passes_in_process():
    assert self_test(verbose=False) == 0


def test_repo_baseline_file_is_valid_json_list():
    path = REPO / "tools/mgdlint/baseline.json"
    assert path.is_file()
    assert isinstance(json.loads(path.read_text()), list)


def test_walker_qualname_and_alias_resolution(tmp_path):
    target = tmp_path / "m.py"
    target.write_text(textwrap.dedent("""\
        import jax.numpy as jnp

        class C:
            def meth(self):
                return jnp.dot
        """))
    s = SourceFile(target, tmp_path)
    import ast as ast_mod
    attr = next(n for n in ast_mod.walk(s.tree)
                if isinstance(n, ast_mod.Attribute))
    assert s.resolve(attr) == "jax.numpy.dot"
    assert s.qualname(attr) == "C.meth"
