"""End-to-end reproductions of the paper's own training experiments
(Table 2 rows at reduced step budgets; full budgets in benchmarks/)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MGDConfig, make_mgd_epoch, mgd_init, mse
from repro.data import tasks
from repro.data.pipeline import dataset_sampler, generator_sampler
from repro.models.simple import mlp_apply, mlp_init
from repro.training.train_loop import train_backprop


def _train_scan(loss_fn, params, cfg, sample_fn, steps, chunk=2000):
    run = make_mgd_epoch(loss_fn, cfg, chunk, sample_fn)
    state = mgd_init(params, cfg)
    for _ in range(steps // chunk):
        params, state, metrics = run(params, state)
    return params, state


def test_xor_trains_to_solution():
    """Paper Fig. 4 / Table 2 row 1: 2-2-1 net solves 2-bit parity with
    MGD (τ_θ = τ_p = τ_x = 1).  Calibration note (EXPERIMENTS.md §Paper):
    the paper's η = 5 saturates our N(0,1/√fan_in)-initialized sigmoids;
    η = 1 solves 8/8 seeds within 15k steps — the claims reproduced are
    the algorithmic ones, not the η value."""
    x, y = tasks.xor_dataset()
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])   # noqa: E731
    sample_fn = dataset_sampler(x, y, 1)
    finals = []
    for seed in (1, 2, 3):
        params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
        cfg = MGDConfig(dtheta=1e-2, eta=1.0, tau_theta=1, tau_x=1,
                        seed=seed)
        params, _ = _train_scan(loss_fn, params, cfg, sample_fn, 20000)
        finals.append(float(mse(mlp_apply(params, x), y)))
    assert sorted(finals)[1] < 0.04, finals   # median seed solves


def test_xor_mgd_tracks_backprop():
    """Paper Fig. 4a: long integration (τ_θ = τ_x large) follows the
    backprop trajectory; both must reach the solution.

    Tolerance rationale: XOR has stuck inits — a 2-2-1 sigmoid net can
    park on the 0.125-cost plateau (one hidden unit saturated, two
    outputs pinned at 0.5), and whether a given init escapes within the
    budget is seed-sensitive for BOTH algorithms (the paper reports
    medians over 100–1000 inits for exactly this reason, §3.1).  A
    single-seed assert here flaked (PRNGKey(5) parks MGD on that
    plateau); assert the median over a small seed set instead.  Seed set
    (1, 2, 5) deliberately includes the stuck init 5 — of inits 0–11,
    only 5/7/8 park on the plateau under this config — so the test keeps
    exercising the robustness story without betting the assert on it."""
    x, y = tasks.xor_dataset()
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])   # noqa: E731
    sample_fn = dataset_sampler(x, y, 4)
    finals_mgd, finals_bp = [], []
    for seed in (1, 2, 5):
        p0 = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
        cfg = MGDConfig(dtheta=1e-2, eta=1.0, tau_theta=1, tau_x=1, seed=0)
        p_mgd, _ = _train_scan(loss_fn, p0, cfg, sample_fn, 20000)
        res = train_backprop(loss_fn, p0, sample_fn, 2000, eta=2.0,
                             log=None)
        finals_mgd.append(float(mse(mlp_apply(p_mgd, x), y)))
        finals_bp.append(float(mse(mlp_apply(res.params, x), y)))
    assert sorted(finals_mgd)[1] < 0.04, finals_mgd
    assert sorted(finals_bp)[1] < 0.04, finals_bp


def test_nist7x7_accuracy():
    """Paper Table 2: 49-4-4 on NIST7x7 batch-1 MGD reaches 81% at 1e5
    steps.  At the SPSA-stable η = 0.1 (η_max ≈ 2/(λP), P = 220) we
    measure ~84% at 9e4 steps; require > 70%."""
    params = mlp_init(jax.random.PRNGKey(2), (49, 4, 4))
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])   # noqa: E731
    sample_fn = generator_sampler(tasks.nist7x7_batch, 1, seed=11)
    cfg = MGDConfig(dtheta=1e-2, eta=0.1, tau_theta=1, tau_x=1, seed=1)
    params, _ = _train_scan(loss_fn, params, cfg, sample_fn, 90000,
                            chunk=15000)
    xe, ye = tasks.nist7x7_batch(jax.random.PRNGKey(99), 512)
    acc = float(jnp.mean((jnp.argmax(mlp_apply(params, xe), -1)
                          == jnp.argmax(ye, -1)).astype(jnp.float32)))
    assert acc > 0.70, acc


def test_batching_via_tau_x():
    """Paper Fig. 3: τ_θ/τ_x controls effective batch.  τ_θ = 4·τ_x with
    the 4 XOR samples cycled ≡ full-batch gradient descent — it must
    solve the task."""
    x, y = tasks.xor_dataset()
    loss_fn = lambda p, b: mse(mlp_apply(p, b["x"]), b["y"])   # noqa: E731
    sample_fn = dataset_sampler(x, y, 1)     # one sample at a time
    finals = []
    for seed in (1, 2, 3):
        params = mlp_init(jax.random.PRNGKey(seed), (2, 2, 1))
        # G accumulates over τ_θ = 4, so η·τ_θ ≈ 1 matches the τ_θ = 1 runs
        cfg = MGDConfig(dtheta=1e-2, eta=0.25, tau_theta=4, tau_x=1,
                        seed=seed)
        params, _ = _train_scan(loss_fn, params, cfg, sample_fn, 40000)
        finals.append(float(mse(mlp_apply(params, x), y)))
    assert sorted(finals)[1] < 0.04, finals
