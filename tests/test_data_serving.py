"""Data substrate + serving loop tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import tasks
from repro.data.pipeline import dataset_sampler, generator_sampler
from repro.models import model_init
from repro.serving import greedy_generate, serve_batch


def test_parity_dataset_exact():
    x, y = tasks.parity_dataset(3)
    assert x.shape == (8, 3) and y.shape == (8, 1)
    for xi, yi in zip(np.asarray(x), np.asarray(y)):
        assert yi[0] == (xi.sum() % 2)


def test_nist7x7_shapes_and_labels():
    x, y = tasks.nist7x7_batch(jax.random.PRNGKey(0), 64)
    assert x.shape == (64, 49) and y.shape == (64, 4)
    np.testing.assert_allclose(np.asarray(y).sum(-1), 1.0)
    # noiseless centered glyphs are linearly separable sanity: distinct means
    x0, y0 = tasks.nist7x7_batch(jax.random.PRNGKey(1), 256, noise=0.0,
                                 shift=False)
    cls = np.asarray(y0).argmax(-1)
    means = [np.asarray(x0)[cls == c].mean(0) for c in range(4)]
    for i in range(4):
        for j in range(i + 1, 4):
            assert np.abs(means[i] - means[j]).max() > 0.5


def test_lm_batch_next_token_labels():
    b = tasks.lm_batch(jax.random.PRNGKey(0), 4, 16, 97)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
    assert int(b["tokens"].max()) < 97


def test_samplers_deterministic():
    s = generator_sampler(tasks.nist7x7_batch, 8, seed=5)
    a = s(3)
    b = s(3)
    np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
    x, y = tasks.xor_dataset()
    ds = dataset_sampler(x, y, 2)
    first = ds(0)
    again = ds(2)   # wraps: 4 samples / batch 2 → period 2
    np.testing.assert_array_equal(np.asarray(first["x"]),
                                  np.asarray(again["x"]))


def test_greedy_generate_deterministic():
    cfg = get_smoke_config("qwen3-14b")
    params = model_init(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab)
    out1 = greedy_generate(params, cfg, prompts, 8)
    out2 = greedy_generate(params, cfg, prompts, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 8)


def test_serve_batch_ragged():
    cfg = get_smoke_config("rwkv6-7b")
    params = model_init(cfg, jax.random.PRNGKey(0))
    reqs = [jnp.arange(5, dtype=jnp.int32) % cfg.vocab,
            jnp.arange(9, dtype=jnp.int32) % cfg.vocab]
    out = serve_batch(params, cfg, reqs, 4)
    assert out.shape == (2, 4)
