"""End-to-end driver: train a transformer LM with MGD for a few hundred
steps, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm_mgd.py                 # ~6M params
    PYTHONPATH=src python examples/train_lm_mgd.py --scale 100m    # ~100M

The model is a qwen3-family decoder (RMSNorm/GQA/SwiGLU/RoPE) from the
assigned-architecture zoo; data is the synthetic Zipf-Markov stream; the
optimizer is central-difference MGD with probe averaging.  Kill it halfway
and re-run: it resumes from the checkpoint onto the same trajectory.
"""
import argparse

import jax

from repro.api import DriverConfig
from repro.configs import get_smoke_config
from repro.data.pipeline import lm_sampler
from repro.models import model_init, model_loss
from repro.training.train_loop import train_mgd

SCALES = {
    # d_model, layers, heads, kv, d_head, d_ff  (≈ params with vocab 4096)
    "6m": (256, 4, 4, 2, 64, 1024),
    "25m": (512, 6, 8, 4, 64, 2048),
    "100m": (768, 12, 12, 4, 64, 3072),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="6m", choices=sorted(SCALES))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--probes", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/mgd_lm_ckpt")
    args = ap.parse_args()

    d, L, h, kv, dh, ff = SCALES[args.scale]
    cfg = get_smoke_config("qwen3-14b").replace(
        d_model=d, n_layers=L, n_heads=h, n_kv_heads=kv, d_head=dh,
        d_ff=ff, vocab=4096, attn_q_block=128, attn_kv_block=128)
    params = model_init(cfg, jax.random.PRNGKey(0))
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    print(f"[lm] {args.scale} model: {n/1e6:.1f}M params, "
          f"{args.probes}-probe central MGD")

    # probe-averaged central MGD: the at-scale configuration (on a pod the
    # probes map onto the "pod" mesh axis — repro.driver("probe_parallel"))
    mgd_cfg = DriverConfig(mode="central", dtheta=1e-3, eta=2e-3,
                           probes=args.probes, seed=0)
    loss_fn = lambda p, b: model_loss(p, cfg, b)       # noqa: E731
    sample_fn = lm_sampler(args.batch, args.seq, cfg.vocab, seed=1)
    res = train_mgd(loss_fn, params, mgd_cfg, sample_fn, args.steps,
                    chunk=25, checkpoint_dir=args.ckpt_dir,
                    checkpoint_every=100)
    first, last = res.history[0][1]["cost"], res.history[-1][1]["cost"]
    print(f"[lm] cost {first:.4f} → {last:.4f} over {res.steps_done} steps"
          f" (checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
