"""Online LM serving example: live requests through ``repro.serve``'s
fixed-slot dispatcher, with optional background MGD re-trim from request
feedback.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-14b
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b --trim

Each "request" is a fixed-length token window; the service pads ragged
client prompts into the window, batches concurrent requests into decode
slots, and answers with next-token logits from one snapshot-consistent
parameter version per batch.  With ``--trim``, labeled feedback flows
into the replay buffer and a background MGD trimmer improves the served
weights while traffic keeps flowing — no backprop, scalar cost only.

Works with any non-stub assigned architecture at smoke scale — including
the recurrent ones (rwkv6/zamba2).
"""
import argparse
import time

import jax
import numpy as np

from repro.api import DriverConfig
from repro.configs import get_smoke_config
from repro.models import model_forward, model_init, model_loss
from repro.serving import ServiceConfig, TrimConfig, serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--window", type=int, default=16,
                    help="fixed decode-slot window (tokens)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--trim", action="store_true",
                    help="background MGD re-trim from request feedback")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = model_init(cfg, jax.random.PRNGKey(args.seed))
    S = args.window

    def predict_fn(p, batch):
        return model_forward(p, cfg, {"tokens": batch["tokens"]})[:, -1, :]

    trim = None
    if args.trim:
        trim = TrimConfig(
            DriverConfig(dtheta=1e-3, eta=2e-3, probes=4, mode="central",
                         seed=args.seed),
            lambda p, b: model_loss(p, cfg, b))

    svc_cfg = ServiceConfig(slots=4, batch_window_s=0.002, min_fill=8,
                            trim_batch=4, publish_every=10, seed=args.seed)

    # ragged client prompts, padded caller-side into the fixed window
    key = jax.random.PRNGKey(args.seed + 1)
    rng = np.random.default_rng(args.seed + 2)
    prompts = [
        np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                      (int(n),), 0, cfg.vocab))
        for i, n in enumerate(rng.integers(5, S + 1, args.requests))
    ]

    with serve(svc_cfg, predict_fn, params, trim=trim, start=False) as svc:
        t0 = time.time()
        futs = []
        for i, p in enumerate(prompts):
            window = np.zeros(S, p.dtype)
            window[-len(p):] = p[-S:]           # left-pad into the slot
            feedback = {"labels": np.roll(window, -1)} if args.trim else None
            futs.append(svc.submit({"tokens": window}, feedback=feedback))
        results = [f.result(120) for f in futs]
        if args.trim:                           # let the trainer catch up
            deadline = time.time() + 60
            while (svc.stats()["trim_global_step"] < 16
                   and time.time() < deadline):
                time.sleep(0.02)
        svc.fence()
        stats = svc.stats()
        dt = time.time() - t0

    print(f"[serve] {cfg.name}: {len(results)} requests in {dt:.2f}s "
          f"({len(results) / dt:.1f} req/s), "
          f"p50={stats['latency_p50_ms']:.2f}ms "
          f"p99={stats['latency_p99_ms']:.2f}ms, "
          f"param version {stats['version']}"
          + (f", {stats['trim_global_step']} trim steps" if args.trim else ""))
    for i in (0, 1, 2):
        r = results[i]
        top = np.argsort(np.asarray(r.output))[-3:][::-1]
        print(f"  req{i} ({len(prompts[i])} prompt toks, v{r.version}) "
              f"top-3 next tokens -> {top.tolist()}")


if __name__ == "__main__":
    main()
