"""Batched serving example: prefill a ragged request batch, decode with the
KV cache, stream greedy tokens.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b

Works with any non-stub assigned architecture at smoke scale — including
the recurrent ones (rwkv6/zamba2), whose "KV cache" is an O(1) state.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import model_init
from repro.serving import serve_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = model_init(cfg, jax.random.PRNGKey(0))

    # a ragged batch of "requests"
    key = jax.random.PRNGKey(1)
    requests = [
        jax.random.randint(jax.random.fold_in(key, i), (n,), 0, cfg.vocab)
        for i, n in enumerate((5, 17, 9, 30))
    ]
    t0 = time.time()
    out = serve_batch(params, cfg, requests, args.max_new)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: {len(requests)} requests × "
          f"{args.max_new} new tokens in {dt:.2f}s "
          f"({len(requests) * args.max_new / dt:.1f} tok/s)")
    for i, row in enumerate(out):
        print(f"  req{i} ({len(requests[i])} prompt toks) →",
              row[:10].tolist(), "...")


if __name__ == "__main__":
    main()
