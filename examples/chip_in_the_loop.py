"""Chip-in-the-loop training through ``hardware.ExternalPlant`` (paper §4/§6).

An analog accelerator sits behind an OPAQUE lab-instrument API — write
parameters, present an input, read ONE scalar cost.  The device
internally has per-neuron activation defects (σ_a), parameter-write
noise (σ_θ) and cost-readout noise (σ_C) that the trainer never models —
exactly the regime where backprop-through-a-model fails (the paper cites
a 97.6% → 63.9% accuracy drop on transfer) and model-free MGD shines.

The trainer side is the SAME ``repro.driver(...)`` registry that drives
every in-process device:

* ``--chips 1`` (default): one chip behind ``ExternalPlant`` driven by
  the discrete central-difference driver — each cost read is an ordered
  host callback (set_params → present batch → measure_cost).
* ``--chips k``: a FARM of k simulated chips with distinct device seeds
  (different defect draws, different noise streams) behind ``ChipFarm``,
  driven by ``repro.driver("probe_parallel_external", ...)`` — k probes
  evaluate concurrently on the k instruments and the trainer averages
  the k error scalars (paper §6's farm picture; variance ∝ 1/k at the
  wall-clock of a single chip).

Swap ``SimulatedAnalogChip`` for a serial-port driver with the same
two/three methods and nothing else changes.

``--drift σ_d`` ages the chip(s): the stored weights random-walk between
writes (``DriftingAnalogChip``), keyed on the optimizer's step counter so
reruns replay the identical aging.  MGD keeps probing the device where it
actually is, so training holds up — the drift study proper lives in
``benchmarks/drift_aging.py``.

``--fault-rate p`` makes the instrument(s) UNRELIABLE — counter-keyed
transient crashes (and, on a farm, outlier readouts) injected through
``FaultyChip`` — and arms the host boundary with a ``FaultPolicy``:
timeouts, retry-with-backoff, and on a farm per-chip masking +
quarantine + MAD outlier rejection.  Training rides through; the fault
summary prints at the end.  The study proper lives in
``benchmarks/fault_tolerance.py``.

    PYTHONPATH=src python examples/chip_in_the_loop.py
    PYTHONPATH=src python examples/chip_in_the_loop.py --chips 4
    PYTHONPATH=src python examples/chip_in_the_loop.py --drift 0.02
    PYTHONPATH=src python examples/chip_in_the_loop.py --chips 4 \
        --fault-rate 0.1
"""
import argparse

import jax

import repro
from repro.data.tasks import nist7x7_batch
from repro.hardware import (DriftingAnalogChip, ExternalPlant, FaultPolicy,
                            FaultSpec, FaultyChip, SimulatedAnalogChip,
                            simulated_chip_farm)
from repro.models.simple import mlp_init

SIZES = (49, 4, 4)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=1,
                    help="farm size k (1 = single chip via ExternalPlant)")
    ap.add_argument("--steps", type=int, default=4001,
                    help="training iterations")
    ap.add_argument("--eval-every", type=int, default=800,
                    help="on-chip accuracy readout period")
    ap.add_argument("--eta", type=float, default=None,
                    help="learning rate (default: 0.1 single chip; "
                         "0.125·k for a farm — the k-averaged error "
                         "signal has 1/k the variance, so it supports a "
                         "proportionally larger step)")
    ap.add_argument("--drift", type=float, default=0.0, metavar="SIGMA_D",
                    help="per-step random-walk std of the stored weights "
                         "(aging chip; 0 = stable device)")
    ap.add_argument("--fault-rate", type=float, default=0.0, metavar="P",
                    help="per-readout fault probability (transient crashes "
                         "+ outliers); arms the FaultPolicy host boundary "
                         "(0 = reliable instrument, no policy)")
    args = ap.parse_args(argv)
    eta = args.eta if args.eta is not None else (
        0.1 if args.chips == 1 else 0.125 * args.chips)

    # central mode: external plants' ordered host callbacks need the
    # cond-free step (forward mode's C₀ refresh is a lax.cond).
    cfg = repro.DriverConfig(dtheta=2e-2, eta=eta, tau_theta=1,
                             mode="central", seed=0)
    plant = None
    if args.chips == 1:
        if args.drift:
            chip = DriftingAnalogChip(SIZES, seed=0, sigma_a=0.15,
                                      sigma_theta=0.01, sigma_c=1e-4,
                                      drift_rate=args.drift)
        else:
            chip = SimulatedAnalogChip(SIZES, seed=0, sigma_a=0.15,
                                       sigma_theta=0.01, sigma_c=1e-4)
        device, policy = chip, None
        if args.fault_rate:
            # a single chip cannot be masked — retries must carry it
            device = FaultyChip(chip, FaultSpec(transient=args.fault_rate),
                                seed=99)
            policy = FaultPolicy(timeout_s=10.0, retries=4, backoff_s=0.01)
        plant = ExternalPlant(device, fault_policy=policy)
        mgd = repro.driver("discrete", cfg, plant=plant)

        def accuracy(params, batch):
            chip.set_params(params)      # commit the belief, then read out
            return chip.measure_accuracy(batch)

        def writes():
            return chip.writes
    else:
        faults = policy = None
        if args.fault_rate:
            # half raising crashes, half silent outliers — masking,
            # quarantine and MAD aggregation all get exercised
            faults = FaultSpec(transient=args.fault_rate / 2,
                               outlier=args.fault_rate / 2,
                               outlier_scale=50.0)
            policy = FaultPolicy(timeout_s=10.0, retries=4, backoff_s=0.01,
                                 quarantine_after=6, reprobe_every=100,
                                 aggregate="mad")
        farm = simulated_chip_farm(args.chips, SIZES, base_seed=0,
                                   sigma_a=0.15, sigma_theta=0.01,
                                   sigma_c=1e-4, drift_rate=args.drift,
                                   faults=faults, fault_policy=policy)
        plant = farm
        mgd = repro.driver("probe_parallel_external", cfg, plant=farm)
        accuracy = farm.measure_accuracy

        def writes():
            return farm.total_writes

    # the trainer's view: parameters it *believes* are on the chip(s)
    params = mlp_init(jax.random.PRNGKey(1), SIZES)
    state = mgd.init(params)
    step_fn = jax.jit(mgd.step)

    key = jax.random.PRNGKey(7)
    for it in range(args.steps):
        key, kb = jax.random.split(key)
        x, y = nist7x7_batch(kb, 8)
        params, state, metrics = step_fn(params, state, {"x": x, "y": y})
        jax.block_until_ready(params)   # chip I/O is synchronous anyway
        if it % args.eval_every == 0:
            xe, ye = nist7x7_batch(jax.random.PRNGKey(99), 256)
            acc = accuracy(params, {"x": xe, "y": ye})
            print(f"iter {it:5d}: on-chip cost {float(metrics['cost']):.4f} "
                  f"accuracy {acc:.3f} (param writes: {writes()})")
    drift_note = (f", re-trimming drift sigma_d={args.drift:g}/step online"
                  if args.drift else "")
    print(f"trained {args.chips} chip(s) through the opaque interface only "
          f"— no gradients, no defect model, no weight readback{drift_note}.")
    if args.fault_rate:
        print(f"fault-tolerance summary at fault rate "
              f"{args.fault_rate:g}: {plant.fault_summary()}")


if __name__ == "__main__":
    main()
