"""Chip-in-the-loop training through ``hardware.ExternalPlant`` (paper §4/§6).

An analog accelerator sits behind an OPAQUE lab-instrument API — write
parameters, present an input, read ONE scalar cost.  The device
internally has per-neuron activation defects (σ_a), parameter-write
noise (σ_θ) and cost-readout noise (σ_C) that the trainer never models —
exactly the regime where backprop-through-a-model fails (the paper cites
a 97.6% → 63.9% accuracy drop on transfer) and model-free MGD shines.

The trainer side is the SAME ``repro.driver("discrete", ...)`` that
drives every in-process device: ``ExternalPlant`` lowers each cost read
to an ordered host callback (set_params → present batch → measure_cost),
so the optimizer has no access to device internals at all — swap the
``SimulatedAnalogChip`` for a serial-port driver with the same two
methods and nothing else changes.

    PYTHONPATH=src python examples/chip_in_the_loop.py
"""
import jax

import repro
from repro.data.tasks import nist7x7_batch
from repro.hardware import ExternalPlant, SimulatedAnalogChip
from repro.models.simple import mlp_init


def main():
    chip = SimulatedAnalogChip((49, 4, 4), seed=0, sigma_a=0.15,
                               sigma_theta=0.01, sigma_c=1e-4)
    plant = ExternalPlant(chip)

    # the trainer's view: parameters it *believes* are on the chip
    params = mlp_init(jax.random.PRNGKey(1), (49, 4, 4))
    # central mode: the external plant's ordered host callbacks need the
    # cond-free step (forward mode's C₀ refresh is a lax.cond).
    cfg = repro.DriverConfig(dtheta=2e-2, eta=0.1, tau_theta=1,
                             mode="central", seed=0)
    mgd = repro.driver("discrete", cfg, plant=plant)
    state = mgd.init(params)
    step_fn = jax.jit(mgd.step)

    key = jax.random.PRNGKey(7)
    for it in range(4001):
        key, kb = jax.random.split(key)
        x, y = nist7x7_batch(kb, 8)
        params, state, metrics = step_fn(params, state, {"x": x, "y": y})
        jax.block_until_ready(params)   # chip I/O is synchronous anyway
        if it % 800 == 0:
            xe, ye = nist7x7_batch(jax.random.PRNGKey(99), 256)
            chip.set_params(params)      # commit the belief, then read out
            acc = chip.measure_accuracy({"x": xe, "y": ye})
            print(f"iter {it:5d}: on-chip cost {float(metrics['cost']):.4f} "
                  f"accuracy {acc:.3f} (param writes: {chip.writes})")
    print("trained through the opaque interface only — no gradients, no "
          "defect model, no weight readback.")


if __name__ == "__main__":
    main()
