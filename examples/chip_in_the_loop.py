"""Chip-in-the-loop training (paper §4 / §6).

Emulates an analog hardware accelerator behind an OPAQUE device interface:
the trainer may only (1) write parameters, (2) present an input, (3) read
the scalar cost.  The device internally has per-neuron activation defects
(σ_a), parameter-write noise (σ_θ) and cost-readout noise (σ_C) that the
trainer never models — exactly the regime where backprop-through-a-model
fails (the paper cites a 97.6% → 63.9% accuracy drop on transfer) and
model-free MGD shines.

    PYTHONPATH=src python examples/chip_in_the_loop.py
"""
import jax
import jax.numpy as jnp

from repro.core import MGDConfig, make_mgd_step, mgd_init, mse
from repro.core.noise import sample_defects
from repro.data.tasks import nist7x7_batch
from repro.models.simple import mlp_apply, mlp_init


class AnalogChip:
    """The 'hardware': a 49-4-4 sigmoidal network with fabrication defects.

    Nothing outside this class may see the defects or the internal
    parameters — only set_params / measure_cost, like a lab instrument.
    """

    def __init__(self, seed=0, sigma_a=0.15, sigma_theta=0.01,
                 sigma_c=1e-4):
        self._defects = [sample_defects(seed, 4, sigma_a),
                         sample_defects(seed + 1, 4, sigma_a)]
        self._sigma_theta = sigma_theta
        self._sigma_c = sigma_c
        self._params = None
        self._key = jax.random.PRNGKey(seed + 2)
        self.writes = 0

    def _noise(self, shape):
        self._key, k = jax.random.split(self._key)
        return jax.random.normal(k, shape)

    def set_params(self, params):
        """Analog memory write — each write lands with noise."""
        self.writes += 1
        self._params = jax.tree_util.tree_map(
            lambda w: w + self._sigma_theta * self._noise(w.shape), params)

    def infer(self, x):
        return mlp_apply(self._params, x, defects=self._defects)

    def measure_cost(self, x, y):
        """Scalar cost readout with measurement noise."""
        c = mse(self.infer(x), y)
        return float(c + self._sigma_c * self._noise(())[()])


def main():
    chip = AnalogChip()
    # the trainer's view: parameters it *believes* are on the chip
    params = mlp_init(jax.random.PRNGKey(1), (49, 4, 4))
    cfg = MGDConfig(dtheta=2e-2, eta=0.1, tau_theta=1, seed=0)

    # model-free loss: ship θ to the chip, show the sample, read the cost.
    # (make_mgd_step wants a jax-traceable callable; chip-in-the-loop runs
    # eagerly instead, so we hand-roll the central-difference probe.)
    from repro.core import perturbations as pert
    from repro.core.utils import tree_add, tree_axpy, tree_scale

    key = jax.random.PRNGKey(7)
    state_step = 0
    for it in range(4001):
        key, kb = jax.random.split(key)
        x, y = nist7x7_batch(kb, 8)
        theta_t = pert.generate(params, ptype="rademacher", step=state_step,
                                seed=cfg.seed, dtheta=cfg.dtheta)
        chip.set_params(tree_add(params, theta_t))
        c_plus = chip.measure_cost(x, y)
        chip.set_params(tree_axpy(-1.0, theta_t, params))
        c_minus = chip.measure_cost(x, y)
        c_tilde = 0.5 * (c_plus - c_minus)
        params = tree_axpy(-cfg.eta * c_tilde / cfg.dtheta ** 2,
                           theta_t, params)
        state_step += 1
        if it % 800 == 0:
            xe, ye = nist7x7_batch(jax.random.PRNGKey(99), 256)
            chip.set_params(params)
            acc = float(jnp.mean(
                (jnp.argmax(chip.infer(xe), -1)
                 == jnp.argmax(ye, -1)).astype(jnp.float32)))
            print(f"iter {it:5d}: on-chip cost {c_plus:.4f} "
                  f"accuracy {acc:.3f} (param writes: {chip.writes})")
    print("trained through the opaque interface only — no gradients, no "
          "defect model, no weight readback.")


if __name__ == "__main__":
    main()
