"""Quickstart: train XOR with multiplexed gradient descent in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

The entire interface between MGD and the model is ONE scalar-valued
function ``loss_fn(params, batch)`` — no gradients, no model structure.
Every algorithm is built the same way through the driver registry:

    mgd = repro.driver("discrete" | "analog" | "probe_parallel", cfg,
                       loss_fn, plant=..., probe_fn=..., mesh=...)
    state = mgd.init(params)
    params, state, aux = mgd.step(params, state, batch)

``aux`` always carries ``cost``, ``c_tilde`` (the one-scalar feedback)
and ``grad_norm_proxy``; ``repro.make_epoch`` scans many steps into one
jitted call.
"""
import jax

import repro
from repro.core import mse
from repro.data.pipeline import dataset_sampler
from repro.data.tasks import xor_dataset
from repro.models.simple import mlp_apply, mlp_init


def main():
    x, y = xor_dataset()
    params = mlp_init(jax.random.PRNGKey(2), (2, 2, 1))   # the paper's 2-2-1

    def loss_fn(p, batch):
        return mse(mlp_apply(p, batch["x"]), batch["y"])

    # τ_p = τ_θ = τ_x = 1 with ±Δθ Rademacher codes == SPSA (paper Fig. 2c)
    cfg = repro.DriverConfig(ptype="rademacher", dtheta=1e-2, eta=1.0,
                             tau_theta=1, tau_x=1, seed=0)
    mgd = repro.driver("discrete", cfg, loss_fn)
    run = repro.make_epoch(mgd, 2000, dataset_sampler(x, y, 1))
    state = mgd.init(params)
    for epoch in range(10):
        params, state, aux = run(params, state)
        cost = float(mse(mlp_apply(params, x), y))
        print(f"iteration {2000 * (epoch + 1):6d}: dataset cost {cost:.4f} "
              f"(|grad| proxy {float(aux['grad_norm_proxy'][-1]):.3g})")
        if cost < 0.04:
            print("solved (paper threshold 0.04)")
            break
    print("predictions:", [round(float(v), 3)
                           for v in mlp_apply(params, x)[:, 0]])


if __name__ == "__main__":
    main()
