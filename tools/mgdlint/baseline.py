"""Baseline handling: grandfathered findings committed to the repo.

The baseline file is a sorted JSON list of line-number-free
fingerprints (``rule``, ``path``, ``symbol``, ``snippet``).  A finding
matching an entry is *grandfathered* — reported as such, but not a
failure; anything else is NEW and fails the run.  Matching is multiset
(two identical offending lines in one function need two entries), so a
fix cannot hide behind a sibling's entry.

Policy, enforced by ``tests/test_hygiene.py``: ``src/repro/hardware/``
and ``src/repro/distributed/`` must carry ZERO baseline entries — the
host-boundary and sharding invariants are exactly the ones that
deadlock, corrupt training or silently retrace when violated, so
findings there get fixed or explicitly waived with a reason, never
grandfathered.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Sequence, Tuple

from .registry import Finding

KEYS = ("rule", "path", "symbol", "snippet")


def load(path: pathlib.Path) -> List[dict]:
    if not path.is_file():
        return []
    entries = json.loads(path.read_text())
    if not isinstance(entries, list):
        raise ValueError(f"{path}: baseline must be a JSON list")
    for e in entries:
        missing = [k for k in KEYS if k not in e]
        if missing:
            raise ValueError(f"{path}: baseline entry missing "
                             f"{missing}: {e}")
    return entries


def save(path: pathlib.Path, findings: Sequence[Finding]) -> List[dict]:
    entries = sorted(
        ({"rule": f.code, "path": f.path, "symbol": f.symbol,
          "snippet": f.snippet} for f in findings),
        key=lambda e: tuple(e[k] for k in KEYS))
    path.write_text(json.dumps(entries, indent=2) + "\n")
    return entries


def split(findings: Sequence[Finding], entries: Sequence[dict]
          ) -> Tuple[List[Finding], List[Finding], List[dict]]:
    """(new, grandfathered, stale_entries) — multiset matching on the
    line-number-free fingerprint."""
    budget: Dict[Tuple[str, str, str, str], int] = {}
    for e in entries:
        key = (e["rule"], e["path"], e["symbol"], e["snippet"])
        budget[key] = budget.get(key, 0) + 1
    new, grandfathered = [], []
    for f in findings:
        key = f.fingerprint()
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            grandfathered.append(f)
        else:
            new.append(f)
    stale = []
    for e in entries:
        key = (e["rule"], e["path"], e["symbol"], e["snippet"])
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            stale.append(e)
    return new, grandfathered, stale
