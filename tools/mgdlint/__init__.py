"""mgdlint — AST-based invariant checker for the MGD repro repo.

Encodes the repo's hard-won host-boundary invariants (counter-keyed
randomness, numpy-pure io_callbacks, timeout/lock/fence discipline) as
static rules with per-rule codes, inline waivers and a committed
baseline.  Stdlib-only: ``PYTHONPATH=tools python -m mgdlint src tests``.
"""
from __future__ import annotations

from . import rules as _rules  # noqa: F401  (registers the rule classes)
from .baseline import load as load_baseline
from .baseline import save as save_baseline
from .baseline import split as split_baseline
from .registry import (RULES, Finding, LintResult, Rule, all_rules,
                       run_lint)
from .walker import SourceFile, iter_python_files

__all__ = [
    "RULES", "Finding", "LintResult", "Rule", "SourceFile", "all_rules",
    "iter_python_files", "load_baseline", "run_lint", "save_baseline",
    "split_baseline",
]

__version__ = "0.1.0"
