"""AST walking infrastructure shared by every mgdlint rule.

``SourceFile`` parses one Python file once and exposes everything a rule
needs: the AST with parent links, the enclosing-scope qualname of any
node, a resolved import-alias table (so ``jnp.dot`` is recognised as
``jax.numpy.dot`` regardless of the local alias), dotted-name rendering
for call targets, and the inline waiver table
(``# mgdlint: disable=MGDxxx (reason)``).

Everything here is stdlib-only — the linter must run on a bare CI box
before any project dependency is installed.
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

#: Inline waiver syntax.  The reason is MANDATORY: an unexplained waiver
#: is itself reported (as MGD000) — every suppression must say why.
WAIVER_RE = re.compile(
    r"#\s*mgdlint:\s*disable=([A-Za-z0-9_,\s]+?)\s*(?:\((.*)\))?\s*$")

CODE_RE = re.compile(r"^MGD\d{3}$")


class Waiver:
    """One parsed ``# mgdlint: disable=...`` comment."""

    __slots__ = ("line", "codes", "reason", "raw", "used")

    def __init__(self, line: int, codes: Tuple[str, ...],
                 reason: Optional[str], raw: str):
        self.line = line
        self.codes = codes
        self.reason = reason
        self.raw = raw
        self.used = False

    @property
    def malformed(self) -> Optional[str]:
        """Why this waiver is invalid, or None when well-formed."""
        bad = [c for c in self.codes if not CODE_RE.match(c)]
        if bad:
            return f"unknown rule code(s) {', '.join(bad)}"
        if not self.reason or not self.reason.strip():
            return ("missing reason — write "
                    "`# mgdlint: disable=MGDxxx (why this is safe)`")
        return None


def _parse_waivers(lines: Sequence[str]) -> List[Waiver]:
    waivers = []
    for i, line in enumerate(lines, start=1):
        m = WAIVER_RE.search(line)
        if m:
            codes = tuple(c.strip() for c in m.group(1).split(",")
                          if c.strip())
            waivers.append(Waiver(i, codes, m.group(2), line.strip()))
    return waivers


def dotted_name(node: ast.AST) -> Optional[str]:
    """Render a Name/Attribute chain as ``a.b.c`` (None for anything
    dynamic, e.g. a subscript or call in the chain)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class SourceFile:
    """One parsed file: AST + parents + aliases + waivers.

    ``rel`` is the POSIX-style path relative to the lint root — the key
    every rule scopes on and every baseline entry records, so a checkout
    moved to another directory keeps its baseline.
    """

    def __init__(self, path: pathlib.Path, root: pathlib.Path):
        self.path = path
        self.rel = path.resolve().relative_to(root.resolve()).as_posix()
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        self.waivers = _parse_waivers(self.lines)
        self._waivers_by_line: Dict[int, List[Waiver]] = {}
        for w in self.waivers:
            self._waivers_by_line.setdefault(w.line, []).append(w)
        self.import_aliases = self._collect_aliases()

    # -- structure helpers ---------------------------------------------------

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def qualname(self, node: ast.AST) -> str:
        """Enclosing scope name, e.g. ``ChipFarm._host_pairs`` — the
        symbol a baseline entry anchors on (stable under line churn)."""
        names = []
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                names.append(anc.name)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.insert(0, node.name)
        return ".".join(reversed(names)) or "<module>"

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def enclosing_function(self, node: ast.AST) \
            -> Optional[ast.FunctionDef]:
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    # -- imports -------------------------------------------------------------

    def _collect_aliases(self) -> Dict[str, str]:
        """local name -> fully-dotted module/object path, from every
        import statement in the file (any nesting level)."""
        aliases: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    aliases[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    if a.name == "*":
                        continue
                    aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
        return aliases

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Fully-qualified dotted path of a Name/Attribute chain after
        import-alias substitution: with ``import jax.numpy as jnp``,
        ``jnp.dot`` resolves to ``jax.numpy.dot``."""
        name = dotted_name(node)
        if name is None:
            return None
        root, _, rest = name.partition(".")
        target = self.import_aliases.get(root)
        if target is None:
            return name
        return f"{target}.{rest}" if rest else target

    # -- waivers -------------------------------------------------------------

    def waived(self, code: str, line: int) -> bool:
        """True when a well-formed waiver for ``code`` sits on ``line``
        or on the immediately preceding (comment-only) line."""
        for probe in (line, line - 1):
            for w in self._waivers_by_line.get(probe, ()):
                if w.malformed:
                    continue
                if probe == line - 1 and not \
                        self.lines[probe - 1].lstrip().startswith("#"):
                    continue        # trailing waiver governs its own line
                if code in w.codes:
                    w.used = True
                    return True
        return False


def iter_python_files(paths: Sequence[pathlib.Path],
                      root: pathlib.Path) -> Iterator[pathlib.Path]:
    """Yield every ``*.py`` under ``paths`` (files or directories),
    deterministically ordered, skipping caches and hidden directories."""
    seen: Set[pathlib.Path] = set()
    for p in paths:
        p = pathlib.Path(p)
        if not p.is_absolute():
            p = root / p
        if p.is_file() and p.suffix == ".py":
            candidates = [p]
        elif p.is_dir():
            candidates = sorted(p.rglob("*.py"))
        else:
            raise FileNotFoundError(f"mgdlint: no such path: {p}")
        for c in candidates:
            parts = c.relative_to(root).parts if c.is_relative_to(root) \
                else c.parts
            if any(part == "__pycache__" or part.startswith(".")
                   for part in parts):
                continue
            c = c.resolve()
            if c not in seen:
                seen.add(c)
                yield c


def call_positional_count(call: ast.Call) -> int:
    return len(call.args)


def call_has_kwarg(call: ast.Call, name: str) -> bool:
    return any(k.arg == name for k in call.keywords)
